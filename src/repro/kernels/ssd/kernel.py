"""Mamba2 SSD (state-space duality) chunked scan, Pallas TPU.

Recurrence per head (state h in R^{N x P}):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * outer(B_t, x_t)
    y_t = C_t @ h_t

Chunked SSD form (arXiv:2405.21060): within a chunk of length L the output
is an attention-like quadratic term gated by the decay matrix
Lmat[i,j] = exp(g_i - g_j) (i >= j, g = cumsum(dt*A)); across chunks a
single (N, P) state carries.

Grid: (batch, heads, num_chunks) with the chunk axis SEQUENTIAL
("arbitrary") so the inter-chunk state lives in VMEM scratch.  B and C are
shared across heads (ngroups=1, Mamba2 default).

VMEM per step (fp32, L=128, P=64, N=128):
    x,y (L,P) 32 KB each | B,C (L,N) 64 KB each | CB,Lmat (L,L) 64 KB each
    | h scratch (N,P) 32 KB  — trivially VMEM-resident.

Stability: A < 0 and dt > 0 => all exponents <= 0, every exp() <= 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (L,)
    a = a_ref[0].astype(jnp.float32)                 # scalar (negative)
    b = b_ref[0].astype(jnp.float32)                 # (L, N)
    c = c_ref[0].astype(jnp.float32)                 # (L, N)

    dta = dt * a                                     # (L,), <= 0
    g = jnp.cumsum(dta)                              # (L,)

    # inter-chunk: y_i += exp(g_i) * (C_i @ h_prev)
    h_prev = h_scr[...]                              # (N, P)
    decay_out = jnp.exp(g)[:, None]                  # (L, 1)
    y_inter = (c * decay_out) @ h_prev               # (L, P)

    # intra-chunk: y_i += sum_{j<=i} exp(g_i - g_j) (C_i.B_j) dt_j x_j
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    i_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(j_ids <= i_ids,
                     jnp.exp(g[:, None] - g[None, :]), 0.0)
    y_intra = (cb * lmat) @ (dt[:, None] * x)        # (L, P)

    y_ref[0, :, 0, :] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: h = exp(g_last) h_prev + sum_j exp(g_last - g_j) dt_j B_j x_j^T
    decay_state = jnp.exp(g[-1] - g)[:, None]        # (L, 1)
    bw = b * decay_state * dt[:, None]               # (L, N)
    h_scr[...] = jnp.exp(g[-1]) * h_prev + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (N, P)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, *, chunk: int = DEFAULT_CHUNK,
        interpret: bool = True):
    """x: (B, S, H, P); dt: (B, S, H) (post-softplus, > 0);
    a_log: (H,) (A = -exp(a_log)); b, c: (B, S, N).  Returns (B, S, H, P).
    S must be divisible by chunk (pad upstream)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))          # (H,), negative

    grid = (bsz, h, nc)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),     # x
            pl.BlockSpec((1, chunk, 1),
                         lambda bi, hi, ci: (bi, ci, hi)),        # dt
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),         # A
            pl.BlockSpec((1, chunk, n),
                         lambda bi, hi, ci: (bi, ci, 0)),         # B
            pl.BlockSpec((1, chunk, n),
                         lambda bi, hi, ci: (bi, ci, 0)),         # C
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b, c)

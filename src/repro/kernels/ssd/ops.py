"""Public SSD op: Pallas chunked scan with jnp-scan fallback."""
from __future__ import annotations

from typing import Optional

import jax

from . import kernel, ref


def ssd_scan(x, dt, a_log, b, c, *, chunk: int = kernel.DEFAULT_CHUNK,
             use_kernel: bool = True, interpret: Optional[bool] = None,
             unroll_heads: bool = False, head_blocks: int = 0):
    """Mamba2 SSD: x (B,S,H,P), dt (B,S,H) > 0, a_log (H,), b/c (B,S,N).

    Paths: Pallas kernel (TPU target) > chunked jnp (XLA fallback /
    dry-run) > exact sequential scan (odd lengths)."""
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    s = x.shape[1]
    eff_chunk = min(chunk, s)
    if s % eff_chunk != 0:
        return ref.ssd_scan_ref(x, dt, a_log, b, c)
    if use_kernel:
        return kernel.ssd(x, dt, a_log, b, c, chunk=eff_chunk,
                          interpret=interpret)
    return ref.ssd_chunked_jnp(x, dt, a_log, b, c, chunk=eff_chunk,
                               unroll_heads=unroll_heads,
                               head_blocks=head_blocks)

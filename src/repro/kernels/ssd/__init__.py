from .ops import ssd_scan  # noqa: F401
from . import ref  # noqa: F401

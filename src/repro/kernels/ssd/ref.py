"""Pure-jnp oracles for the SSD recurrence.

``ssd_scan_ref``      exact sequential per-timestep scan (ground truth).
``ssd_chunked_jnp``   chunked SSD in vectorized jnp: per-chunk quadratic
                      terms + associative scan across chunks.  This is the
                      XLA execution path for SSM models when the Pallas
                      kernel is off — fully parallel (no while loop), so
                      dry-run cost_analysis counts its work correctly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a_log, b, c):
    """Exact per-timestep recurrence.

    x: (B, S, H, P); dt: (B, S, H); a_log: (H,); b, c: (B, S, N).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))          # (H,)

    def step(h_state, inputs):
        x_t, dt_t, b_t, c_t = inputs                 # (H,P),(H,),(N,),(N,)
        da = jnp.exp(dt_t * a)                       # (H,)
        inc = dt_t[:, None, None] * b_t[None, :, None] \
            * x_t[:, None, :]                        # (H, N, P)
        h_state = da[:, None, None] * h_state + inc
        y_t = jnp.einsum("n,hnp->hp", c_t, h_state)
        return h_state, y_t

    def per_batch(xb, dtb, bb, cb):
        h0 = jnp.zeros((h, n, p), jnp.float32)
        _, ys = jax.lax.scan(
            step, h0,
            (xb.astype(jnp.float32), dtb.astype(jnp.float32),
             bb.astype(jnp.float32), cb.astype(jnp.float32)))
        return ys                                    # (S, H, P)

    ys = jax.vmap(per_batch)(x, dt, b, c)
    return ys.astype(x.dtype)


def _ssd_chunked_one_head(xh, dth, a_h, bf, cf, tile_dtype=None):
    """Chunked SSD for ONE head (keeps the (L, L) decay matrix per
    (batch, chunk) only — the memory shape the Pallas kernel realizes).

    xh: (B, nc, L, P); dth: (B, nc, L); a_h: scalar; bf, cf: (B, nc, L, N).
    tile_dtype: storage dtype for the (L, L) tiles (bf16 halves the HBM
    traffic the XLA fallback pays on them; accumulation stays fp32 via
    preferred_element_type — §Perf hillclimb).
    """
    chunk = xh.shape[2]
    td = tile_dtype or jnp.float32
    dta = dth * a_h                                           # (B,nc,L)
    g = jnp.cumsum(dta, axis=2)
    g_last = g[:, :, -1]                                      # (B,nc)

    # intra-chunk quadratic term
    cb = jax.lax.dot_general(
        cf.astype(td), bf.astype(td),
        (((3,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)                   # (B,nc,L,L)
    i_ids = jnp.arange(chunk)[:, None]
    j_ids = jnp.arange(chunk)[None, :]
    seg = g[:, :, :, None] - g[:, :, None, :]                 # (B,nc,L,L)
    # mask BEFORE exp: masked (j > i) entries have seg > 0 and would
    # overflow; where-after-exp leaks inf into the gradient (inf * 0 = nan)
    lmat = jnp.exp(jnp.where((j_ids <= i_ids)[None, None], seg, -1e30))
    y_intra = jax.lax.dot_general(
        (cb * lmat).astype(td), (xh * dth[..., None]).astype(td),
        (((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)                   # (B,nc,L,P)

    # per-chunk state contribution + cross-chunk associative scan
    decay_state = jnp.exp(g_last[:, :, None] - g)             # (B,nc,L)
    inc = jnp.einsum("bcln,bcl,bclp->bcnp",
                     bf, dth * decay_state, xh)               # (B,nc,N,P)
    chunk_decay = jnp.exp(g_last)                             # (B,nc)

    def combine(lhs, rhs):
        d1, s1 = lhs
        d2, s2 = rhs
        return d1 * d2, s1 * d2[..., None, None] + s2

    _, st_sc = jax.lax.associative_scan(
        combine, (chunk_decay, inc), axis=1)
    h_in = jnp.concatenate(
        [jnp.zeros_like(st_sc[:, :1]), st_sc[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcln,bcl,bcnp->bclp",
                         cf, jnp.exp(g), h_in)
    return y_intra + y_inter                                  # (B,nc,L,P)


def ssd_chunked_jnp(x, dt, a_log, b, c, *, chunk: int = 128,
                    unroll_heads: bool = False,
                    head_blocks: int = 0,
                    tile_dtype=None):
    """Chunked SSD in vectorized jnp (arXiv:2405.21060 Alg. 1), processed
    in HEAD BLOCKS so only (heads_per_block-vmapped) (B, nc, L, L) decay
    matrices are live — mirroring the Pallas kernel's VMEM tiling.

    The head axis is split (head_blocks, heads_per_block); the inner axis
    stays vectorized (it is the "model"-sharded axis in SPMD lowerings, so
    each chip computes only its own heads), while the outer axis is looped:
    unroll_heads=True inlines that loop (dry-run accounting: XLA
    cost_analysis counts loop bodies once); False uses lax.map (memory-
    faithful).  head_blocks=0 defaults to one block per head.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    a = -jnp.exp(a_log.astype(jnp.float32))                   # (H,)
    hb = head_blocks if head_blocks > 0 else h
    hb = min(hb, h)
    while h % hb != 0:
        hb -= 1
    hs = h // hb                                              # vmapped width

    from ...distributed.sharding import constrain
    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, hb, hs, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, hb, hs)
    xf = constrain(xf, ("batch", None, None, None, "head_shard", None))
    dtf = constrain(dtf, ("batch", None, None, None, "head_shard"))
    bf = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    af = a.reshape(hb, hs)

    # vectorize the one-head body over the (sharded) inner head axis
    import functools
    one_head = functools.partial(_ssd_chunked_one_head,
                                 tile_dtype=tile_dtype)
    one_block = jax.vmap(one_head,
                         in_axes=(3, 3, 0, None, None), out_axes=3)
    # -> xh (B,nc,L,HS,P), dth (B,nc,L,HS), a (HS,) => y (B,nc,L,HS,P)

    if unroll_heads:
        ys = [one_block(xf[:, :, :, i], dtf[:, :, :, i], af[i], bf, cf)
              for i in range(hb)]
        y = jnp.stack(ys, axis=3)                       # (B,nc,L,HB,HS,P)
    else:
        xm = jnp.moveaxis(xf, 3, 0)                     # (HB,B,nc,L,HS,P)
        dtm = jnp.moveaxis(dtf, 3, 0)
        y = jax.lax.map(
            lambda args: one_block(args[0], args[1], args[2], bf, cf),
            (xm, dtm, af))                              # (HB,B,nc,L,HS,P)
        y = jnp.moveaxis(y, 0, 3)
    return y.reshape(bsz, s, h, p).astype(x.dtype)

"""Pure-jnp oracle for flash attention (naive materialized softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def attention(q, k, v, *, sm_scale: float, causal: bool = True,
              window: int = 0):
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D).  Exact reference."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)

    s_mat = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * sm_scale
    q_ids = jnp.arange(s)[:, None]
    k_ids = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask = mask & (k_ids <= q_ids)
    if window > 0:
        mask = mask & (k_ids >= q_ids - window)
    s_mat = jnp.where(mask[None, None], s_mat, -jnp.inf)
    p = jnp.exp(s_mat - jnp.max(s_mat, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

from .ops import flash_attention  # noqa: F401
from . import ref  # noqa: F401

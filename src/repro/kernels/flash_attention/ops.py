"""Public flash-attention op: kernel on TPU, interpret-mode kernel on CPU,
with an XLA fallback for shapes the kernel does not tile well."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel, ref


def flash_attention(q, k, v, *, sm_scale: Optional[float] = None,
                    causal: bool = True, window: int = 0,
                    block_q: int = kernel.DEFAULT_BLOCK_Q,
                    block_kv: int = kernel.DEFAULT_BLOCK_KV,
                    use_kernel: bool = True,
                    interpret: Optional[bool] = None):
    """Batched multi-head attention with GQA, causal & sliding-window.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D) -> (B, Hq, S, D).
    """
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    s = q.shape[2]
    if not use_kernel or s % 8 != 0:
        return ref.attention(q, k, v, sm_scale=sm_scale, causal=causal,
                             window=window)
    return kernel.mha(q, k, v, sm_scale=sm_scale, causal=causal,
                      window=window, block_q=block_q, block_kv=block_kv,
                      interpret=interpret)

"""Blocked flash attention, Pallas TPU.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); the kv axis is the
innermost, SEQUENTIAL grid dimension ("arbitrary" semantics on TPU), so the
online-softmax running state (m, l, o-accumulator) lives in VMEM scratch and
carries across kv steps.

BlockSpec tiling (VMEM working set per step, bf16, bq=bk=128, d<=256):
    q tile  (bq, d)    ~ 64 KB     k tile (bkv, d) ~ 64 KB
    v tile  (bkv, d)   ~ 64 KB     acc    (bq, d) f32 ~ 128 KB
well under the ~128 MB/core VMEM budget; scores (bq, bkv) stay in VREG/VMEM.

Masking supports causal and sliding-window (SWA: h2o-danube /
recurrentgemma local attention).  GQA head mapping happens via the k/v
index_map (no materialized kv broadcast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 sm_scale: float, causal: bool, window: int,
                 block_q: int, block_kv: int, num_kv_blocks: int,
                 seq_len: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_kv

    # Skip fully-masked blocks (causal: block strictly above the diagonal;
    # window: block strictly left of the oldest query row's window).
    relevant = jnp.bool_(True)
    if causal:
        relevant = jnp.logical_and(relevant, k_start <= q_start + block_q - 1)
    if window > 0:
        relevant = jnp.logical_and(
            relevant, k_start + block_kv - 1 >= q_start - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bkv)

        q_ids = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_ids = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = k_ids < seq_len                    # padded tail
        if causal:
            mask = jnp.logical_and(mask, k_ids <= q_ids)
        if window > 0:
            mask = jnp.logical_and(mask, k_ids >= q_ids - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                       # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "causal", "window", "block_q", "block_kv",
                     "interpret"))
def mha(q, k, v, *, sm_scale: float, causal: bool = True, window: int = 0,
        block_q: int = DEFAULT_BLOCK_Q, block_kv: int = DEFAULT_BLOCK_KV,
        interpret: bool = True):
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0.

    window > 0 keeps keys with q_pos - window <= k_pos (on top of causal).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    nq = pl.cdiv(s, block_q)
    nkv = pl.cdiv(s, block_kv)

    grid = (b, hq, nq, nkv)
    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda bi, hi, qi, kj: (bi, hi, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, block_kv, d),
                           lambda bi, hi, qi, kj: (bi, hi // group, kj, 0))
    o_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda bi, hi, qi, kj: (bi, hi, qi, 0))

    kernel = functools.partial(
        _attn_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, num_kv_blocks=nkv, seq_len=s)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),      # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)

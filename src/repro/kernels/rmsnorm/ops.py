"""Public RMSNorm op: flattens leading dims, dispatches kernel or oracle."""
from __future__ import annotations

from typing import Optional

import jax

from . import kernel, ref


def rmsnorm(x, w, *, eps: float = 1e-6, use_kernel: bool = True,
            interpret: Optional[bool] = None):
    """x: (..., D), w: (D,)."""
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    d = x.shape[-1]
    if not use_kernel or x.ndim < 2 or d % 8 != 0:
        return ref.rmsnorm(x, w, eps=eps)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, d)
    out = kernel.rmsnorm_2d(x2, w, eps=eps, interpret=interpret)
    return out.reshape(*lead, d)

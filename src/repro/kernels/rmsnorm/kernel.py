"""Fused RMSNorm * weight, Pallas TPU.

Row-blocked: grid over (rows / block_rows); each step normalizes a
(block_rows, d) tile fully resident in VMEM.  Fusing the reduction,
rsqrt and scale into one pass halves HBM traffic vs materializing the
normalized intermediate (the kernel-fusion win the paper prices with
tau_fusion in §IV-B)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_2d(x, w, *, eps: float = 1e-6,
               block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = True):
    """x: (R, D), w: (D,) -> (R, D)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    grid = (pl.cdiv(r, block_rows),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)

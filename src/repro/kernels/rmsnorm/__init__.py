from .ops import rmsnorm  # noqa: F401
from . import ref  # noqa: F401

"""Pure-jnp oracle for RMSNorm."""
import jax.numpy as jnp


def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jnp.reciprocal(jnp.sqrt(var + eps))
            * w.astype(jnp.float32)).astype(x.dtype)

"""JAX API compatibility shims shared by the Pallas TPU kernels.

The Pallas TPU compiler-params class was renamed across JAX releases:
older releases expose ``pltpu.TPUCompilerParams``, newer ones
``pltpu.CompilerParams``.  Kernels import ``CompilerParams`` from here so
they run on either API without per-kernel version checks.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

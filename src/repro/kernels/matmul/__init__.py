from .ops import matmul  # noqa: F401
from . import ref  # noqa: F401

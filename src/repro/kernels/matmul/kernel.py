"""Tiled MXU matmul, Pallas TPU — the tensor-throughput microbenchmark
kernel (paper §V-A(iv)) adapted from CUDA CTA tiles to MXU BlockSpecs.

Grid (M/bm, N/bn, K/bk); K is the innermost sequential axis; a float32
accumulator tile (bm, bn) lives in VMEM scratch across K steps (the TPU
analogue of TMEM-resident accumulators — paper Eq. 2's D_accum).

VMEM working set per step: A (bm, bk) + B (bk, bn) + acc (bm, bn) f32.
bm=bn=256, bk=512 bf16 => 0.25 + 0.25 + 0.25 MB — MXU-aligned multiples
of 128 (the model's mxu_utilization term rewards this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, num_k: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kj == num_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "out_dtype"))
def matmul_tiled(a, b, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 bk: int = DEFAULT_BK, interpret: bool = True,
                 out_dtype=None):
    """a: (M, K) @ b: (K, N) -> (M, N), tiled with fp32 accumulation."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    out_dtype = out_dtype or a.dtype

    return pl.pallas_call(
        functools.partial(_matmul_kernel, num_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)

"""Pure-jnp oracle for the tiled matmul."""
import jax.numpy as jnp


def matmul(a, b, out_dtype=None):
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)

"""Public matmul op with block-size selection hooks.

``predict_block_time`` prices a candidate (bm, bn, bk) with the core
analytical model — the paper's adaptive tile selection (§IV-B) applied to
BlockSpec shapes."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from . import kernel, ref


def matmul(a, b, *, bm: int = kernel.DEFAULT_BM, bn: int = kernel.DEFAULT_BN,
           bk: int = kernel.DEFAULT_BK, use_kernel: bool = True,
           interpret: Optional[bool] = None, out_dtype=None):
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"
    m, k = a.shape
    n = b.shape[1]
    if not use_kernel or min(m, n, k) < 8:
        return ref.matmul(a, b, out_dtype=out_dtype)
    return kernel.matmul_tiled(a, b, bm=bm, bn=bn, bk=bk,
                               interpret=interpret, out_dtype=out_dtype)


def predict_block_time(m: int, n: int, k: int,
                       blocks: Tuple[int, int, int],
                       precision: str = "bf16") -> float:
    """Analytical step-time for one (bm,bn,bk) BlockSpec on TPU v5e:
    Blackwell-style stage model re-derived for the MXU (DESIGN.md §3).

    Per grid step: T = max(T_mxu, (1-alpha) T_dma) + T_sync, where the
    working set (A tile + B tile + f32 acc) must fit VMEM (else spill
    penalty) and MXU utilization degrades for dims < 512 (pipeline
    fill of the 128x128 systolic array).
    """
    from repro.core import hardware
    from repro.core.hardware import BYTES_PER_ELEM
    hw = hardware.TPU_V5E
    bm, bn, bk = blocks
    eb = BYTES_PER_ELEM[precision]
    steps = -(-m // bm) * -(-n // bn) * -(-k // bk)

    mxu_util = 1.0
    for d in (bm, bn, bk):
        if d % 128 != 0:
            mxu_util *= d / (128 * -(-d // 128))
        if d < 512:
            mxu_util *= 0.85 + 0.15 * d / 512     # systolic fill fraction
    t_mxu = 2.0 * bm * bn * bk / (
        hw.sustained_flops(precision, matrix=True) * mxu_util)

    tile_bytes = (bm * bk + bk * bn) * eb
    working_set = tile_bytes * 2 + bm * bn * 4    # dbl-buffered + f32 acc
    t_dma = tile_bytes / hw.hbm_sustained_bw
    spill = 2.0 if working_set > hw.accum_capacity_bytes else 1.0
    t_sync = hw.cycles_to_seconds(hw.mbarrier_latency_cycles)
    t_step = max(t_mxu * spill,
                 (1 - hw.pipeline_overlap_alpha) * t_dma) + t_sync
    t_store = m * n * eb / hw.hbm_sustained_bw
    return hw.launch_latency_s + steps * t_step + t_store


def select_blocks(m: int, n: int, k: int, *,
                  candidates=((128, 128, 128), (256, 256, 256),
                              (256, 256, 512), (512, 512, 256)),
                  precision: str = "bf16"):
    """Model-driven argmin over BlockSpec candidates (paper's tile
    selection on TPU)."""
    costs = {c: predict_block_time(m, n, k, c, precision) for c in candidates}
    best = min(costs, key=costs.get)
    return best, costs

"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel subpackage ships three layers:
  * kernel.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
                (TPU is the TARGET; validated on CPU via interpret=True)
  * ops.py    — jit'd public wrapper (shape plumbing, GQA mapping, dtypes)
  * ref.py    — pure-jnp oracle for allclose validation

Kernels:
  flash_attention — row/column-blocked attention with online softmax,
                    causal + sliding-window masking, GQA
  ssd             — Mamba2 state-space-dual chunked scan
  rmsnorm         — fused RMSNorm * weight
  matmul          — tiled MXU matmul (the microbenchmark kernel: its block
                    sweep feeds the analytical model's tile-selection demo)

The paper's own hot-spots are GEMM/attention-class kernels (its validation
classes); ``matmul`` doubles as the tensor-throughput microbenchmark from
§V-A, adapted from CUDA tiles to MXU-aligned BlockSpecs.
"""
from . import flash_attention, matmul, rmsnorm, ssd  # noqa: F401

"""Stage-centric analytical model for NVIDIA Blackwell (paper §IV-A).

Execution time is assembled from explicitly measurable pipeline stages
(TMA -> TMEM -> TensorCore -> Sync), per paper Fig. 3:

    T_step   = max(T_compute, T_io_eff) + T_sync + O_misc          (Eq. 8)
    T_kernel = T_launch + waves * K_tiles * T_step + T_writeback

with
    T_compute       = 2 bM bN bK / (R_TC^SM * S_mode)
                      + T_TMEM + T_TMEM_mgmt                        (Eq. 3/6)
    T_TMEM_per_tile = D_accum/BW_read + L_mma + D_accum/BW_write    (Eq. 2)
    T_tma           = L_TMA + bytes(T) / (P * B_TMA)                (Eq. 4)
    T_DE_load       = D_unc / (CR * BW_link * eta_DE)               (Eq. 5)
    T_io_eff        = (1-alpha)(T_tma + T_decomp) + T_sync          (Eq. 7)
    T_sync          = N_bar * L_mbar

Interpretive choices (the paper's prose is the spec; these are documented
deviations/disambiguations):
  * Eq. 2's accumulator traffic is paid once per OUTPUT TILE (accumulators
    stay TMEM-resident across K-steps) and amortized over K_tiles, matching
    the text "TMEM (256 KB/SM) holds accumulators" and the measured 22 TB/s
    epilogue bandwidth note in §V-B.
  * B_TMA is a chip-level effective bandwidth; each concurrently resident
    CTA gets an equal share (persistent-kernel execution, one CTA/SM).
  * Exceeding TMEM capacity (bM*bN*4B > 256 KB) forces spill: modeled as a
    2x penalty on the TMEM term plus per-step (not amortized) payment.
  * Non-GEMM workloads route through the memory/vector stage directly
    (the paper routes them to the generic path; ``predict`` handles both so
    the stage model is total).
"""
from __future__ import annotations

import math
from typing import List, Sequence

import numpy as np


from .cache import working_set_blend, working_set_blend_batch
from .hardware import BYTES_PER_ELEM, HardwareParams
from .workload import Row, TimeBreakdown, TileConfig, Workload, tb_from_row

ACCUM_BYTES = 4.0  # FP32 accumulators in TMEM


def tmem_time_per_tile(tile: TileConfig, hw: HardwareParams) -> float:
    """Eq. 2: T_TMEM = D/BW_read + L_mma + D/BW_write, per output tile.

    Bandwidths in the parameter file are chip-level; an SM's share is
    BW/num_sms (one resident CTA per SM in persistent kernels).
    """
    d_accum = tile.accum_bytes(ACCUM_BYTES)
    bw_r = hw.accum_read_bw / hw.num_sms
    bw_w = hw.accum_write_bw / hw.num_sms
    t = d_accum / bw_r + hw.cycles_to_seconds(hw.mma_latency_cycles) \
        + d_accum / bw_w
    if d_accum > hw.accum_capacity_bytes:
        t *= 2.0  # spill penalty: "Exceeding 256 KB forces spill"
    return t


def tmem_mgmt_amortized(k_tiles: int, hw: HardwareParams) -> float:
    """T_TMEM_mgmt = (L_alloc + L_dealloc) / K_tiles (paper §IV-A5)."""
    return hw.tmem_alloc_latency_s / max(k_tiles, 1)


def compute_time_per_step(w: Workload, hw: HardwareParams, *,
                          two_sm: bool = False,
                          sustained: bool = True) -> float:
    """Eq. 3 / Eq. 6: per-K-step tensor-core compute time."""
    tile = w.tile or TileConfig()
    flops = tile.flops_per_tile_step
    rate = (hw.sustained_flops(w.precision, matrix=True) if sustained
            else hw.peak_flops(w.precision, matrix=True))
    r_sm = rate / hw.num_sms
    s_mode = hw.two_sm_speedup if two_sm else 1.0
    eff = hw.precision_efficiency.get(w.precision, 1.0)
    t_mma = flops / (r_sm * s_mode * eff)
    k_tiles = max(w.k_tiles, 1)
    spill = tile.accum_bytes(ACCUM_BYTES) > hw.accum_capacity_bytes
    t_tmem_tile = tmem_time_per_tile(tile, hw)
    # resident accumulators amortize; spilled ones pay per step
    t_tmem = t_tmem_tile if spill else t_tmem_tile / k_tiles
    return t_mma + t_tmem + tmem_mgmt_amortized(k_tiles, hw)


def tma_time_per_step(w: Workload, hw: HardwareParams, *,
                      two_sm: bool = False) -> float:
    """Eq. 4: T_tma = L_TMA + bytes / (P * B_TMA-per-CTA-share).

    2-SM CTA pairs share the B operand via DSMEM: traffic 2M_A + M_B
    instead of 2(M_A + M_B) (paper §IV-A4, ~1.33x reduction for square
    tiles).
    """
    tile = w.tile or TileConfig()
    in_b = BYTES_PER_ELEM[w.precision]
    m_a = tile.bm * tile.bk * in_b
    m_b = tile.bk * tile.bn * in_b
    if two_sm:
        bytes_step = (2 * m_a + m_b) / 2.0  # per CTA of the pair
    else:
        bytes_step = m_a + m_b
    if w.bytes_per_cta > 0 and not two_sm:
        bytes_step = w.bytes_per_cta
    active_ctas = max(1, min(w.num_ctas or hw.num_sms, hw.num_sms))
    # L2-residency-aware effective TMA bandwidth (Eq. 16 blend; §IV-A2
    # "L2 hit rates strongly affect B_TMA")
    b_tma = working_set_blend(
        w.working_set_bytes, hw,
        peak=hw.tma_bandwidth * 1.35, sustained=hw.tma_bandwidth)
    per_cta_bw = b_tma / active_ctas
    p = max(1, w.tma_participants)
    return hw.cycles_to_seconds(hw.tma_latency_cycles) \
        + bytes_step / (p * per_cta_bw)


def decompression_time(w: Workload, hw: HardwareParams) -> float:
    """Eq. 5: T_DE_load = D_unc / (CR * BW_link * eta_DE)."""
    if w.compressed_bytes <= 0:
        return 0.0
    d_unc = w.compressed_bytes * w.compression_ratio
    link = max(
        min(hw.hbm_sustained_bw, hw.decomp_engine_rate or math.inf), 1.0)
    return d_unc / (w.compression_ratio * link * hw.decomp_efficiency)


def sync_time(hw: HardwareParams, n_bar: int = 1) -> float:
    """T_sync = N_bar * L_mbar (N_bar typically 1-2)."""
    return n_bar * hw.cycles_to_seconds(hw.mbarrier_latency_cycles)


def _tiled_gemm_predict(w: Workload, hw: HardwareParams, *,
                        two_sm: bool, n_bar: int) -> TimeBreakdown:
    k_tiles = max(w.k_tiles, 1)
    t_comp = compute_time_per_step(w, hw, two_sm=two_sm)
    t_tma = tma_time_per_step(w, hw, two_sm=two_sm)
    t_dec = decompression_time(w, hw) / max(w.num_ctas * k_tiles, 1)
    t_sync = sync_time(hw, n_bar)
    alpha = hw.pipeline_overlap_alpha
    t_io_eff = (1.0 - alpha) * (t_tma + t_dec) + t_sync          # Eq. 7
    # O_misc: TMEM mgmt is already inside T_compute (Eq. 3); adding it again
    # here would double-count (paper lists it in both Eq. 3 and Eq. 8 —
    # disambiguated to Eq. 3 only).
    o_misc = 0.0
    t_step = max(t_comp, t_io_eff) + t_sync + o_misc             # Eq. 8

    num_ctas = max(w.num_ctas, 1)
    if two_sm:
        num_ctas = max(1, num_ctas)  # pairs co-scheduled on adjacent SMs
    # fractional waves: persistent-kernel execution keeps all SMs busy until
    # the tail; grids smaller than the SM count still occupy one wave.
    waves = max(1.0, num_ctas / hw.num_sms)
    # first wave pays the un-overlapped TMA prologue (pipeline fill)
    t_fill = t_tma + t_dec
    t_body = waves * k_tiles * t_step

    # writeback: C tile via TMA store, overlapped in persistent kernels
    out_bytes = 0.0
    if w.gemm is not None:
        out_bytes = w.gemm.m * w.gemm.n * BYTES_PER_ELEM[w.precision]
    t_store = (1.0 - alpha) * out_bytes / hw.hbm_sustained_bw

    total = hw.launch_latency_s + t_fill + t_body + t_store
    total += (w.concurrent_kernels - 1) * hw.tau_interference_s   # §IV-A6
    total += (w.num_devices - 1) * hw.tau_interference_gpu_s
    return TimeBreakdown(
        total=total,
        compute=waves * k_tiles * t_comp,
        memory=waves * k_tiles * t_tma,
        io_effective=waves * k_tiles * t_io_eff,
        sync=waves * k_tiles * t_sync,
        launch=hw.launch_latency_s,
        writeback=t_store,
        detail={
            "t_step": t_step, "t_compute_step": t_comp,
            "t_tma_step": t_tma, "t_sync_step": t_sync,
            "waves": waves, "k_tiles": float(k_tiles),
            "pipeline_fill": t_fill,
        },
    )


def _streaming_predict(w: Workload, hw: HardwareParams) -> TimeBreakdown:
    """Memory/balanced/stencil kernels: sustained-bandwidth stage with the
    Eq. 16 working-set blend, vector-path compute, launch overhead.

    This is the Blackwell instantiation of the paper's generic path
    (§IV-F); vector ops land within 7-9% per §V-B(c) because of L2 benefit
    and 5-12us launch overhead, both modeled here.
    """
    bw = working_set_blend(w.working_set_bytes or w.bytes, hw)
    t_mem = w.bytes / bw
    rate = hw.sustained_flops(w.precision, matrix=w.matrix)
    t_comp = w.flops / rate if w.flops > 0 else 0.0
    if w.irregular:
        # Obs. 2: pointer-chasing violates regular-access assumptions;
        # bandwidth degrades to latency-bound. Model as 4x bandwidth loss.
        t_mem *= 4.0
    t_sync = sync_time(hw, 1)
    total = hw.launch_latency_s + max(t_comp, t_mem) + t_sync
    total += (w.concurrent_kernels - 1) * hw.tau_interference_s
    total += (w.num_devices - 1) * hw.tau_interference_gpu_s
    return TimeBreakdown(total=total, compute=t_comp, memory=t_mem,
                         io_effective=t_mem, sync=t_sync,
                         launch=hw.launch_latency_s,
                         detail={"bw_eff": bw})


def predict(w: Workload, hw: HardwareParams, *,
            two_sm: bool = False, n_bar: int = 1) -> TimeBreakdown:
    """Stage-centric Blackwell prediction (paper §IV-A).

    Tiled-GEMM workloads (w.tile/w.gemm set) take the full TMA->TMEM->TC
    pipeline; everything else takes the bandwidth stage.
    """
    if hw.model_family not in ("blackwell", "tpu"):
        raise ValueError(f"blackwell model mis-routed to {hw.name}")
    if w.gemm is not None or (w.tile is not None and w.k_tiles > 0):
        return _tiled_gemm_predict(w, hw, two_sm=two_sm, n_bar=n_bar)
    return _streaming_predict(w, hw)


# ---------------------------------------------------------------------------
# Columnar (NumPy-vectorized) stage model — the WorkloadTable / SweepEngine
# hot path.  Bit-identical to the scalar functions above: every elementwise
# expression mirrors the scalar operation order, and transcendentals ride the
# libm-exact helpers in core.cache.
# ---------------------------------------------------------------------------

def _tiled_gemm_cols(table, hw: HardwareParams):
    from .workload import NV_BM, NV_BN, NV_BK, NV_K_TILES, NV_NUM_CTAS, \
        NV_WS, NV_BYTES_PER_CTA, NV_TMA_P, NV_COMP_BYTES, NV_COMP_RATIO, \
        NV_CONCURRENT, NV_DEVICES, NV_GMN, TableCols
    raw = table.cols
    bm, bn, bk = raw[:, NV_BM], raw[:, NV_BN], raw[:, NV_BK]
    k_tiles = np.maximum(raw[:, NV_K_TILES].astype(np.int64), 1)
    num_ctas = raw[:, NV_NUM_CTAS].astype(np.int64)
    wsb = raw[:, NV_WS]

    # compute_time_per_step (Eq. 3/6), two_sm=False, sustained=True
    flops = 2.0 * bm * bn * bk
    rate = table.per_precision(
        lambda p: hw.sustained_flops(p, matrix=True))
    eff = table.per_precision(
        lambda p: hw.precision_efficiency.get(p, 1.0))
    in_b = table.per_precision(lambda p: BYTES_PER_ELEM[p])
    r_sm = rate / hw.num_sms
    t_mma = flops / (r_sm * 1.0 * eff)
    d_accum = bm * bn * ACCUM_BYTES
    spill = d_accum > hw.accum_capacity_bytes
    bw_r = hw.accum_read_bw / hw.num_sms
    bw_w = hw.accum_write_bw / hw.num_sms
    t_tile = d_accum / bw_r + hw.cycles_to_seconds(hw.mma_latency_cycles) \
        + d_accum / bw_w
    t_tile = np.where(spill, t_tile * 2.0, t_tile)
    t_tmem = np.where(spill, t_tile, t_tile / k_tiles)
    t_comp = t_mma + t_tmem + hw.tmem_alloc_latency_s / k_tiles

    # tma_time_per_step (Eq. 4)
    m_a = bm * bk * in_b
    m_b = bk * bn * in_b
    bytes_step = m_a + m_b
    bpc = raw[:, NV_BYTES_PER_CTA]
    bytes_step = np.where(bpc > 0, bpc, bytes_step)
    active = np.maximum(
        1, np.minimum(np.where(num_ctas != 0, num_ctas, hw.num_sms),
                      hw.num_sms))
    b_tma = working_set_blend_batch(
        wsb, hw, peak=hw.tma_bandwidth * 1.35, sustained=hw.tma_bandwidth)
    per_cta_bw = b_tma / active
    p = np.maximum(1.0, raw[:, NV_TMA_P])
    t_tma = hw.cycles_to_seconds(hw.tma_latency_cycles) \
        + bytes_step / (p * per_cta_bw)

    # decompression (Eq. 5)
    comp_b = raw[:, NV_COMP_BYTES]
    if comp_b.any():
        comp_r = raw[:, NV_COMP_RATIO]
        link = max(
            min(hw.hbm_sustained_bw, hw.decomp_engine_rate or math.inf), 1.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            d_unc = comp_b * comp_r
            t_de = np.where(
                comp_b > 0,
                d_unc / (comp_r * link * hw.decomp_efficiency), 0.0)
        t_dec = t_de / np.maximum(num_ctas * k_tiles, 1)
    else:
        t_dec = 0.0  # scalar path yields exactly 0.0 here

    t_sync = sync_time(hw, 1)
    alpha = hw.pipeline_overlap_alpha
    t_io_eff = (1.0 - alpha) * (t_tma + t_dec) + t_sync           # Eq. 7
    t_step = np.maximum(t_comp, t_io_eff) + t_sync + 0.0          # Eq. 8

    waves = np.maximum(1.0, np.maximum(num_ctas, 1) / hw.num_sms)
    t_fill = t_tma + t_dec
    t_body = waves * k_tiles * t_step

    t_store = (1.0 - alpha) * (raw[:, NV_GMN] * in_b) / hw.hbm_sustained_bw

    total = hw.launch_latency_s + t_fill + t_body + t_store
    total = total + (raw[:, NV_CONCURRENT] - 1) * hw.tau_interference_s
    total = total + (raw[:, NV_DEVICES] - 1) * hw.tau_interference_gpu_s

    return TableCols(
        len(table),
        (total, waves * k_tiles * t_comp, waves * k_tiles * t_tma,
         waves * k_tiles * t_io_eff, waves * k_tiles * t_sync,
         hw.launch_latency_s, t_store, 0.0, 0.0),
        ("t_step", "t_compute_step", "t_tma_step", "t_sync_step",
         "waves", "k_tiles", "pipeline_fill"),
        (t_step, t_comp, t_tma, t_sync, waves,
         k_tiles.astype(np.float64), t_fill))


def _streaming_cols(table, hw: HardwareParams):
    from .workload import NV_BYTES, NV_WS_OR_BYTES, NV_FLOPS, \
        NV_IRREGULAR, NV_CONCURRENT, NV_DEVICES, TableCols
    raw = table.cols
    nbytes, wsb, flops = raw[:, NV_BYTES], raw[:, NV_WS_OR_BYTES], \
        raw[:, NV_FLOPS]
    bw = working_set_blend_batch(wsb, hw)
    t_mem = nbytes / bw
    rate = table.per_precision_matrix(
        lambda p, m: hw.sustained_flops(p, matrix=m))
    with np.errstate(divide="ignore", invalid="ignore"):
        t_comp = np.where(flops > 0, flops / rate, 0.0)
    t_mem = np.where(raw[:, NV_IRREGULAR] != 0, t_mem * 4.0, t_mem)
    t_sync = sync_time(hw, 1)
    total = hw.launch_latency_s + np.maximum(t_comp, t_mem) + t_sync
    total = total + (raw[:, NV_CONCURRENT] - 1) * hw.tau_interference_s
    total = total + (raw[:, NV_DEVICES] - 1) * hw.tau_interference_gpu_s

    return TableCols(
        len(table),
        (total, t_comp, t_mem, t_mem, t_sync, hw.launch_latency_s,
         0.0, 0.0, 0.0),
        ("bw_eff",), (bw,))


def predict_table_cols(table, hw: HardwareParams):
    """Columnar ``predict`` over a WorkloadTable (defaults two_sm=False,
    n_bar=1).  Bit-identical per row to scalar ``predict``."""
    from .workload import NV_HAS_GEMM, NV_HAS_TILE, NV_K_TILES, SegmentedCols
    if hw.model_family not in ("blackwell", "tpu"):
        raise ValueError(f"blackwell model mis-routed to {hw.name}")
    raw = table.cols
    tiled = (raw[:, NV_HAS_GEMM] != 0) | \
        ((raw[:, NV_HAS_TILE] != 0) & (raw[:, NV_K_TILES] > 0))
    if tiled.all():
        return _tiled_gemm_cols(table, hw)
    if not tiled.any():
        return _streaming_cols(table, hw)
    idx_t = np.flatnonzero(tiled)
    idx_s = np.flatnonzero(~tiled)
    return SegmentedCols(len(table), [
        (idx_t, _tiled_gemm_cols(table.take(idx_t), hw)),
        (idx_s, _streaming_cols(table.take(idx_s), hw))])


def predict_rows(ws: Sequence[Workload], hw: HardwareParams) -> List[Row]:
    """Vectorized ``predict`` over a workload batch, in row form (defaults
    two_sm=False, n_bar=1).  Bit-identical to per-workload ``predict``."""
    from .workload import WorkloadTable
    return predict_table_cols(WorkloadTable.from_workloads(ws), hw).rows()


def predict_batch(ws: Sequence[Workload],
                  hw: HardwareParams) -> List[TimeBreakdown]:
    """Materialized form of ``predict_rows``."""
    return [tb_from_row(r) for r in predict_rows(ws, hw)]


def two_sm_traffic_reduction(tile: TileConfig) -> float:
    """§IV-A4: D_2CTA = 2M_A + M_B vs 2(M_A + M_B); ~1.33x for square."""
    m_a = tile.bm * tile.bk
    m_b = tile.bk * tile.bn
    return 2.0 * (m_a + m_b) / (2.0 * m_a + m_b)


def two_sm_speedup(w: Workload, hw: HardwareParams) -> float:
    """Predicted end-to-end speedup of CTA-pair execution on a
    memory(TMA)-bound kernel (§V-B(c): predicted 1.30x vs measured 1.28x).

    The prediction comes from the §IV-A4 traffic argument: the pair shares B
    via DSMEM, cutting operand traffic by 2(M_A+M_B)/(2M_A+M_B) (~1.33x for
    square tiles), degraded by the per-K-step commit barrier the pair adds
    (K_tiles * L_commit, pipelined so only the (1-alpha) fraction is
    exposed):
        S_2SM = traffic_reduction * T_step / (T_step + (1-alpha) L_commit)
    """
    tile = w.tile or TileConfig()
    reduction = two_sm_traffic_reduction(tile)
    t_step = predict(w, hw, two_sm=False).detail["t_step"]
    l_commit = hw.cycles_to_seconds(hw.commit_latency_cycles)
    exposed = (1.0 - hw.pipeline_overlap_alpha) * l_commit
    return reduction * t_step / (t_step + exposed)

"""Cache / working-set models.

Implements:
  * paper Table III — MI300A Infinity Cache hit-rate model h_LLC(W),
  * BW_effective = h_LLC * BW_LLC + (1 - h_LLC) * BW_HBM,
  * paper Eq. 16  — working-set-aware bandwidth blend
        B_eff(W) = B_sustained + (B_peak - B_sustained) * exp(-W / w0),
  * paper Eq. 10  — expected-latency hierarchy walk.

Each model also has a ``*_batch`` variant operating on NumPy arrays of
working-set sizes (the SweepEngine hot path).  Batch variants are
bit-identical to the scalar ones: elementwise arithmetic follows the same
operation order, and the transcendentals go through ``vexp``/``vpow`` —
per-element ``math.exp``/``pow`` — because NumPy's SIMD ``np.exp`` /
``np.power`` differ from libm in the last ulp on some platforms.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

from .hardware import HardwareParams


def vexp(x: np.ndarray) -> np.ndarray:
    """Elementwise exp, bit-identical to scalar ``math.exp``.

    Sweeps typically share few distinct working-set sizes (a tile sweep
    varies tiles, not operands), so evaluate on the unique values when that
    pays for the sort.
    """
    x = np.asarray(x, dtype=np.float64)
    if x.size > 64:
        uniq, inv = np.unique(x, return_inverse=True)
        if uniq.size * 2 <= x.size:
            vals = np.fromiter((math.exp(v) for v in uniq),
                               np.float64, uniq.size)
            return vals[inv].reshape(x.shape)
    return np.fromiter((math.exp(v) for v in x.ravel()),
                       np.float64, x.size).reshape(x.shape)


def vpow(base: np.ndarray, exponent: float) -> np.ndarray:
    """Elementwise power, bit-identical to scalar ``float ** float``."""
    base = np.asarray(base, dtype=np.float64)
    return np.fromiter((v ** exponent for v in base.ravel()),
                       np.float64, base.size).reshape(base.shape)


def llc_hit_rate(working_set_bytes: float, hw: HardwareParams) -> float:
    """Piecewise h_LLC(W) per paper Table III.

    W < resident          -> 1.0                     (fully cache-resident)
    resident <= W <= cap  -> (1 - (W-res)/(cap-res))^alpha   (transition)
    W > cap               -> (cap / W)^beta          (streaming / spill)
    """
    w_mb = working_set_bytes / 1e6
    res = hw.llc_resident_mb
    cap = hw.llc_capacity_mb
    if cap <= 0:
        return 0.0
    if w_mb < res:
        return 1.0
    if w_mb <= cap:
        frac = 1.0 - (w_mb - res) / max(cap - res, 1e-9)
        return max(0.0, frac) ** hw.llc_transition_alpha
    return (cap / w_mb) ** hw.llc_transition_beta


def effective_bandwidth_llc(working_set_bytes: float,
                            hw: HardwareParams,
                            h_llc: Optional[float] = None) -> float:
    """BW_effective = h_LLC * BW_LLC + (1 - h_LLC) * BW_HBM (paper §IV-B)."""
    if not hw.cache_levels:
        return hw.hbm_sustained_bw
    llc = hw.cache_levels[-1]
    h = llc_hit_rate(working_set_bytes, hw) if h_llc is None else h_llc
    return h * llc.bandwidth + (1.0 - h) * hw.hbm_sustained_bw


def working_set_blend(working_set_bytes: float, hw: HardwareParams,
                      *, peak: Optional[float] = None,
                      sustained: Optional[float] = None) -> float:
    """Paper Eq. 16: B_eff(W) = B_sus + (B_peak - B_sus) exp(-W/w0).

    Captures that small resident working sets see higher effective bandwidth
    than HBM-saturating streams.  w0 <= 0 disables the blend (returns
    sustained).
    """
    b_peak = hw.hbm_peak_bw if peak is None else peak
    b_sus = hw.hbm_sustained_bw if sustained is None else sustained
    w0 = hw.working_set_scale_bytes
    if w0 <= 0:
        return b_sus
    return b_sus + (b_peak - b_sus) * math.exp(-working_set_bytes / w0)


def llc_hit_rate_batch(working_set_bytes: np.ndarray,
                       hw: HardwareParams) -> np.ndarray:
    """Vectorized ``llc_hit_rate`` (bit-identical per element)."""
    w_mb = np.asarray(working_set_bytes, dtype=np.float64) / 1e6
    res = hw.llc_resident_mb
    cap = hw.llc_capacity_mb
    out = np.zeros_like(w_mb)
    if cap <= 0:
        return out
    out[w_mb < res] = 1.0
    mid = (w_mb >= res) & (w_mb <= cap)
    if mid.any():
        frac = 1.0 - (w_mb[mid] - res) / max(cap - res, 1e-9)
        out[mid] = vpow(np.maximum(0.0, frac), hw.llc_transition_alpha)
    hi = w_mb > cap
    if hi.any():
        out[hi] = vpow(cap / w_mb[hi], hw.llc_transition_beta)
    return out


def effective_bandwidth_llc_batch(working_set_bytes: np.ndarray,
                                  hw: HardwareParams) -> np.ndarray:
    """Vectorized ``effective_bandwidth_llc`` (no per-workload h override —
    callers with explicit hit rates take the scalar path)."""
    ws = np.asarray(working_set_bytes, dtype=np.float64)
    if not hw.cache_levels:
        return np.full(ws.shape, hw.hbm_sustained_bw)
    llc = hw.cache_levels[-1]
    h = llc_hit_rate_batch(ws, hw)
    return h * llc.bandwidth + (1.0 - h) * hw.hbm_sustained_bw


def working_set_blend_batch(working_set_bytes: np.ndarray,
                            hw: HardwareParams, *,
                            peak: Optional[float] = None,
                            sustained: Optional[float] = None) -> np.ndarray:
    """Vectorized ``working_set_blend`` (bit-identical per element)."""
    ws = np.asarray(working_set_bytes, dtype=np.float64)
    b_peak = hw.hbm_peak_bw if peak is None else peak
    b_sus = hw.hbm_sustained_bw if sustained is None else sustained
    w0 = hw.working_set_scale_bytes
    if w0 <= 0:
        return np.full(ws.shape, b_sus)
    return b_sus + (b_peak - b_sus) * vexp(-ws / w0)


def hierarchy_latency_walk(num_loads: float,
                           hit_rates: Dict[str, float],
                           hw: HardwareParams) -> float:
    """Paper Eq. 10 expected-latency memory time (seconds).

    T = N_loads * ( h_L1*L_L1 + (1-h_L1)h_L2*L_L2
                   + (1-h_L1)(1-h_L2)h_LLC*L_LLC + (1-h_total)*L_HBM )

    Hit rates outside [0,1] are rejected.  Missing levels contribute nothing.
    """
    for k, v in hit_rates.items():
        if not (0.0 <= v <= 1.0):
            raise ValueError(f"hit rate {k}={v} outside [0, 1]")
    levels = {c.name: c for c in hw.cache_levels}
    h1 = hit_rates.get("l1", 0.0)
    h2 = hit_rates.get("l2", 0.0)
    hllc = hit_rates.get("llc", 0.0)

    expected_cycles = 0.0
    miss = 1.0
    if "l1" in levels:
        expected_cycles += h1 * levels["l1"].latency_cycles
        miss = (1.0 - h1)
    if "l2" in levels:
        expected_cycles += miss * h2 * levels["l2"].latency_cycles
        miss = miss * (1.0 - h2)
    if "llc" in levels:
        expected_cycles += miss * hllc * levels["llc"].latency_cycles
        miss = miss * (1.0 - hllc)
    expected_cycles += miss * hw.hbm_latency_cycles
    return num_loads * hw.cycles_to_seconds(expected_cycles)

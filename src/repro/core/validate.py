"""Validation harness: MAE / IQR statistics and model-vs-roofline comparison
(paper §V).

Protocol (paper §V-B): each kernel runs 100 times after 10 warm-ups; median
execution time is the measurement; MAE is the mean of per-kernel absolute
percent errors.  All reported MAE values use the base model (MWP=CWP=0).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .hardware import HardwareParams
from .workload import TimeBreakdown, Workload


def pct_error(predicted: float, measured: float) -> float:
    return abs(predicted - measured) / max(abs(measured), 1e-30) * 100.0


def mae_percent(predicted: Sequence[float],
                measured: Sequence[float]) -> float:
    if not predicted:
        return 0.0
    errs = [pct_error(p, m) for p, m in zip(predicted, measured)]
    return sum(errs) / len(errs)


def iqr(values: Sequence[float]) -> float:
    s = sorted(values)
    n = len(s)
    if n < 4:
        return 0.0

    def q(p: float) -> float:
        pos = p * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        return s[lo] + (s[hi] - s[lo]) * (pos - lo)

    return q(0.75) - q(0.25)


@dataclass
class ValidationRow:
    name: str
    wclass: str
    measured_s: float
    model_s: float
    roofline_s: float

    @property
    def model_err(self) -> float:
        return pct_error(self.model_s, self.measured_s)

    @property
    def roofline_err(self) -> float:
        return pct_error(self.roofline_s, self.measured_s)


@dataclass
class ValidationReport:
    """Table-VI-shaped result: model MAE vs naive-roofline error."""

    platform: str
    rows: List[ValidationRow] = field(default_factory=list)

    @property
    def n(self) -> int:
        return len(self.rows)

    @property
    def model_mae(self) -> float:
        return mae_percent([r.model_s for r in self.rows],
                           [r.measured_s for r in self.rows])

    @property
    def roofline_mae(self) -> float:
        return mae_percent([r.roofline_s for r in self.rows],
                           [r.measured_s for r in self.rows])

    def per_class_mae(self) -> Dict[str, float]:
        by: Dict[str, List[ValidationRow]] = {}
        for r in self.rows:
            by.setdefault(r.wclass, []).append(r)
        return {cls: mae_percent([r.model_s for r in rs],
                                 [r.measured_s for r in rs])
                for cls, rs in by.items()}

    def summary(self) -> Dict[str, float]:
        return {"n": float(self.n), "model_mae": self.model_mae,
                "roofline_mae": self.roofline_mae}


def validate_suite(platform_hw: HardwareParams,
                   workloads: Sequence[Workload],
                   measured: Sequence[float], *,
                   calibration=None,
                   model: Optional[str] = None,
                   chunk_size: Optional[int] = None,
                   jobs=None) -> ValidationReport:
    """Run model + naive roofline over a suite with known measured times.

    The suite is lifted into one columnar ``WorkloadTable`` and priced
    through the shared SweepEngine's table path — one column query per
    route, memoized whole, so repeated validation of the same suite is a
    single content-token hit per route.

    ``chunk_size``/``jobs`` switch pricing to the streaming/sharded
    executor (``core.sweep.predict_totals_stream``): peak memory bounded
    by chunk, throughput scaled across workers (0/"auto" =
    ``os.cpu_count()``) — identical totals either way, so arbitrarily
    large suites validate without materializing result columns.
    """
    from . import sweep
    from .workload import WorkloadTable
    assert len(workloads) == len(measured)
    table = WorkloadTable.from_workloads(workloads)
    if chunk_size is None and jobs is None:
        t_models = sweep.predict_table(
            table, platform_hw, model=model, calibration=calibration).totals
        t_roofs = sweep.predict_table(table, platform_hw,
                                      model="roofline").totals
    elif sweep.effective_jobs(jobs) > 1:
        # one pool + one shared-memory export prices both routes per shard
        from . import parallel
        (m_red,), (r_red,) = parallel.reduce_sharded_multi(
            table, platform_hw,
            [((sweep.TotalsStream,), model, calibration),
             ((sweep.TotalsStream,), "roofline", None)],
            jobs=jobs, chunk_size=chunk_size)
        t_models = m_red.result()
        t_roofs = r_red.result()
    else:
        t_models = sweep.predict_totals_stream(
            table, platform_hw, model=model, calibration=calibration,
            chunk_size=chunk_size)
        t_roofs = sweep.predict_totals_stream(
            table, platform_hw, model="roofline", chunk_size=chunk_size)
    rep = ValidationReport(platform=platform_hw.name)
    for w, t_meas, t_model, t_roof in zip(workloads, measured,
                                          t_models, t_roofs):
        rep.rows.append(ValidationRow(
            name=w.name, wclass=w.wclass, measured_s=t_meas,
            model_s=float(t_model), roofline_s=float(t_roof)))
    return rep


def measure_median(fn: Callable[[], None], *, repeats: int = 100,
                   warmups: int = 10,
                   timer: Optional[Callable[[], float]] = None
                   ) -> Tuple[float, float]:
    """Paper's measurement protocol: warmups, repeats, median (+ IQR%).

    ``fn`` must block until the work is done (e.g. block_until_ready)."""
    import time
    clock = timer or time.perf_counter
    for _ in range(warmups):
        fn()
    samples = []
    for _ in range(repeats):
        t0 = clock()
        fn()
        samples.append(clock() - t0)
    samples.sort()
    med = samples[len(samples) // 2]
    spread = iqr(samples) / max(med, 1e-30) * 100.0
    return med, spread

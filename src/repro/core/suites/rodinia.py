"""Rodinia 3.1 segment files (paper §V-B/C, Table X, Fig. 4).

Each benchmark is a sum of segments (dominant GPU kernels / repeated launch
patterns) characterized by FLOPs, bytes, class and n_exec, with the paper's
documented segment-construction rules:

  * HotSpot (hs_calc): stencil class -> memory-bound transpose proxy.
  * Pathfinder (dynproc_kernel): reduced effective FLOPs/bytes per step.
  * SRAD: single aggregate, traffic sized from bytes column.
  * Backprop: two layers merged into ONE compute segment (avoids
    double-counting launch latency).
  * Streamcluster: n_exec scaled to the measured launch regime — the
    paper's flagship roofline failure: measured 157 ms on MI300A vs naive
    roofline 0.005 ms, because ~26k microsecond-scale launches dominate.

Measured totals: streamcluster_1M/MI300A is paper-published (157 ms); all
others are reconstructed from the paper's per-benchmark MAE (Table X).
"""
from __future__ import annotations

from typing import Dict, List

from .. import segments as seg_mod
from ..hardware import B200, MI300A, HardwareParams
from ..workload import Segment, Workload
from . import AppEntry, PROVENANCE_PAPER, PROVENANCE_RECON, \
    reconstruct_measured

# paper Table X: per-benchmark MAE (%) on B200 and MI300A
TABLE_X = {
    "hotspot_1024":     ("stencil",  31.0, 23.6),
    "hotspot_512":      ("stencil",  15.4, 1.6),
    "bfs_1M":           ("memory",   44.9, 40.9),
    "backprop_65536":   ("compute",  33.0, 21.3),
    "pathfinder_1000":  ("balanced",  0.4, 0.1),
    "srad_502":         ("balanced",  0.5, 0.5),
    "streamcluster_1M": ("memory",   12.4, 0.03),
}

STREAMCLUSTER_MEASURED_MI300A_S = 0.157     # paper §V-C
RODINIA_MAE_MI300A = 12.5                   # paper Obs. 2 overall


def _segments() -> Dict[str, List[Segment]]:
    MB = 1e6
    segs: Dict[str, List[Segment]] = {}

    # HotSpot: 5-point stencil over the grid, pyramid-blocked; routed as
    # memory-bound transpose proxy per the paper.
    for grid, iters in ((1024, 1000), (512, 1000)):
        traffic = 2.0 * grid * grid * 4.0
        segs[f"hotspot_{grid}"] = [Segment(
            workload=Workload(
                name=f"hs_calc_{grid}", wclass="stencil",
                flops=15.0 * grid * grid, bytes=traffic,
                precision="fp32", working_set_bytes=2 * grid * grid * 4.0),
            n_exec=iters)]

    # BFS: frontier expansion over 1M nodes, ~12 level iterations,
    # pointer-chasing (irregular=True -> Obs. 2 accuracy boundary).
    segs["bfs_1M"] = [Segment(
        workload=Workload(
            name="bfs_kernel", wclass="memory",
            flops=2.0e6, bytes=24.0 * MB, precision="fp32",
            working_set_bytes=40.0 * MB, irregular=True),
        n_exec=12)]

    # Backprop: layerforward + adjust_weights merged into ONE compute
    # segment (paper's rule).  Microsecond-scale: launch-dominated.
    n_in, n_hid = 65536, 16
    segs["backprop_65536"] = [Segment(
        workload=Workload(
            name="backprop_merged", wclass="compute",
            flops=2.0 * 2 * n_in * n_hid * 2,     # fwd+bwd, merged layers
            bytes=(n_in * n_hid * 4.0) * 3,
            precision="fp32", matrix=True,
            working_set_bytes=n_in * n_hid * 4.0),
        n_exec=2)]

    # Pathfinder: dynamic programming rows; reduced effective FLOPs/bytes
    # per step, effective timestep count aligned with profilers.
    cols, steps = 100000, 99
    segs["pathfinder_1000"] = [Segment(
        workload=Workload(
            name="dynproc_kernel", wclass="balanced",
            flops=6.0 * cols, bytes=8.0 * cols, precision="fp32",
            working_set_bytes=8.0 * cols),
        n_exec=steps)]

    # SRAD: single aggregate (N=M=0 in the paper's segment file); traffic
    # sized from the bytes column.
    g = 502
    segs["srad_502"] = [Segment(
        workload=Workload(
            name="srad_aggregate", wclass="balanced",
            flops=40.0 * g * g, bytes=10.0 * g * g * 4.0,
            precision="fp32", working_set_bytes=g * g * 4.0 * 2),
        n_exec=200)]

    # Streamcluster: ~26k tiny launches; each moves ~1 KB.  Model time is
    # n_exec * (launch + t_kernel) ~= 157 ms; naive roofline sees only the
    # ~26 MB of traffic -> ~5 us.
    segs["streamcluster_1M"] = [Segment(
        workload=Workload(
            name="pgain_kernel", wclass="memory",
            flops=256.0, bytes=1024.0, precision="fp32",
            working_set_bytes=1024.0),
        n_exec=26165)]
    return segs


def apps(platform: str = "mi300a") -> List[AppEntry]:
    """AppEntries for one platform ('b200' | 'mi300a')."""
    hw = MI300A if platform == "mi300a" else B200
    col = 2 if platform == "mi300a" else 1
    segs = _segments()
    out: List[AppEntry] = []
    for name, row in TABLE_X.items():
        wclass, mae = row[0], row[col]
        app_segs = tuple(segs[name])
        pred = seg_mod.predict_app(name, app_segs, hw).total
        if name == "streamcluster_1M" and platform == "mi300a":
            out.append(AppEntry(
                name=name, wclass=wclass, segments=app_segs,
                measured_s=STREAMCLUSTER_MEASURED_MI300A_S,
                provenance=PROVENANCE_PAPER, paper_mae_pct=mae,
                note="paper: measured 157 ms; roofline predicts 0.005 ms"))
            continue
        meas = reconstruct_measured(f"{name}@{platform}", pred, mae)
        out.append(AppEntry(name=name, wclass=wclass, segments=app_segs,
                            measured_s=meas, provenance=PROVENANCE_RECON,
                            paper_mae_pct=mae))
    return out

"""Validation workload suites (paper Tables VI, IX, X, XI, XII).

GROUND-TRUTH PROVENANCE — read this before interpreting any MAE:

We have no B200/MI300A hardware in this container.  Suite "measured" values
are therefore one of:

  (a) PAPER-PUBLISHED absolute numbers, used verbatim where the paper gives
      them (GEMM 16384^3 measured 4.10 ms on B200; streamcluster_1M measured
      157 ms on MI300A; 2-SM speedup 1.28x; tile ordering).
  (b) RECONSTRUCTED values: measured_i := model_i / (1 - s_i * e_i), where
      e_i is the paper's published error level for that kernel/benchmark/
      class (Tables VI/X/XI) and s_i in {+1,-1} is a deterministic
      name-hash sign.  By construction the *model* MAE then reproduces the
      paper's number; the *naive-roofline* error against the same values is
      computed genuinely and must emerge from the physics (datasheet peaks,
      ignored launch latency, ignored caches) — it is asserted, not
      constructed.
  (c) GENUINELY MEASURED values on the CPU host (core/microbench.py), the
      one platform we can actually time.

Every suite entry records its provenance tag.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..workload import Segment, Workload

PROVENANCE_PAPER = "paper-published"
PROVENANCE_RECON = "reconstructed"
PROVENANCE_MEASURED = "measured-here"


def det_sign(name: str) -> float:
    """Deterministic +-1 from a stable hash of the kernel name."""
    h = hashlib.md5(name.encode()).digest()
    return 1.0 if h[0] % 2 == 0 else -1.0


def reconstruct_measured(name: str, model_time: float,
                         error_level: float) -> float:
    """measured = model / (1 - s*e) so that |model-measured|/measured = e."""
    s = det_sign(name)
    denom = 1.0 - s * error_level / 100.0
    return model_time / denom


@dataclass(frozen=True)
class SuiteEntry:
    workload: Workload
    measured_s: float
    provenance: str
    note: str = ""


@dataclass(frozen=True)
class AppEntry:
    """One application benchmark: segments + measured total."""

    name: str
    wclass: str
    segments: Tuple[Segment, ...]
    measured_s: float
    provenance: str
    paper_mae_pct: Optional[float] = None  # published per-benchmark MAE
    note: str = ""


def split(entries: Sequence[SuiteEntry]) -> Tuple[List[Workload], List[float]]:
    return ([e.workload for e in entries],
            [e.measured_s for e in entries])

"""B200 microbenchmark validation suite: 21 kernels (paper Table VI row 1,
Table IX classes, §V-B(c) narrative).

Classes and counts mirror the paper:
  * memory-bound (8): vector add/copy (2 sizes), transpose (2 sizes),
    reduction (2 sizes) — class error ~8.4% driven by L2 benefits and
    5-12us launch overhead on the small sizes.
  * compute-bound (7): FP16/FP8/LLM GEMMs via cuBLAS — class error ~5.4%.
  * balanced (6): FFT, SpMV (two densities), GEMV, stencils — ~7.9%;
    spmv at 0.1% density is the 13.6%-error outlier (atomics/load balance
    not modeled -> flagged irregular).

Suite-level reconstruction targets Table VI: model MAE 1.33%.
The headline Table VI number uses per-kernel error levels ~1.33%; the
per-class §V-B(c) narrative numbers are exposed via ``class_error_levels``
for the observation benchmark.
"""
from __future__ import annotations

from typing import List

from .. import blackwell, predict as predict_mod
from ..hardware import B200, HardwareParams
from ..workload import TileConfig, Workload, gemm_workload, streaming_workload
from . import PROVENANCE_PAPER, PROVENANCE_RECON, SuiteEntry, \
    reconstruct_measured

TABLE_VI_MAE = 1.33          # paper Table VI, B200 row
CLASS_ERROR_LEVELS = {"memory": 8.4, "compute": 5.4, "balanced": 7.9}

# The paper's worked example (§IV-D): GEMM M=N=K=16384, tile 128x128x32,
# predicted 4.17 ms vs measured 4.10 ms (1.8% error).  FP8 LLM GEMM class.
PAPER_GEMM_PREDICTED_MS = 4.17
PAPER_GEMM_MEASURED_MS = 4.10


def _w_memory() -> List[Workload]:
    # Microbenchmark regime: parameter-extraction kernels are us-scale, so
    # the 5-12us launch overhead + sustained-vs-peak gap compound — exactly
    # the paper's §II explanation of why naive roofline exceeds 95% error.
    MB = 1e6
    out = []
    for size, tag in ((0.5 * MB, "512KB"), (2.0 * MB, "2MB")):
        out.append(streaming_workload(f"vec_copy_{tag}", size,
                                      flops_per_byte=0.0))
        out.append(streaming_workload(f"vec_add_{tag}", size * 1.5,
                                      flops_per_byte=1.0 / 12.0))
        out.append(streaming_workload(f"reduction_{tag}", size,
                                      flops_per_byte=0.25))
    for n in (256, 512):
        nb = 2.0 * n * n * 4
        out.append(streaming_workload(f"transpose_{n}", nb))
    return out


def _w_compute() -> List[Workload]:
    tile = TileConfig(128, 128, 32)
    out = []
    for n in (512, 768, 1024):
        out.append(gemm_workload(f"gemm_fp16_{n}", n, n, n,
                                 precision="fp16", tile=tile))
    for n in (1024, 1280):
        out.append(gemm_workload(f"gemm_fp8_{n}", n, n, n,
                                 precision="fp8", tile=tile))
    # LLM-shaped projection GEMM (decode-time skinny GEMM)
    out.append(gemm_workload("llm_gemm_qkv", 1024, 1280, 1024,
                             precision="fp8", tile=tile))
    # the paper's worked example: the one LARGE kernel in the suite
    out.append(gemm_workload("gemm_fp8_16384", 16384, 16384, 16384,
                             precision="fp8", tile=tile))
    return out


def _w_balanced() -> List[Workload]:
    out = []
    n_fft = 1 << 16
    out.append(Workload(
        name="fft_64K", wclass="balanced",
        flops=5.0 * n_fft * 16,          # 5 N log2 N
        bytes=16.0 * n_fft * 3,          # multi-pass complex traffic
        precision="fp32", working_set_bytes=16.0 * n_fft,
    ))
    for n, dens, tag, irr in ((8192, 0.001, "0.1pct", True),
                              (4096, 0.01, "1pct", False)):
        nnz = n * n * dens
        out.append(Workload(
            name=f"spmv_{tag}", wclass="balanced",
            flops=2.0 * nnz, bytes=nnz * 12.0 + n * 8.0,
            precision="fp32", working_set_bytes=nnz * 12.0,
            irregular=irr, atomics=irr,
        ))
    out.append(Workload(
        name="gemv_1024", wclass="balanced",
        flops=2.0 * 1024.0 ** 2, bytes=4.0 * (1024.0 ** 2 + 2 * 1024),
        precision="fp32", working_set_bytes=4.0 * 1024.0 ** 2,
    ))
    for g in (256, 512):
        out.append(Workload(
            name=f"stencil_{g}", wclass="stencil",
            flops=7.0 * g * g, bytes=8.0 * g * g,
            precision="fp32", working_set_bytes=8.0 * g * g,
        ))
    return out


def workloads() -> List[Workload]:
    ws = _w_memory() + _w_compute() + _w_balanced()
    assert len(ws) == 21, f"B200 suite must have 21 kernels, got {len(ws)}"
    return ws


def suite(hw: HardwareParams = B200) -> List[SuiteEntry]:
    """21 entries with measured values (reconstruction per suites/__init__)."""
    entries: List[SuiteEntry] = []
    for w in workloads():
        t_model = predict_mod.predict(w, hw).total
        if w.name == "gemm_fp8_16384":
            # paper-published absolute measurement (§IV-D example)
            entries.append(SuiteEntry(
                workload=w, measured_s=PAPER_GEMM_MEASURED_MS * 1e-3,
                provenance=PROVENANCE_PAPER,
                note="paper §IV-D: predicted 4.17ms vs measured 4.10ms"))
            continue
        meas = reconstruct_measured(w.name, t_model, TABLE_VI_MAE)
        entries.append(SuiteEntry(workload=w, measured_s=meas,
                                  provenance=PROVENANCE_RECON))
    return entries


def two_sm_case() -> Workload:
    """The 2-SM cooperative validation case (§V-B(c))."""
    return gemm_workload("gemm_fp8_2sm", 16384, 16384, 16384,
                         precision="fp8", tile=TileConfig(128, 128, 32))

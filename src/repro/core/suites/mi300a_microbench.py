"""MI300A microbenchmark validation suite: 27 kernels (paper Table VI row 2,
§V-B(d)).

Composition per §V-B(d): "vectors, reductions, 2D transposes, FP64
rocblas_dgemm, occupancy-tile GEMMs, VGPR/cache stencil variants".

  * vectors (6): add/copy at 3 sizes
  * reductions (3)
  * 2D transposes (4): 2048^2..16384^2 — the paper applies host-measured
    multipliers for 8192^2 and 16384^2 (uncalibrated model is optimistic
    on large transpose traffic)
  * FP64 DGEMM (4): piecewise scaling vs M=N=K
  * occupancy-tile GEMMs (4): 8/16/32/64 tiles (Table VII row)
  * VGPR/cache stencil variants (6): VGPR 64/128/256 x resident/streaming

Uncalibrated error level ~6.5% (paper Obs. 1: "roughly 5-8% MAE");
per-case calibration brings it to ~0.09% (quantized multipliers leave a
small residual, mirroring the paper's nonzero calibrated MAE).
"""
from __future__ import annotations

from typing import List

from .. import cdna3, predict as predict_mod
from ..hardware import MI300A, HardwareParams
from ..workload import TileConfig, Workload, gemm_workload, \
    streaming_workload
from . import PROVENANCE_RECON, SuiteEntry, reconstruct_measured

TABLE_VI_MAE_CALIBRATED = 0.09
UNCALIBRATED_ERROR_LEVEL = 6.5    # Obs. 1: "roughly 5-8%"


def _vectors() -> List[Workload]:
    # us-scale parameter-extraction kernels (launch-overhead regime): this
    # is where naive roofline genuinely fails by ~99% (paper Table VI).
    KB = 1e3
    out = []
    for size, tag in ((64 * KB, "64KB"), (128 * KB, "128KB"),
                      (256 * KB, "256KB")):
        out.append(streaming_workload(f"vec_copy_{tag}", size))
        out.append(streaming_workload(f"vec_add_{tag}", size * 1.5,
                                      flops_per_byte=1.0 / 12.0))
    return out


def _reductions() -> List[Workload]:
    KB = 1e3
    return [streaming_workload(f"reduction_{tag}", size, flops_per_byte=0.25)
            for size, tag in ((64 * KB, "64KB"), (256 * KB, "256KB"),
                              (1024 * KB, "1MB"))]


def _transposes() -> List[Workload]:
    out = []
    for n in (128, 192, 256, 384):
        nb = 2.0 * n * n * 4
        out.append(streaming_workload(f"transpose_{n}", nb))
    return out


def _dgemms() -> List[Workload]:
    tile = TileConfig(64, 64, 16)
    return [gemm_workload(f"dgemm_{n}", n, n, n, precision="fp64", tile=tile)
            for n in (128, 160, 192, 224)]


def occupancy_tile_cases() -> List[Workload]:
    """GEMM at fixed problem size across tile sizes 8/16/32/64 (the
    occupancy/tile study; Eq. 14 must order 16x16 faster than 8x8)."""
    out = []
    for t in (8, 16, 32, 64):
        out.append(gemm_workload(f"occ_gemm_tile{t}", 256, 256, 256,
                                 precision="fp32",
                                 tile=TileConfig(t, t, 16)))
    return out


def _stencil_variants() -> List[Workload]:
    """VGPR-pressure x cache-residency stencil grid."""
    out = []
    for vgpr in (64, 128, 256):
        for resident, tag in ((True, "resident"), (False, "streaming")):
            g = 256 if resident else 768      # LLC-resident vs larger grid
            out.append(Workload(
                name=f"stencil_v{vgpr}_{tag}", wclass="stencil",
                flops=7.0 * g * g, bytes=8.0 * g * g, precision="fp32",
                working_set_bytes=8.0 * g * g,
                vgpr_per_workitem=vgpr,
            ))
    return out


def workloads() -> List[Workload]:
    ws = (_vectors() + _reductions() + _transposes() + _dgemms()
          + occupancy_tile_cases() + _stencil_variants())
    assert len(ws) == 27, f"MI300A suite must have 27 kernels, got {len(ws)}"
    return ws


def suite(hw: HardwareParams = MI300A) -> List[SuiteEntry]:
    entries: List[SuiteEntry] = []
    for w in workloads():
        t_model = predict_mod.predict(w, hw).total
        meas = reconstruct_measured(w.name, t_model,
                                    UNCALIBRATED_ERROR_LEVEL)
        note = ""
        if w.name in ("transpose_8192", "transpose_16384"):
            note = "paper applies host-measured multiplier (large transpose)"
        elif w.name.startswith("dgemm"):
            note = "paper: piecewise scaling vs M=N=K"
        entries.append(SuiteEntry(workload=w, measured_s=meas,
                                  provenance=PROVENANCE_RECON, note=note))
    return entries

"""SPEChpc 2021 Tiny segment files (paper §V-D, Tables XI & XII, Obs. 3).

Two characterizations per benchmark:
  * PROFILER-derived FLOPs/bytes (the main-table inputs; MAE 1.3% MI300A),
  * FIRST-PRINCIPLES (source-level algorithm analysis), whose FLOP counts
    differ from profiler counts by up to 1000x for directive-based offload
    codes (Table XII FLOP ratios) — the paper's "characterization gap"
    finding, which we reproduce by scaling the characterization and
    re-running the SAME model.

535.weather_t omitted (no GPU kernels in profiler output), as in the paper.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from .. import segments as seg_mod
from ..hardware import B200, MI300A, HardwareParams
from ..workload import Segment, Workload
from . import AppEntry, PROVENANCE_RECON, reconstruct_measured

# name: (class, B200 MAE, MI300A MAE, Table XII FLOP ratio, Table XII FP MAE)
TABLE_XI_XII = {
    "505.lbm_t":      ("memory",   14.9, 0.1, 0.121, 98.7),
    "513.soma_t":     ("balanced",  0.3, 1.3, 1.065, 31.8),
    "518.tealeaf_t":  ("memory",    0.2, 1.6, 0.008, 98.4),
    "519.clvleaf_t":  ("memory",   18.5, 1.5, 0.013, 98.7),
    "521.miniswp_t":  ("compute",  32.8, 0.8, 0.001, 99.2),
    "528.pot3d_t":    ("memory",   None, 7.0, 0.961, 10.3),
    "532.sph_exa_t":  ("balanced", 0.03, 0.6, 0.021, 94.0),
    "534.hpgmgfv_t":  ("memory",    0.3, 0.8, 0.800, 19.4),
}

SPECHPC_MAE_MI300A = 1.3      # profiler-characterized overall (paper)
SPECHPC_FP_MAE_MI300A = 92.5  # first-principles-characterized overall


def _profiler_segments() -> Dict[str, List[Segment]]:
    """Profiler-derived characterization (reconstructed magnitudes: dominant
    kernel loops at Tiny scale, seconds-scale totals, FP64)."""
    GB = 1e9
    spec = {
        # name: (flops, bytes, n_exec, working_set, matrix)
        "505.lbm_t":     (4.0e9,  3.6 * GB, 500,  1.2 * GB, False),
        "513.soma_t":    (1.2e9,  0.4 * GB, 400,  0.2 * GB, False),
        "518.tealeaf_t": (0.8e9,  2.4 * GB, 800,  0.9 * GB, False),
        "519.clvleaf_t": (1.0e9,  2.0 * GB, 600,  1.1 * GB, False),
        "521.miniswp_t": (4.8e12, 0.8 * GB, 10,   0.3 * GB, True),
        "528.pot3d_t":   (2.0e9,  1.6 * GB, 700,  0.8 * GB, False),
        "532.sph_exa_t": (2.5e9,  0.9 * GB, 300,  0.5 * GB, False),
        "534.hpgmgfv_t": (1.5e9,  1.8 * GB, 400,  1.4 * GB, False),
    }
    out: Dict[str, List[Segment]] = {}
    for name, (fl, by, n, ws, mat) in spec.items():
        cls = TABLE_XI_XII[name][0]
        out[name] = [Segment(
            workload=Workload(
                name=f"{name}_main", wclass=cls, flops=fl, bytes=by,
                precision="fp64", matrix=mat, working_set_bytes=ws),
            n_exec=n)]
    return out


def first_principles_segments() -> Dict[str, List[Segment]]:
    """Source-level characterization: FLOPs scaled by the published Table
    XII ratio; bytes scaled consistently (reconstructed so the FP-vs-
    profiler gap reproduces the published FP MAE ordering)."""
    prof = _profiler_segments()
    out: Dict[str, List[Segment]] = {}
    for name, segs in prof.items():
        _, _, _, flop_ratio, fp_mae = TABLE_XI_XII[name]
        # byte ratio: for memory-bound codes the FP error is byte-driven
        byte_ratio = (1.0 + fp_mae / 100.0) if flop_ratio > 1.0 \
            else max(1.0 - fp_mae / 100.0, 1e-4)
        new = []
        for s in segs:
            w = s.workload
            new.append(Segment(
                workload=w.replace(
                    name=w.name + "_fp",
                    flops=w.flops * flop_ratio,
                    bytes=w.bytes * byte_ratio,
                    working_set_bytes=w.working_set_bytes * byte_ratio),
                n_exec=s.n_exec))
        out[name] = new
    return out


def apps(platform: str = "mi300a") -> List[AppEntry]:
    hw = MI300A if platform == "mi300a" else B200
    col = 2 if platform == "mi300a" else 1
    segs = _profiler_segments()
    out: List[AppEntry] = []
    for name, row in TABLE_XI_XII.items():
        wclass, mae = row[0], row[col]
        if mae is None:      # 528.pot3d_t has no B200 entry in Table XI
            continue
        app_segs = tuple(segs[name])
        pred = seg_mod.predict_app(name, app_segs, hw).total
        meas = reconstruct_measured(f"{name}@{platform}", pred, mae)
        out.append(AppEntry(name=name, wclass=wclass, segments=app_segs,
                            measured_s=meas, provenance=PROVENANCE_RECON,
                            paper_mae_pct=mae))
    return out


def flop_ratios() -> Dict[str, float]:
    return {k: v[3] for k, v in TABLE_XI_XII.items()}

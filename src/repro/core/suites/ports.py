"""H200 and MI250X port suites (paper Table VI rows 3-4, §V-B(e)).

The paper's portability claim: same model FRAMEWORK, parameter-file update
only, no re-derivation.  H200 gets the Blackwell stage model with Hopper
values (4.8 TB/s HBM, 141 GB, no TMEM/2-SM); MI250X gets the CDNA wavefront
model with its own values (3.2 TB/s, 128 MB LLC, 220 CUs).

Published anchors:
  * H200 microbench MAE 9.57% (n=21), roofline 94.5%
  * MI250X microbench MAE 4.69% (n=19), roofline 97.9%
  * MI250X FP64 GEMM at 16384^3: predicted 0.283 s vs measured 0.283 s
  * MI250X tile ordering reproduced (16x16 faster)
"""
from __future__ import annotations

from typing import List

from .. import predict as predict_mod
from ..hardware import H200, MI250X, HardwareParams
from ..workload import TileConfig, Workload, gemm_workload
from . import PROVENANCE_PAPER, PROVENANCE_RECON, SuiteEntry, \
    reconstruct_measured
from . import b200_microbench, mi300a_microbench

H200_MAE = 9.57
MI250X_MAE = 4.69
MI250X_DGEMM_MEASURED_S = 0.283


def h200_suite(hw: HardwareParams = H200) -> List[SuiteEntry]:
    """Same 21 kernel shapes as the B200 suite; H200 parameter file;
    measured values reconstructed at the published port error level."""
    entries: List[SuiteEntry] = []
    for w in b200_microbench.workloads():
        t_model = predict_mod.predict(w, hw).total
        meas = reconstruct_measured(f"{w.name}@h200", t_model, H200_MAE)
        entries.append(SuiteEntry(workload=w, measured_s=meas,
                                  provenance=PROVENANCE_RECON))
    return entries


def mi250x_workloads() -> List[Workload]:
    """19 kernels per §V-B(e): memory-bound vectors, FP64 GEMM, the
    occupancy/tile study (MI300A composition minus the stencil variants
    and two transposes)."""
    base = mi300a_microbench.workloads()
    keep = [w for w in base
            if not w.name.startswith("stencil_v")
            and w.name not in ("transpose_128", "transpose_192",
                               "dgemm_224")]
    # add the paper's large FP64 GEMM point
    keep.append(gemm_workload("dgemm_16384", 16384, 16384, 16384,
                              precision="fp64", tile=TileConfig(64, 64, 16)))
    assert len(keep) == 19, f"MI250X suite must have 19 kernels: {len(keep)}"
    return keep


def mi250x_suite(hw: HardwareParams = MI250X) -> List[SuiteEntry]:
    entries: List[SuiteEntry] = []
    for w in mi250x_workloads():
        if w.name == "dgemm_16384":
            entries.append(SuiteEntry(
                workload=w, measured_s=MI250X_DGEMM_MEASURED_S,
                provenance=PROVENANCE_PAPER,
                note="paper §V-B(e): 0.283 s predicted vs 0.283 s measured"))
            continue
        t_model = predict_mod.predict(w, hw).total
        meas = reconstruct_measured(f"{w.name}@mi250x", t_model, MI250X_MAE)
        entries.append(SuiteEntry(workload=w, measured_s=meas,
                                  provenance=PROVENANCE_RECON))
    return entries

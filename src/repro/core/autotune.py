"""Model-driven plan selection (paper §IV-B 'adaptive tile selection',
generalized to SPMD execution plans — DESIGN.md §3).

The paper evaluates candidate GEMM tiles through the analytical model and
returns the argmin.  On a TPU pod the analogous knobs are the sharding plan
(how much TP vs DP/FSDP vs EP), the microbatch count, and the remat policy.
``enumerate_plans`` prices each candidate with the TPU stage + collective
models; ``select_plan`` returns the argmin.  This is the napkin-math engine
used by the §Perf hillclimbing loop.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import collectives as coll
from . import tpu
from .hardware import HardwareParams, TPU_V5E
from .workload import LatticeSpec, TileConfig, Workload, WorkloadTable


@dataclass(frozen=True)
class PlanCandidate:
    """One execution plan for a train/serve step on a mesh."""

    name: str
    mesh: coll.MeshSpec
    # model-parallel degree along "model" axis actually used by the plan
    tp_degree: int
    microbatches: int = 1
    remat: str = "none"              # none | block | full
    compressed_grads: bool = False   # int8 error-feedback all-reduce

    def describe(self) -> str:
        return (f"{self.name}: tp={self.tp_degree} ubatch={self.microbatches}"
                f" remat={self.remat} int8grads={self.compressed_grads}")


REMAT_FLOP_FACTOR = {"none": 1.0, "block": 4.0 / 3.0, "full": 5.0 / 3.0}


@dataclass(frozen=True)
class StepCost:
    plan: PlanCandidate
    compute_s: float
    memory_s: float
    collective_s: float
    exposed_collective_s: float
    total_s: float
    hbm_bytes_per_chip: float
    detail: Dict[str, float] = field(default_factory=dict)


def _collective_ops(plan: PlanCandidate, *, param_bytes: float,
                    activation_bytes: float) -> List[Tuple[str, float, str]]:
    """The plan's collective schedule (shared by ``price_train_step`` and
    the columnar ``enumerate_plans``):
      * FSDP all-gather of params (per microbatch, fwd + bwd if remat=full)
      * reduce-scatter of grads over data axes (+ pod axis)
      * TP activation all-reduces: ~2 per layer-equivalent, approximated as
        activation_bytes/tp_degree volume when tp>1.
    """
    mesh = plan.mesh
    chips = mesh.num_devices
    data_axes = [a for a, _ in mesh.axes if a in ("data", "pod")]
    ops: List[Tuple[str, float, str]] = []
    shard_param_bytes = param_bytes / chips
    for axis in data_axes:
        # FSDP gather once per microbatch fwd; bwd regather if remat=full
        gathers = plan.microbatches * (2 if plan.remat == "full" else 1)
        ops.append(("all-gather", shard_param_bytes * gathers, axis))
    grad_bytes = param_bytes / chips
    if plan.compressed_grads:
        grad_bytes *= 0.25           # int8 wire format vs fp32 master grads
    for axis in data_axes:
        ops.append(("reduce-scatter", grad_bytes, axis))
        ops.append(("all-gather", grad_bytes, axis))
    if plan.tp_degree > 1:
        # activation all-reduces on the model axis
        ops.append(("all-reduce",
                    activation_bytes / chips / max(plan.microbatches, 1),
                    "model"))
    return ops


def _step_total(t_compute: float, t_memory: float, t_exposed: float,
                alpha: float, hw: HardwareParams) -> float:
    """Step-time roofline: overlapped max + exposed remainder + launch."""
    t_step = max(t_compute, (1 - alpha) * t_memory, t_exposed) \
        + min(t_compute, (1 - alpha) * t_memory)
    return t_step + hw.launch_latency_s


def price_train_step(plan: PlanCandidate, *,
                     model_flops: float,          # 6*N*D useful flops (global)
                     param_bytes: float,          # total param bytes (global)
                     activation_bytes: float,     # per-step act traffic (global)
                     hw: HardwareParams = TPU_V5E) -> StepCost:
    """Price one training step under a plan (collective schedule per
    ``_collective_ops``)."""
    chips = plan.mesh.num_devices

    remat_f = REMAT_FLOP_FACTOR[plan.remat]
    flops_per_chip = model_flops * remat_f / chips
    t_compute = flops_per_chip / hw.sustained_flops("bf16", matrix=True)

    # HBM traffic per chip: params touched fwd+bwd+opt (3x) + activations
    act_factor = _REMAT_ACT_FACTOR[plan.remat]
    hbm_bytes = (3.0 * param_bytes / chips
                 + activation_bytes * act_factor / chips)
    t_memory = hbm_bytes / hw.hbm_sustained_bw

    ops = _collective_ops(plan, param_bytes=param_bytes,
                          activation_bytes=activation_bytes)
    alpha = hw.pipeline_overlap_alpha
    sched = coll.schedule_time(ops, plan.mesh, hw, overlap_alpha=alpha)
    t_coll, t_exposed = sched["total"], sched["exposed"]

    total = _step_total(t_compute, t_memory, t_exposed, alpha, hw)
    return StepCost(plan=plan, compute_s=t_compute, memory_s=t_memory,
                    collective_s=t_coll, exposed_collective_s=t_exposed,
                    total_s=total, hbm_bytes_per_chip=hbm_bytes,
                    detail={k: v for k, v in sched.items()
                            if k not in ("total", "exposed")})


def hbm_fits(plan: PlanCandidate, *, param_bytes: float,
             opt_state_bytes: float, activation_peak_bytes: float,
             hw: HardwareParams = TPU_V5E) -> bool:
    chips = plan.mesh.num_devices
    act_factor = {"none": 1.0, "block": 0.4, "full": 0.15}[plan.remat]
    per_chip = ((param_bytes + opt_state_bytes) / chips
                + activation_peak_bytes * act_factor
                / chips * plan.microbatches ** 0  # act peak per microbatch
                / max(plan.microbatches, 1))
    return per_chip <= hw.hbm_capacity * 0.9


_REMAT_ACT_FACTOR = {"none": 1.0, "block": 0.6, "full": 0.35}
_REMAT_PEAK_FACTOR = {"none": 1.0, "block": 0.4, "full": 0.15}


#: below this many plans per worker a process pool costs more than the
#: collective-schedule Python it parallelizes (~tens of us per plan).
_MIN_PLANS_PER_WORKER = 64


def _price_plan_block(candidates: Sequence[PlanCandidate],
                      opt_b: np.ndarray, model_flops: float,
                      param_bytes: float, activation_bytes: float,
                      activation_peak_bytes: float,
                      hw: HardwareParams) -> List[StepCost]:
    """One columnar pricing block (the chunk unit of ``enumerate_plans``;
    matches ``price_train_step`` expression-for-expression)."""
    chips = np.array([p.mesh.num_devices for p in candidates],
                     dtype=np.float64)
    ubatch = np.array([p.microbatches for p in candidates], dtype=np.float64)
    remat_f = np.array([REMAT_FLOP_FACTOR[p.remat] for p in candidates])
    act_f = np.array([_REMAT_ACT_FACTOR[p.remat] for p in candidates])
    peak_f = np.array([_REMAT_PEAK_FACTOR[p.remat] for p in candidates])

    flops_per_chip = model_flops * remat_f / chips
    t_compute = flops_per_chip / hw.sustained_flops("bf16", matrix=True)
    hbm_bytes = (3.0 * param_bytes / chips
                 + activation_bytes * act_f / chips)
    t_memory = hbm_bytes / hw.hbm_sustained_bw
    alpha = hw.pipeline_overlap_alpha

    # HBM-fit gate (mirrors hbm_fits per element)
    per_chip = ((param_bytes + opt_b) / chips
                + activation_peak_bytes * peak_f
                / chips / np.maximum(ubatch, 1))
    feasible = per_chip <= hw.hbm_capacity * 0.9

    costs = []
    for i, plan in enumerate(candidates):
        ops = _collective_ops(plan, param_bytes=param_bytes,
                              activation_bytes=activation_bytes)
        sched = coll.schedule_time(ops, plan.mesh, hw, overlap_alpha=alpha)
        t_coll, t_exposed = sched["total"], sched["exposed"]
        t_c, t_m = float(t_compute[i]), float(t_memory[i])
        detail = {k: v for k, v in sched.items()
                  if k not in ("total", "exposed")}
        detail["feasible"] = 1.0 if feasible[i] else 0.0
        costs.append(StepCost(
            plan=plan, compute_s=t_c, memory_s=t_m, collective_s=t_coll,
            exposed_collective_s=t_exposed,
            total_s=_step_total(t_c, t_m, t_exposed, alpha, hw),
            hbm_bytes_per_chip=float(hbm_bytes[i]), detail=detail))
    return costs


def _plan_shard(candidates, opt_b, model_flops, param_bytes,
                activation_bytes, activation_peak_bytes, hw, chunk_size):
    """Worker body for jobs-sharded ``enumerate_plans`` (top-level so it
    pickles under spawn as well as fork)."""
    return enumerate_plans(
        candidates, model_flops=model_flops, param_bytes=param_bytes,
        activation_bytes=activation_bytes, opt_state_bytes=opt_b,
        activation_peak_bytes=activation_peak_bytes, hw=hw,
        chunk_size=chunk_size)


def enumerate_plans(candidates: Sequence[PlanCandidate], *,
                    model_flops: float, param_bytes: float,
                    activation_bytes: float,
                    opt_state_bytes: Union[float, Sequence[float]] = 0.0,
                    activation_peak_bytes: float = 0.0,
                    hw: HardwareParams = TPU_V5E,
                    chunk_size: Optional[int] = None,
                    jobs=None) -> List[StepCost]:
    """Price every candidate plan (collective schedule + HBM-fit gate).

    This is the enumeration half of the paper's argmin: callers that only
    need the winner use ``select_plan``; hillclimb-style consumers read the
    whole priced list to order their experiments.

    The arithmetic runs columnar over the candidate set (one NumPy block
    per ``chunk_size`` plans, matching ``price_train_step``
    expression-for-expression); only the per-plan collective schedule walks
    Python.  ``opt_state_bytes`` may be a per-plan sequence (e.g. int8 vs
    fp32 optimizer moments) so heterogeneous what-if screens price in a
    single call.

    ``chunk_size`` bounds the NumPy intermediates for very large candidate
    sets; ``jobs`` (0/"auto" = ``os.cpu_count()``) shards the candidate
    list across worker processes when the set is large enough to amortize
    the pool (results are concatenated in candidate order, identical to a
    serial run).
    """
    n = len(candidates)
    if not n:
        return []
    opt_b = np.full(n, opt_state_bytes, dtype=np.float64) \
        if np.isscalar(opt_state_bytes) \
        else np.asarray(opt_state_bytes, dtype=np.float64)
    if opt_b.shape != (n,):
        raise ValueError(f"opt_state_bytes: expected scalar or {n} values")

    if jobs is not None:
        from . import parallel, sweep
        njobs = sweep.effective_jobs(jobs)
        if njobs > 1 and n >= _MIN_PLANS_PER_WORKER * njobs:
            bounds = [(n * j // njobs, n * (j + 1) // njobs)
                      for j in range(njobs)]
            shards = parallel.map_jobs(
                _plan_shard,
                [(list(candidates[lo:hi]), opt_b[lo:hi], model_flops,
                  param_bytes, activation_bytes, activation_peak_bytes,
                  hw, chunk_size) for lo, hi in bounds if hi > lo],
                jobs=njobs)
            return [c for shard in shards for c in shard]

    size = int(chunk_size) if chunk_size else n
    costs: List[StepCost] = []
    for lo in range(0, n, max(size, 1)):
        hi = min(lo + size, n)
        costs.extend(_price_plan_block(
            list(candidates[lo:hi]), opt_b[lo:hi], model_flops,
            param_bytes, activation_bytes, activation_peak_bytes, hw))
    return costs


def select_plan(candidates: Sequence[PlanCandidate], *,
                model_flops: float, param_bytes: float,
                activation_bytes: float,
                opt_state_bytes: float = 0.0,
                activation_peak_bytes: float = 0.0,
                hw: HardwareParams = TPU_V5E
                ) -> Tuple[StepCost, List[StepCost]]:
    """Price all candidates; return (best, all) — paper's argmin, with an
    HBM-fit feasibility gate (the paper's 'proves it fits')."""
    costs = enumerate_plans(
        candidates, model_flops=model_flops, param_bytes=param_bytes,
        activation_bytes=activation_bytes, opt_state_bytes=opt_state_bytes,
        activation_peak_bytes=activation_peak_bytes, hw=hw)
    feas = [c for c in costs if c.detail.get("feasible", 1.0) > 0]
    pool = feas or costs
    best = min(pool, key=lambda c: c.total_s)
    return best, costs


# ---------------------------------------------------------------------------
# Columnar kernel-level sweeps (paper §IV-B adaptive tile selection, served
# by the table path so 10^3-10^4-point searches never instantiate
# per-config Workload objects).
# ---------------------------------------------------------------------------

def _tile_totals(base: Workload, hw: HardwareParams,
                 candidate_tiles: Sequence["TileConfig"], *,
                 model: Optional[str], engine, chunk_size, jobs
                 ) -> np.ndarray:
    """Totals column for a tile lattice: the memoized whole-table path by
    default, the streaming/sharded path when ``chunk_size``/``jobs`` ask
    for bounded memory or multi-core pricing (same floats either way)."""
    from . import sweep
    if chunk_size is None and jobs is None:
        table = WorkloadTable.tile_lattice(base, candidate_tiles)
        return sweep.predict_table(table, hw, model=model,
                                   engine=engine).totals
    spec = LatticeSpec.tile_lattice(base, candidate_tiles)
    return sweep.predict_totals_stream(spec, hw, model=model,
                                       engine=engine,
                                       chunk_size=chunk_size, jobs=jobs)


def enumerate_tiles(base: Workload, hw: HardwareParams,
                    candidate_tiles: Sequence["TileConfig"], *,
                    model: Optional[str] = None,
                    engine=None, chunk_size: Optional[int] = None,
                    jobs=None) -> Dict[str, float]:
    """Price ``base`` re-tiled with every candidate through the columnar
    table path; returns {"bMxbNxbK": seconds}."""
    totals = _tile_totals(base, hw, candidate_tiles, model=model,
                          engine=engine, chunk_size=chunk_size, jobs=jobs)
    return {f"{t.bm}x{t.bn}x{t.bk}": float(s)
            for t, s in zip(candidate_tiles, totals)}


def select_tile(base: Workload, hw: HardwareParams,
                candidate_tiles: Sequence["TileConfig"], *,
                model: Optional[str] = None,
                engine=None, chunk_size: Optional[int] = None,
                jobs=None) -> Tuple["TileConfig", Dict[str, float]]:
    """Fused argmin over candidate tiles (the paper's adaptive tile
    selection): one columnar sweep, one reduction on the totals column.
    With ``chunk_size``/``jobs`` the lattice streams in O(chunk) memory
    and/or shards across cores — winner identical either way."""
    totals = _tile_totals(base, hw, candidate_tiles, model=model,
                          engine=engine, chunk_size=chunk_size, jobs=jobs)
    best_i = int(np.argmin(totals))
    costs = {f"{t.bm}x{t.bn}x{t.bk}": float(s)
             for t, s in zip(candidate_tiles, totals)}
    return candidate_tiles[best_i], costs

"""Model-driven plan selection (paper §IV-B 'adaptive tile selection',
generalized to SPMD execution plans — DESIGN.md §3).

The paper evaluates candidate GEMM tiles through the analytical model and
returns the argmin.  On a TPU pod the analogous knobs are the sharding plan
(how much TP vs DP/FSDP vs EP), the microbatch count, and the remat policy.
``enumerate_plans`` prices each candidate with the TPU stage + collective
models; ``select_plan`` returns the argmin.  This is the napkin-math engine
used by the §Perf hillclimbing loop.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from . import collectives as coll
from . import tpu
from .hardware import HardwareParams, TPU_V5E
from .workload import TileConfig, Workload


@dataclass(frozen=True)
class PlanCandidate:
    """One execution plan for a train/serve step on a mesh."""

    name: str
    mesh: coll.MeshSpec
    # model-parallel degree along "model" axis actually used by the plan
    tp_degree: int
    microbatches: int = 1
    remat: str = "none"              # none | block | full
    compressed_grads: bool = False   # int8 error-feedback all-reduce

    def describe(self) -> str:
        return (f"{self.name}: tp={self.tp_degree} ubatch={self.microbatches}"
                f" remat={self.remat} int8grads={self.compressed_grads}")


REMAT_FLOP_FACTOR = {"none": 1.0, "block": 4.0 / 3.0, "full": 5.0 / 3.0}


@dataclass(frozen=True)
class StepCost:
    plan: PlanCandidate
    compute_s: float
    memory_s: float
    collective_s: float
    exposed_collective_s: float
    total_s: float
    hbm_bytes_per_chip: float
    detail: Dict[str, float] = field(default_factory=dict)


def price_train_step(plan: PlanCandidate, *,
                     model_flops: float,          # 6*N*D useful flops (global)
                     param_bytes: float,          # total param bytes (global)
                     activation_bytes: float,     # per-step act traffic (global)
                     hw: HardwareParams = TPU_V5E) -> StepCost:
    """Price one training step under a plan.

    Collective schedule priced:
      * FSDP all-gather of params (per microbatch, fwd + bwd if remat=full)
      * reduce-scatter of grads over data axes (+ pod axis)
      * TP activation all-reduces: ~2 per layer-equivalent, approximated as
        activation_bytes/tp_degree volume when tp>1.
    """
    mesh = plan.mesh
    chips = mesh.num_devices
    data_axes = [a for a, _ in mesh.axes if a in ("data", "pod")]
    dp = 1
    for a in data_axes:
        dp *= mesh.size(a)

    remat_f = REMAT_FLOP_FACTOR[plan.remat]
    flops_per_chip = model_flops * remat_f / chips
    t_compute = flops_per_chip / hw.sustained_flops("bf16", matrix=True)

    # HBM traffic per chip: params touched fwd+bwd+opt (3x) + activations
    act_factor = {"none": 1.0, "block": 0.6, "full": 0.35}[plan.remat]
    hbm_bytes = (3.0 * param_bytes / chips
                 + activation_bytes * act_factor / chips)
    t_memory = hbm_bytes / hw.hbm_sustained_bw

    # collective schedule
    ops: List[Tuple[str, float, str]] = []
    shard_param_bytes = param_bytes / chips
    for axis in data_axes:
        # FSDP gather once per microbatch fwd; bwd regather if remat=full
        gathers = plan.microbatches * (2 if plan.remat == "full" else 1)
        ops.append(("all-gather", shard_param_bytes * gathers, axis))
    grad_bytes = param_bytes / chips
    if plan.compressed_grads:
        grad_bytes *= 0.25           # int8 wire format vs fp32 master grads
    for axis in data_axes:
        ops.append(("reduce-scatter", grad_bytes, axis))
        ops.append(("all-gather", grad_bytes, axis))
    if plan.tp_degree > 1:
        # activation all-reduces on the model axis
        ops.append(("all-reduce",
                    activation_bytes / chips / max(plan.microbatches, 1),
                    "model"))

    alpha = hw.pipeline_overlap_alpha
    sched = coll.schedule_time(ops, mesh, hw, overlap_alpha=alpha)
    t_coll, t_exposed = sched["total"], sched["exposed"]

    t_step = max(t_compute, (1 - alpha) * t_memory, t_exposed) \
        + min(t_compute, (1 - alpha) * t_memory)
    total = t_step + hw.launch_latency_s
    return StepCost(plan=plan, compute_s=t_compute, memory_s=t_memory,
                    collective_s=t_coll, exposed_collective_s=t_exposed,
                    total_s=total, hbm_bytes_per_chip=hbm_bytes,
                    detail={k: v for k, v in sched.items()
                            if k not in ("total", "exposed")})


def hbm_fits(plan: PlanCandidate, *, param_bytes: float,
             opt_state_bytes: float, activation_peak_bytes: float,
             hw: HardwareParams = TPU_V5E) -> bool:
    chips = plan.mesh.num_devices
    act_factor = {"none": 1.0, "block": 0.4, "full": 0.15}[plan.remat]
    per_chip = ((param_bytes + opt_state_bytes) / chips
                + activation_peak_bytes * act_factor
                / chips * plan.microbatches ** 0  # act peak per microbatch
                / max(plan.microbatches, 1))
    return per_chip <= hw.hbm_capacity * 0.9


def enumerate_plans(candidates: Sequence[PlanCandidate], *,
                    model_flops: float, param_bytes: float,
                    activation_bytes: float,
                    opt_state_bytes: float = 0.0,
                    activation_peak_bytes: float = 0.0,
                    hw: HardwareParams = TPU_V5E) -> List[StepCost]:
    """Price every candidate plan (collective schedule + HBM-fit gate).

    This is the enumeration half of the paper's argmin: callers that only
    need the winner use ``select_plan``; hillclimb-style consumers read the
    whole priced list to order their experiments.
    """
    costs = []
    for plan in candidates:
        c = price_train_step(plan, model_flops=model_flops,
                             param_bytes=param_bytes,
                             activation_bytes=activation_bytes, hw=hw)
        feasible = hbm_fits(plan, param_bytes=param_bytes,
                            opt_state_bytes=opt_state_bytes,
                            activation_peak_bytes=activation_peak_bytes,
                            hw=hw)
        c.detail["feasible"] = 1.0 if feasible else 0.0
        costs.append(c)
    return costs


def select_plan(candidates: Sequence[PlanCandidate], *,
                model_flops: float, param_bytes: float,
                activation_bytes: float,
                opt_state_bytes: float = 0.0,
                activation_peak_bytes: float = 0.0,
                hw: HardwareParams = TPU_V5E
                ) -> Tuple[StepCost, List[StepCost]]:
    """Price all candidates; return (best, all) — paper's argmin, with an
    HBM-fit feasibility gate (the paper's 'proves it fits')."""
    costs = enumerate_plans(
        candidates, model_flops=model_flops, param_bytes=param_bytes,
        activation_bytes=activation_bytes, opt_state_bytes=opt_state_bytes,
        activation_peak_bytes=activation_peak_bytes, hw=hw)
    feas = [c for c in costs if c.detail.get("feasible", 1.0) > 0]
    pool = feas or costs
    best = min(pool, key=lambda c: c.total_s)
    return best, costs


# ---------------------------------------------------------------------------
# Batched kernel-level sweeps (paper §IV-B adaptive tile selection, served
# by the SweepEngine so 10^3-10^4-point searches stay off the scalar path).
# ---------------------------------------------------------------------------

def enumerate_tiles(base: Workload, hw: HardwareParams,
                    candidate_tiles: Sequence["TileConfig"], *,
                    model: Optional[str] = None,
                    engine=None) -> Dict[str, float]:
    """Price ``base`` re-tiled with every candidate through the batched
    engine; returns {"bMxbNxbK": seconds}."""
    from . import sweep
    from .cdna3 import _retile
    engine = engine or sweep.default_engine()
    ws = [_retile(base, t) for t in candidate_tiles]
    totals = engine.predict_batch(ws, hw, model=model).totals
    return {f"{t.bm}x{t.bn}x{t.bk}": float(s)
            for t, s in zip(candidate_tiles, totals)}


def select_tile(base: Workload, hw: HardwareParams,
                candidate_tiles: Sequence["TileConfig"], *,
                model: Optional[str] = None,
                engine=None) -> Tuple["TileConfig", Dict[str, float]]:
    """Batched argmin over candidate tiles (the paper's adaptive tile
    selection, engine-served)."""
    costs = enumerate_tiles(base, hw, candidate_tiles, model=model,
                            engine=engine)
    best_i = min(range(len(candidate_tiles)),
                 key=lambda i: costs[f"{candidate_tiles[i].bm}x"
                                     f"{candidate_tiles[i].bn}x"
                                     f"{candidate_tiles[i].bk}"])
    return candidate_tiles[best_i], costs

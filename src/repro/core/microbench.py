"""Runnable microbenchmarks (paper §V-A) for the host we can actually
measure: this container's CPU, through JAX.

The paper's loop is microbenchmark -> parameters -> predict -> validate.
On B200/MI300A we rely on the paper's published measurements; HERE we close
the loop with real timings: measure sustained GEMM throughput, streaming
bandwidth and dispatch overhead, then emit a calibrated ``cpu_host``
parameter file that core.generic / core.predict consume.

Everything uses the paper's measurement protocol (warmups, repeats, median;
core.validate.measure_median), with reduced defaults so the suite runs in
seconds on CI.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hardware import CPU_HOST, HardwareParams, register
from .validate import measure_median


@dataclass
class MeasuredSuite:
    """One microbenchmark suite run: workloads + their measured medians.

    This is the calibration artifact that travels over the wire
    (``serve.codec.encode_suite``): a client measures kernels locally,
    ships the suite, and the server fits disclosed multipliers against
    its own predictions (paper §IV-D loop, served).  ``meta`` carries
    free-form floats about the run (repeats, warmups, ...).
    """

    name: str
    workloads: List["Workload"]
    measured_s: List[float]
    meta: Dict[str, float] = field(default_factory=dict)

    def __post_init__(self):
        if len(self.workloads) != len(self.measured_s):
            raise ValueError(
                f"suite {self.name!r}: {len(self.workloads)} workloads "
                f"vs {len(self.measured_s)} measurements")

    def __len__(self) -> int:
        return len(self.workloads)

    # ------------------------------------------------------- serialization
    def to_dict(self) -> Dict:
        return {"name": self.name,
                "workloads": [w.to_dict() for w in self.workloads],
                "measured_s": [float(t) for t in self.measured_s],
                "meta": dict(self.meta)}

    @staticmethod
    def from_dict(d: Dict) -> "MeasuredSuite":
        from .workload import Workload
        if not isinstance(d, dict):
            raise ValueError(f"suite payload must be a dict, got "
                             f"{type(d).__name__}")
        try:
            return MeasuredSuite(
                name=str(d["name"]),
                workloads=[Workload.from_dict(w) for w in d["workloads"]],
                measured_s=[float(t) for t in d["measured_s"]],
                meta={str(k): float(v)
                      for k, v in (d.get("meta") or {}).items()})
        except (KeyError, TypeError) as e:
            raise ValueError(f"bad suite payload: {e}") from None

DEFAULT_REPEATS = 15
DEFAULT_WARMUPS = 3


def _timed(fn: Callable[[], jax.Array], *, repeats: int, warmups: int
           ) -> float:
    def run():
        fn().block_until_ready()
    med, _ = measure_median(run, repeats=repeats, warmups=warmups)
    return med


def measure_matmul_flops(n: int = 1024, *, dtype=jnp.float32,
                         repeats: int = DEFAULT_REPEATS,
                         warmups: int = DEFAULT_WARMUPS) -> float:
    """Sustained matrix FLOP/s: the tensor-throughput microbenchmark."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n, n), dtype)
    b = jax.random.normal(key, (n, n), dtype)
    f = jax.jit(lambda x, y: x @ y)
    f(a, b).block_until_ready()
    t = _timed(lambda: f(a, b), repeats=repeats, warmups=warmups)
    return 2.0 * n ** 3 / t


def measure_stream_bandwidth(nbytes: int = 1 << 26, *,
                             repeats: int = DEFAULT_REPEATS,
                             warmups: int = DEFAULT_WARMUPS) -> float:
    """Sustained memory bandwidth via vector copy (2 bytes moved per
    element byte: read + write)."""
    n = nbytes // 4
    x = jnp.arange(n, dtype=jnp.float32)
    f = jax.jit(lambda v: v + 1.0)
    f(x).block_until_ready()
    t = _timed(lambda: f(x), repeats=repeats, warmups=warmups)
    return 2.0 * nbytes / t


def measure_launch_latency(*, repeats: int = 50,
                           warmups: int = 10) -> float:
    """Dispatch overhead: time an O(1) jitted program."""
    x = jnp.float32(1.0)
    f = jax.jit(lambda v: v * 2.0)
    f(x).block_until_ready()
    return _timed(lambda: f(x), repeats=repeats, warmups=warmups)


def measure_vector_flops(n: int = 1 << 22, *,
                         repeats: int = DEFAULT_REPEATS,
                         warmups: int = DEFAULT_WARMUPS) -> float:
    """Non-matrix FLOP throughput (fused elementwise chain, 8 flops/elem,
    high arithmetic intensity so bandwidth is not the limiter)."""
    x = jnp.ones((n,), jnp.float32)

    def chain(v):
        for _ in range(4):
            v = v * 1.0001 + 0.5
        return v
    f = jax.jit(chain)
    f(x).block_until_ready()
    t = _timed(lambda: f(x), repeats=repeats, warmups=warmups)
    return 8.0 * n / t


def calibrate_host(*, quick: bool = True) -> HardwareParams:
    """Run all host microbenchmarks and return a measured parameter file
    (registered as 'cpu_host_measured')."""
    reps = 7 if quick else DEFAULT_REPEATS
    gemm_n = 512 if quick else 1024
    stream_b = (1 << 24) if quick else (1 << 26)

    mat = measure_matmul_flops(gemm_n, repeats=reps)
    bw = measure_stream_bandwidth(stream_b, repeats=reps)
    vec = measure_vector_flops(1 << 20 if quick else 1 << 22, repeats=reps)
    launch = measure_launch_latency()

    hw = CPU_HOST.with_updates(
        name="cpu_host_measured",
        tensor_peak_flops={"fp32": mat * 1.15, "fp64": mat * 0.6},
        tensor_sustained_flops={"fp32": mat, "fp64": mat * 0.5},
        vector_peak_flops={"fp32": vec * 1.15},
        vector_sustained_flops={"fp32": vec},
        hbm_peak_bw=bw * 1.2,
        hbm_sustained_bw=bw,
        launch_latency_s=launch,
        working_set_scale_bytes=0.0,  # disable Eq. 16 blend on host (caches
                                      # already folded into sustained number)
    )
    # overwrite: re-calibration legitimately replaces the previous run
    register(hw, overwrite=True)
    return hw


# ---------------------------------------------------------------------------
# The host validation suite: real kernels with real measured medians.
# Mirrors the paper's workload classes (Table IX).
# ---------------------------------------------------------------------------

def host_suite(*, quick: bool = True):
    """Returns (workloads, measured_seconds, runnables) for the CPU host.

    Classes: memory-bound (copy/add/transpose/reduction), compute-bound
    (GEMMs), balanced (elementwise-heavy), stencil (2D 5-point).
    """
    from .workload import Workload

    reps = 7 if quick else 30
    warm = 2 if quick else 10
    key = jax.random.PRNGKey(0)

    cases = []  # (workload, thunk)

    def add_case(w: Workload, thunk: Callable[[], jax.Array]):
        thunk().block_until_ready()  # compile
        cases.append((w, thunk))

    # --- memory-bound -----------------------------------------------------
    n = (1 << 22) if quick else (1 << 24)
    x = jnp.arange(n, dtype=jnp.float32)
    y = jnp.ones((n,), jnp.float32)
    fc = jax.jit(lambda v: v * 1.0)
    fa = jax.jit(lambda a, b: a + b)
    fr = jax.jit(lambda v: jnp.sum(v))
    add_case(Workload(name="vec_copy", wclass="memory", flops=0.0,
                      bytes=8.0 * n, precision="fp32",
                      working_set_bytes=8.0 * n),
             lambda: fc(x))
    add_case(Workload(name="vec_add", wclass="memory", flops=float(n),
                      bytes=12.0 * n, precision="fp32",
                      working_set_bytes=12.0 * n),
             lambda: fa(x, y))
    add_case(Workload(name="reduction", wclass="memory", flops=float(n),
                      bytes=4.0 * n, precision="fp32",
                      working_set_bytes=4.0 * n),
             lambda: fr(x))
    m2 = 1024 if quick else 2048
    t2 = jax.random.normal(key, (m2, m2), jnp.float32)
    ft = jax.jit(lambda v: v.T.copy() if hasattr(v.T, "copy")
                 else jnp.transpose(v) + 0.0)
    ft = jax.jit(lambda v: jnp.transpose(v) + 0.0)
    add_case(Workload(name="transpose_2d", wclass="memory",
                      flops=float(m2 * m2), bytes=8.0 * m2 * m2,
                      precision="fp32", working_set_bytes=8.0 * m2 * m2),
             lambda: ft(t2))

    # --- compute-bound ----------------------------------------------------
    for gn in ((256, 512) if quick else (512, 1024, 2048)):
        a = jax.random.normal(key, (gn, gn), jnp.float32)
        b = jax.random.normal(key, (gn, gn), jnp.float32)
        fm = jax.jit(lambda p, q: p @ q)
        add_case(Workload(name=f"gemm_{gn}", wclass="compute",
                          flops=2.0 * gn ** 3, bytes=12.0 * gn * gn,
                          precision="fp32", matrix=True,
                          working_set_bytes=12.0 * gn * gn),
                 (lambda fm=fm, a=a, b=b: fm(a, b)))

    # --- balanced ----------------------------------------------------------
    nb = (1 << 20) if quick else (1 << 22)
    xb = jnp.linspace(0.0, 1.0, nb, dtype=jnp.float32)

    def bal(v):
        for _ in range(8):
            v = v * v + 0.1
        return v
    fb = jax.jit(bal)
    add_case(Workload(name="poly_chain", wclass="balanced",
                      flops=16.0 * nb, bytes=8.0 * nb, precision="fp32",
                      working_set_bytes=8.0 * nb),
             lambda: fb(xb))

    # --- stencil -----------------------------------------------------------
    sg = 512 if quick else 1024
    grid = jax.random.normal(key, (sg, sg), jnp.float32)

    def stencil(g):
        return (g
                + 0.1 * (jnp.roll(g, 1, 0) + jnp.roll(g, -1, 0)
                         + jnp.roll(g, 1, 1) + jnp.roll(g, -1, 1)
                         - 4.0 * g))
    fs = jax.jit(stencil)
    add_case(Workload(name="hotspot_like_stencil", wclass="stencil",
                      flops=7.0 * sg * sg, bytes=8.0 * sg * sg,
                      precision="fp32", working_set_bytes=8.0 * sg * sg),
             lambda: fs(grid))

    workloads = [w for w, _ in cases]
    measured = []
    for _, thunk in cases:
        def run(thunk=thunk):
            thunk().block_until_ready()
        med, _ = measure_median(run, repeats=reps, warmups=warm)
        measured.append(med)
    return workloads, measured


def host_suite_result(*, quick: bool = True) -> MeasuredSuite:
    """``host_suite`` packaged as a wire-shippable :class:`MeasuredSuite`
    (what ``PredictionClient.calibrate`` uploads)."""
    workloads, measured = host_suite(quick=quick)
    return MeasuredSuite(name="host_suite", workloads=workloads,
                         measured_s=measured,
                         meta={"quick": 1.0 if quick else 0.0})

"""Hardware parameter registry.

Every coefficient in the analytical models is tied either to a
microbenchmark measurement or a vendor datasheet (paper Tables II and VII).
This module is the single source of truth for those values.

Since PR 6 the values themselves live as **data files** under
``core/hwdata/*.json`` (one schema-validated document per accelerator —
see ``core/hwlib.py`` for the schema, loader and diff tool), loaded
lazily by the registry below.  Adding an accelerator is a data entry,
not a code change: the paper's B200→H200 / MI300A→MI250X ports swap
parameter files, not formulas (Obs. 6, §V-E).

Parameter files distinguish PEAK (datasheet) from SUSTAINED (microbenchmark)
values for bandwidth and compute throughput, per paper §V-A ("Datasheet peaks
are not the sole inputs for validation"); each file's ``provenance``
section mirrors paper Table II's Source column.

Units: seconds, bytes, FLOP/s, bytes/s unless suffixed otherwise.

The classic preset names (``hardware.B200`` ... ``hardware.CPU_HOST``)
remain importable; they resolve through the registry, so every caller
shares one instance per entry (which keeps ``core.sweep.hardware_key``'s
per-instance token stash effective).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from collections.abc import MutableMapping
from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional

# ---------------------------------------------------------------------------
# Precision handling
# ---------------------------------------------------------------------------

BYTES_PER_ELEM = {
    "fp64": 8,
    "fp32": 4,
    "tf32": 4,
    "bf16": 2,
    "fp16": 2,
    "fp8": 1,
    "int8": 1,
    "fp6": 0.75,
    "fp4": 0.5,
}


@dataclass(frozen=True)
class CacheLevel:
    """One level of the memory hierarchy (paper Eq. 10 walk)."""

    name: str
    capacity_bytes: float
    latency_cycles: float
    bandwidth: float  # bytes/s, sustained


@dataclass(frozen=True)
class HardwareParams:
    """Parameter file for one accelerator.

    Fields map 1:1 onto paper Tables II / VII rows; the data file's
    ``provenance`` section records whether each came from a microbenchmark
    or a datasheet (Table II's Source column) for audit.
    """

    name: str
    vendor: str                      # "nvidia" | "amd" | "google" | "host"
    model_family: str                # "blackwell" | "cdna" | "tpu" | "generic"

    # --- compute units -----------------------------------------------------
    num_sms: int                     # SMs / CUs / TensorCores-per-chip
    warp_size: int
    max_resident_warps: int          # per SM/CU (microbench)
    clock_ghz: float

    # --- matrix-unit throughput: peak (datasheet) and sustained (microbench)
    # keyed by precision string.  FLOP/s for the WHOLE chip.
    tensor_peak_flops: Dict[str, float]
    tensor_sustained_flops: Dict[str, float]
    # vector (non-matrix) path throughput, FLOP/s whole chip
    vector_peak_flops: Dict[str, float] = field(default_factory=dict)
    vector_sustained_flops: Dict[str, float] = field(default_factory=dict)

    # --- memory ------------------------------------------------------------
    hbm_peak_bw: float = 0.0         # datasheet
    hbm_sustained_bw: float = 0.0    # microbench
    hbm_capacity: float = 0.0
    cache_levels: tuple = ()         # tuple[CacheLevel, ...] L1->LLC order
    hbm_latency_cycles: float = 0.0

    # --- accumulator / scratch space (TMEM on B200, LDS on MI300A, VMEM on TPU)
    accum_capacity_bytes: float = 0.0     # per SM/CU/core
    accum_read_bw: float = 0.0            # bytes/s whole chip (TMEM read)
    accum_write_bw: float = 0.0           # bytes/s whole chip (TMEM write)

    # --- Blackwell stage-model coefficients (microbench, Table VII) --------
    tma_latency_cycles: float = 0.0       # L_TMA = 420 cyc
    tma_bandwidth: float = 0.0            # B_TMA bytes/s
    mma_latency_cycles: float = 0.0       # tcgen05.mma 11-14 cyc
    mbarrier_latency_cycles: float = 0.0  # L_mbar 40-50 cyc
    commit_latency_cycles: float = 0.0    # L_commit
    decomp_engine_rate: float = 0.0       # R_DE bytes/s
    decomp_efficiency: float = 0.9        # eta_DE
    two_sm_speedup: float = 1.0           # S_2SM measured
    tmem_alloc_latency_s: float = 0.0     # L_alloc + L_dealloc (amortized)

    # --- CDNA wavefront-model coefficients ---------------------------------
    vgpr_per_cu: int = 0                  # 65536 on CDNA3
    llc_transition_alpha: float = 1.0     # Table III alpha
    llc_transition_beta: float = 1.0      # Table III beta
    llc_resident_mb: float = 205.0        # Table III boundary
    llc_capacity_mb: float = 256.0
    coherence_latency_s: float = 0.0      # 100-200 ns
    cross_xcd_latency_s: float = 0.0      # 50-100 ns NUMA
    mfma_utilization: float = 0.55        # Table IV: Util 0.4-0.7

    # --- interference / concurrency (paper §IV-A6, §IV-B) ------------------
    tau_interference_s: float = 0.0       # per extra concurrent kernel (50us MI300A)
    tau_interference_gpu_s: float = 0.0   # per extra device
    tau_fusion_s: float = 0.0             # fusion overhead

    # --- launch / host ------------------------------------------------------
    launch_latency_s: float = 5e-6        # kernel launch overhead
    h2d_bandwidth: float = 45e9           # B_eff H2D (Table VII default)
    d2h_bandwidth: float = 45e9
    tau_memcpy_s: float = 2e-6            # Table VII defaults
    tau_sync_s: float = 3e-6

    # --- generic-path knobs (paper §IV-F) -----------------------------------
    working_set_scale_bytes: float = 0.0  # w0 in Eq. 16; <=0 disables blend
    class_scales: Dict[str, float] = field(
        default_factory=lambda: {
            "memory": 1.0, "compute": 1.0, "balanced": 1.0, "stencil": 1.0}
    )
    precision_efficiency: Dict[str, float] = field(default_factory=dict)

    # --- interconnect (TPU extension; absent from the paper) ----------------
    ici_link_bw: float = 0.0              # bytes/s per link per direction
    ici_links_per_axis: int = 1
    dci_link_bw: float = 0.0              # cross-pod link
    pipeline_overlap_alpha: float = 0.9   # paper alpha in [0.85, 0.95]

    def cycles_to_seconds(self, cycles: float) -> float:
        return cycles / (self.clock_ghz * 1e9)

    def peak_flops(self, precision: str = "fp16", matrix: bool = True) -> float:
        if precision not in BYTES_PER_ELEM:
            raise KeyError(
                f"no peak flops for {precision!r} on {self.name}: unknown "
                f"precision (known: {sorted(BYTES_PER_ELEM)})")
        table = self.tensor_peak_flops if matrix else self.vector_peak_flops
        if precision in table:
            return table[precision]
        # fall back: scale from a known precision by byte ratio (wider = slower)
        if matrix and self.tensor_peak_flops:
            base_prec, base = next(iter(self.tensor_peak_flops.items()))
            return base * BYTES_PER_ELEM[base_prec] / BYTES_PER_ELEM[precision]
        raise KeyError(f"no peak flops for {precision} on {self.name}")

    def sustained_flops(self, precision: str = "fp16", matrix: bool = True) -> float:
        table = (self.tensor_sustained_flops if matrix
                 else self.vector_sustained_flops)
        if precision in table:
            return table[precision]
        return self.peak_flops(precision, matrix)

    def with_updates(self, **kw) -> "HardwareParams":
        """Parameter-file portability (paper Obs. 6): new GPU = new values,
        same formulas."""
        return dataclasses.replace(self, **kw)

    def __getstate__(self):
        """Strip process-local caches before pickling.

        ``core.sweep.hardware_key`` stashes its interned ``(name, id)``
        content token on the instance; the token is only meaningful
        against the interning process's own table.  Default pickling
        would ship it to spawn/forkserver workers (``core.parallel``,
        the serve worker pool), where a fresh intern table hands out the
        same ids for *different* parameter content — a stale inherited
        token could then collide with a live one and mix cache entries
        across hardware.  Workers must always re-derive the token from
        content."""
        return {k: v for k, v in self.__dict__.items()
                if k != "_sweep_content_token"}


# ---------------------------------------------------------------------------
# The registry: lazily backed by core/hwdata/*.json.
# ---------------------------------------------------------------------------

DATA_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "hwdata")


class _LazyRegistry(MutableMapping):
    """name -> HardwareParams, loading data files on first access.

    Iteration and membership see the union of already-loaded/registered
    entries and the on-disk library without parsing any file; an entry's
    JSON is validated and decoded exactly once (``get()`` then always
    returns that same instance, which keeps the sweep cache's
    per-instance token stash effective).  Thread-safe; the data directory
    is scanned once per process.
    """

    def __init__(self, data_dir: str = DATA_DIR):
        self._data_dir = data_dir
        self._loaded: Dict[str, HardwareParams] = {}
        self._files: Optional[Dict[str, str]] = None
        self._removed: set = set()
        self._lock = threading.RLock()

    def _scan(self) -> Dict[str, str]:
        files = self._files
        if files is None:
            files = {}
            if os.path.isdir(self._data_dir):
                for fn in sorted(os.listdir(self._data_dir)):
                    if fn.endswith(".json"):
                        files[fn[:-5]] = os.path.join(self._data_dir, fn)
            self._files = files
        return files

    def __getitem__(self, name: str) -> HardwareParams:
        with self._lock:
            p = self._loaded.get(name)
            if p is not None:
                return p
            if name in self._removed:
                raise KeyError(name)
            path = self._scan().get(name)
            if path is None:
                raise KeyError(name)
            from . import hwlib  # deferred: hwlib imports this module
            p = hwlib.load_file(path).params
            self._loaded[name] = p
            return p

    def __setitem__(self, name: str, params: HardwareParams) -> None:
        with self._lock:
            self._removed.discard(name)
            self._loaded[name] = params

    def __delitem__(self, name: str) -> None:
        with self._lock:
            if name not in self:
                raise KeyError(name)
            self._loaded.pop(name, None)
            if name in self._scan():
                self._removed.add(name)   # tombstone the file-backed entry

    def __contains__(self, name) -> bool:
        with self._lock:
            if name in self._loaded:
                return True
            return name not in self._removed and name in self._scan()

    def __iter__(self) -> Iterator[str]:
        with self._lock:
            names = (set(self._loaded) | set(self._scan())) - self._removed
        return iter(sorted(names))

    def __len__(self) -> int:
        with self._lock:
            return len((set(self._loaded) | set(self._scan()))
                       - self._removed)


REGISTRY: MutableMapping = _LazyRegistry()

# fork() clones one thread: another thread mid-load would leave the
# child's registry lock held forever (the FORK-LOCK contract).  Loaded
# params are immutable so the child keeps them; only the lock re-inits.
os.register_at_fork(
    after_in_child=lambda: setattr(REGISTRY, "_lock", threading.RLock()))


def get(name: str) -> HardwareParams:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware '{name}'; known: {sorted(REGISTRY)}") from None


def register(params: HardwareParams, *, overwrite: bool = False) -> None:
    """Add a parameter file to the registry.

    Collisions raise: a typo'd or malicious entry must not silently
    shadow a shipped one (``b200`` et al. count — the library's data
    files are part of the namespace even before they're loaded).  Pass
    ``overwrite=True`` for intentional replacement, e.g. re-registering
    a re-calibrated ``cpu_host_measured``.
    """
    if not isinstance(params, HardwareParams):
        raise TypeError(f"register() takes a HardwareParams, got "
                        f"{type(params).__name__}")
    if not overwrite and params.name in REGISTRY:
        raise ValueError(
            f"hardware '{params.name}' is already registered; pass "
            f"overwrite=True to replace it")
    REGISTRY[params.name] = params


# Classic preset attribute names resolve through the registry (module
# ``__getattr__``): ``hardware.B200`` lazy-loads hwdata/b200.json once.
_PRESET_ATTRS = {
    "B200": "b200", "H200": "h200", "MI300A": "mi300a",
    "MI250X": "mi250x", "TPU_V5E": "tpu_v5e", "CPU_HOST": "cpu_host",
}


def __getattr__(name: str):
    key = _PRESET_ATTRS.get(name)
    if key is not None:
        return get(key)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Hardware parameter registry.

Every coefficient in the analytical models is tied either to a
microbenchmark measurement or a vendor datasheet (paper Tables II and VII).
This module is the single source of truth for those values.

Parameter files distinguish PEAK (datasheet) from SUSTAINED (microbenchmark)
values for bandwidth and compute throughput, per paper §V-A ("Datasheet peaks
are not the sole inputs for validation").

Units: seconds, bytes, FLOP/s, bytes/s unless suffixed otherwise.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional

# ---------------------------------------------------------------------------
# Precision handling
# ---------------------------------------------------------------------------

BYTES_PER_ELEM = {
    "fp64": 8,
    "fp32": 4,
    "tf32": 4,
    "bf16": 2,
    "fp16": 2,
    "fp8": 1,
    "int8": 1,
    "fp6": 0.75,
    "fp4": 0.5,
}


@dataclass(frozen=True)
class CacheLevel:
    """One level of the memory hierarchy (paper Eq. 10 walk)."""

    name: str
    capacity_bytes: float
    latency_cycles: float
    bandwidth: float  # bytes/s, sustained


@dataclass(frozen=True)
class HardwareParams:
    """Parameter file for one accelerator.

    Fields map 1:1 onto paper Tables II / VII rows; ``source`` records
    whether each came from a microbenchmark or a datasheet (Table II's
    Source column) for audit.
    """

    name: str
    vendor: str                      # "nvidia" | "amd" | "google" | "host"
    model_family: str                # "blackwell" | "cdna" | "tpu" | "generic"

    # --- compute units -----------------------------------------------------
    num_sms: int                     # SMs / CUs / TensorCores-per-chip
    warp_size: int
    max_resident_warps: int          # per SM/CU (microbench)
    clock_ghz: float

    # --- matrix-unit throughput: peak (datasheet) and sustained (microbench)
    # keyed by precision string.  FLOP/s for the WHOLE chip.
    tensor_peak_flops: Dict[str, float]
    tensor_sustained_flops: Dict[str, float]
    # vector (non-matrix) path throughput, FLOP/s whole chip
    vector_peak_flops: Dict[str, float] = field(default_factory=dict)
    vector_sustained_flops: Dict[str, float] = field(default_factory=dict)

    # --- memory ------------------------------------------------------------
    hbm_peak_bw: float = 0.0         # datasheet
    hbm_sustained_bw: float = 0.0    # microbench
    hbm_capacity: float = 0.0
    cache_levels: tuple = ()         # tuple[CacheLevel, ...] L1->LLC order
    hbm_latency_cycles: float = 0.0

    # --- accumulator / scratch space (TMEM on B200, LDS on MI300A, VMEM on TPU)
    accum_capacity_bytes: float = 0.0     # per SM/CU/core
    accum_read_bw: float = 0.0            # bytes/s whole chip (TMEM read)
    accum_write_bw: float = 0.0           # bytes/s whole chip (TMEM write)

    # --- Blackwell stage-model coefficients (microbench, Table VII) --------
    tma_latency_cycles: float = 0.0       # L_TMA = 420 cyc
    tma_bandwidth: float = 0.0            # B_TMA bytes/s
    mma_latency_cycles: float = 0.0       # tcgen05.mma 11-14 cyc
    mbarrier_latency_cycles: float = 0.0  # L_mbar 40-50 cyc
    commit_latency_cycles: float = 0.0    # L_commit
    decomp_engine_rate: float = 0.0       # R_DE bytes/s
    decomp_efficiency: float = 0.9        # eta_DE
    two_sm_speedup: float = 1.0           # S_2SM measured
    tmem_alloc_latency_s: float = 0.0     # L_alloc + L_dealloc (amortized)

    # --- CDNA wavefront-model coefficients ---------------------------------
    vgpr_per_cu: int = 0                  # 65536 on CDNA3
    llc_transition_alpha: float = 1.0     # Table III alpha
    llc_transition_beta: float = 1.0      # Table III beta
    llc_resident_mb: float = 205.0        # Table III boundary
    llc_capacity_mb: float = 256.0
    coherence_latency_s: float = 0.0      # 100-200 ns
    cross_xcd_latency_s: float = 0.0      # 50-100 ns NUMA
    mfma_utilization: float = 0.55        # Table IV: Util 0.4-0.7

    # --- interference / concurrency (paper §IV-A6, §IV-B) ------------------
    tau_interference_s: float = 0.0       # per extra concurrent kernel (50us MI300A)
    tau_interference_gpu_s: float = 0.0   # per extra device
    tau_fusion_s: float = 0.0             # fusion overhead

    # --- launch / host ------------------------------------------------------
    launch_latency_s: float = 5e-6        # kernel launch overhead
    h2d_bandwidth: float = 45e9           # B_eff H2D (Table VII default)
    d2h_bandwidth: float = 45e9
    tau_memcpy_s: float = 2e-6            # Table VII defaults
    tau_sync_s: float = 3e-6

    # --- generic-path knobs (paper §IV-F) -----------------------------------
    working_set_scale_bytes: float = 0.0  # w0 in Eq. 16; <=0 disables blend
    class_scales: Dict[str, float] = field(
        default_factory=lambda: {
            "memory": 1.0, "compute": 1.0, "balanced": 1.0, "stencil": 1.0}
    )
    precision_efficiency: Dict[str, float] = field(default_factory=dict)

    # --- interconnect (TPU extension; absent from the paper) ----------------
    ici_link_bw: float = 0.0              # bytes/s per link per direction
    ici_links_per_axis: int = 1
    dci_link_bw: float = 0.0              # cross-pod link
    pipeline_overlap_alpha: float = 0.9   # paper alpha in [0.85, 0.95]

    def cycles_to_seconds(self, cycles: float) -> float:
        return cycles / (self.clock_ghz * 1e9)

    def peak_flops(self, precision: str = "fp16", matrix: bool = True) -> float:
        table = self.tensor_peak_flops if matrix else self.vector_peak_flops
        if precision in table:
            return table[precision]
        # fall back: scale from a known precision by byte ratio (wider = slower)
        if matrix and self.tensor_peak_flops:
            base_prec, base = next(iter(self.tensor_peak_flops.items()))
            return base * BYTES_PER_ELEM[base_prec] / BYTES_PER_ELEM[precision]
        raise KeyError(f"no peak flops for {precision} on {self.name}")

    def sustained_flops(self, precision: str = "fp16", matrix: bool = True) -> float:
        table = (self.tensor_sustained_flops if matrix
                 else self.vector_sustained_flops)
        if precision in table:
            return table[precision]
        return self.peak_flops(precision, matrix)

    def with_updates(self, **kw) -> "HardwareParams":
        """Parameter-file portability (paper Obs. 6): new GPU = new values,
        same formulas."""
        return dataclasses.replace(self, **kw)

    def __getstate__(self):
        """Strip process-local caches before pickling.

        ``core.sweep.hardware_key`` stashes its interned ``(name, id)``
        content token on the instance; the token is only meaningful
        against the interning process's own table.  Default pickling
        would ship it to spawn/forkserver workers (``core.parallel``,
        the serve worker pool), where a fresh intern table hands out the
        same ids for *different* parameter content — a stale inherited
        token could then collide with a live one and mix cache entries
        across hardware.  Workers must always re-derive the token from
        content."""
        return {k: v for k, v in self.__dict__.items()
                if k != "_sweep_content_token"}


# ---------------------------------------------------------------------------
# Parameter files.  Values from paper Tables II, VII, VIII and §III.
# ---------------------------------------------------------------------------

B200 = HardwareParams(
    name="b200",
    vendor="nvidia",
    model_family="blackwell",
    num_sms=176,
    warp_size=32,
    max_resident_warps=64,
    clock_ghz=1.8,
    # Table II: 2,250 TFLOPS FP16 peak, 4,500 FP8; §II: sustained 1,100-1,400
    # FP16.  FP8 sustained inferred from the paper's own measured GEMM point
    # (16384^3 in 4.10 ms end-to-end => MMA-stage rate ~3,050 TF/s once the
    # stage model's sync/TMEM overheads are separated out).
    tensor_peak_flops={"fp16": 2250e12, "bf16": 2250e12, "fp8": 4500e12,
                       "fp4": 9000e12, "tf32": 1100e12, "fp64": 40e12},
    tensor_sustained_flops={"fp16": 1400e12, "bf16": 1400e12, "fp8": 3050e12,
                            "fp4": 5600e12, "fp64": 37e12},
    vector_peak_flops={"fp32": 75e12, "fp64": 37e12},
    vector_sustained_flops={"fp32": 60e12, "fp64": 30e12},
    # §II: sustained HBM 6.8-7.1 TB/s vs 8.0 datasheet
    hbm_peak_bw=8.0e12,
    hbm_sustained_bw=6.95e12,
    hbm_capacity=192e9,
    hbm_latency_cycles=600,
    cache_levels=(
        CacheLevel("l1", 256 * 1024, 30, 40e12),
        CacheLevel("l2", 64 * 1024 * 1024, 200, 12e12),
    ),
    # TMEM: 256 KB/SM; Table VII: 16/8 TB/s read/write as the conservative
    # default; §V-B(c): "TMEM at 22 TB/s is conservative (24-26 TB/s in
    # tuned kernels reduces error to 2-3%)" — we use the tuned values since
    # the validation GEMMs are cuBLAS-tuned.
    accum_capacity_bytes=256 * 1024,
    accum_read_bw=24e12,
    accum_write_bw=12e12,
    # Table VII microbench values
    tma_latency_cycles=420,
    tma_bandwidth=6.5e12,          # effective TMA BW, L2-dependent
    mma_latency_cycles=12.5,       # tcgen05.mma 11-14 cyc midpoint
    mbarrier_latency_cycles=40,    # L_mbar 40-50 (lower end: tuned kernels)
    commit_latency_cycles=45,      # L_commit 40-50
    decomp_engine_rate=800e9,
    decomp_efficiency=0.9,
    two_sm_speedup=1.30,           # predicted/measured §V-B(c)
    tmem_alloc_latency_s=1.0e-6,
    launch_latency_s=8e-6,         # 5-12us observed (§V-B(c))
    pipeline_overlap_alpha=0.92,   # alpha in [0.85, 0.95]
    working_set_scale_bytes=48e6,  # L2-ish scale for Eq. 16 blend
    precision_efficiency={"fp16": 1.0, "bf16": 1.0, "fp8": 1.0, "fp4": 0.9,
                          "fp64": 1.0, "fp32": 1.0},
)

H200 = B200.with_updates(
    # Paper §IV-B end + §V-E: same model framework, updated parameters only.
    name="h200",
    num_sms=132,
    hbm_peak_bw=4.8e12,
    hbm_sustained_bw=4.2e12,     # Obs. 4: ~4.2 TB/s sustained
    hbm_capacity=141e9,
    tensor_peak_flops={"fp16": 989e12, "bf16": 989e12, "fp8": 1979e12,
                       "tf32": 494e12, "fp64": 67e12},
    tensor_sustained_flops={"fp16": 700e12, "bf16": 700e12, "fp8": 1400e12,
                            "fp64": 60e12},
    # Hopper: no TMEM; accumulators in RF/SMEM -> model uses SMEM-as-accum
    accum_capacity_bytes=228 * 1024,
    accum_read_bw=9e12,
    accum_write_bw=4.5e12,
    tma_bandwidth=4.0e12,
    two_sm_speedup=1.0,          # no 2-SM UMMA pairs on Hopper
    cache_levels=(
        CacheLevel("l1", 256 * 1024, 30, 30e12),
        CacheLevel("l2", 50 * 1024 * 1024, 220, 9e12),
    ),
)

MI300A = HardwareParams(
    name="mi300a",
    vendor="amd",
    model_family="cdna",
    num_sms=304,                   # 38 CU x 8 XCD
    warp_size=64,
    max_resident_warps=32,
    clock_ghz=2.1,
    # Table II: FP8 1,307 TFLOPS; FP64 61.3 (SPEChpc roofline uses 30.4
    # no-FMA).  NOTE on sustained values: the CDNA model's Eq. 12 divides
    # (T_mem + T_comp) by (1 + eta_overlap), so T_compute is the
    # PER-WAVEFRONT-SERIAL issue time; end-to-end throughput = serial rate
    # x (1 + eta).  Sustained numbers below are therefore the measured
    # serial-issue rates (~ peak * Util / 2 with eta -> 1 at the measured
    # 0.4-0.7 utilization band).
    tensor_peak_flops={"fp8": 1307e12, "fp16": 653e12, "bf16": 653e12,
                       "tf32": 163e12, "fp32": 122e12, "fp64": 61.3e12},
    tensor_sustained_flops={"fp8": 560e12, "fp16": 280e12, "bf16": 280e12,
                            "fp32": 52e12, "fp64": 23e12},
    vector_peak_flops={"fp32": 61.3e12, "fp64": 30.4e12},
    vector_sustained_flops={"fp32": 45e12, "fp64": 24e12},
    hbm_peak_bw=5.3e12,
    hbm_sustained_bw=4.6e12,
    hbm_capacity=128e9,
    hbm_latency_cycles=400,        # Table VII L_HBM
    cache_levels=(
        # Table VII: L1/L2/LLC latency 5/50/150 cyc; LLC (Infinity Cache)
        # BW 17.2 TB/s (microbench).
        CacheLevel("l1", 32 * 1024, 5, 50e12),
        CacheLevel("l2", 4 * 1024 * 1024, 50, 25e12),
        CacheLevel("llc", 256 * 1024 * 1024, 150, 17.2e12),
    ),
    accum_capacity_bytes=64 * 1024,   # LDS 64 KB/CU (Table II)
    accum_read_bw=10e12,
    accum_write_bw=10e12,
    vgpr_per_cu=65536,
    llc_transition_alpha=1.5,      # Table III alpha (calibrated)
    llc_transition_beta=0.85,      # Table III beta
    llc_resident_mb=205.0,
    llc_capacity_mb=256.0,
    coherence_latency_s=150e-9,    # Table IV: 100-200 ns
    cross_xcd_latency_s=75e-9,     # §III: 50-100 ns
    mfma_utilization=0.55,         # Table IV 0.4-0.7
    tau_interference_s=50e-6,      # Table VII tuned
    tau_interference_gpu_s=100e-6,
    tau_fusion_s=2e-6,
    launch_latency_s=6e-6,
    pipeline_overlap_alpha=0.85,
    working_set_scale_bytes=200e6,
    precision_efficiency={"fp64": 1.0, "fp32": 1.0, "fp16": 0.95,
                          "bf16": 0.95, "fp8": 0.9},
)

MI250X = MI300A.with_updates(
    # §IV-B end: same CDNA framework; own FP64 peak (383 TFLOPS matrix),
    # bandwidth 3.2 TB/s, 128 MB LLC, 220 CUs.
    name="mi250x",
    num_sms=220,
    # paper §IV-B: "own peak FP64 (383 TFLOPS)" — read as the FP16 matrix
    # peak; FP64 matrix peak is 95.7 TFLOPS (vendor datasheet).  FP64
    # sustained serial-issue rate calibrated against the paper's published
    # point: dgemm 16384^3 measured = predicted = 0.283 s
    # (=> 8.8 TFLOP / 0.283 s / (1+eta) with eta=1 -> ~15.6 TF/s serial).
    tensor_peak_flops={"fp16": 383e12, "bf16": 383e12, "fp64": 95.7e12,
                       "fp32": 95.7e12},
    tensor_sustained_flops={"fp16": 150e12, "bf16": 150e12,
                            "fp32": 38e12, "fp64": 15.55e12},
    vector_peak_flops={"fp32": 47.9e12, "fp64": 47.9e12},
    vector_sustained_flops={"fp32": 19e12, "fp64": 19e12},
    hbm_peak_bw=3.2e12,
    hbm_sustained_bw=2.8e12,
    hbm_capacity=128e9,
    cache_levels=(
        CacheLevel("l1", 16 * 1024, 5, 30e12),
        CacheLevel("l2", 8 * 1024 * 1024, 60, 12e12),
        CacheLevel("llc", 128 * 1024 * 1024, 170, 7e12),
    ),
    llc_resident_mb=102.0,
    llc_capacity_mb=128.0,
    coherence_latency_s=0.0,       # discrete GPU, no APU coherence term
    cross_xcd_latency_s=90e-9,     # dual-GCD
)

# ---------------------------------------------------------------------------
# TPU v5e: our deployment target (hardware-adaptation of the paper's models).
# Constants per task spec: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
# ---------------------------------------------------------------------------

TPU_V5E = HardwareParams(
    name="tpu_v5e",
    vendor="google",
    model_family="tpu",
    num_sms=1,                     # one TensorCore per v5e chip
    warp_size=128,                 # VPU lane width (8x128) - nearest analogue
    max_resident_warps=1,          # no occupancy concept
    clock_ghz=1.6,
    tensor_peak_flops={"bf16": 197e12, "fp16": 197e12, "int8": 394e12,
                       "fp8": 394e12, "fp32": 49e12},
    # MXU sustained ~ 0.85 of peak for well-aligned shapes (mult of 128/256)
    tensor_sustained_flops={"bf16": 167e12, "fp16": 167e12, "int8": 335e12,
                            "fp32": 42e12},
    vector_peak_flops={"fp32": 3.2e12, "bf16": 6.4e12},
    vector_sustained_flops={"fp32": 2.7e12, "bf16": 5.4e12},
    hbm_peak_bw=819e9,
    hbm_sustained_bw=740e9,        # ~90% achievable on streaming
    hbm_capacity=16e9,
    hbm_latency_cycles=500,
    cache_levels=(),               # no big LLC: VMEM is software-managed
    # VMEM = the TPU analogue of TMEM (accumulators + staged tiles)
    accum_capacity_bytes=128 * 1024 * 1024,
    accum_read_bw=23e12,           # VMEM<->MXU effective
    accum_write_bw=11e12,
    tma_latency_cycles=800,        # DMA issue latency analogue
    tma_bandwidth=740e9,           # DMA rides HBM sustained BW
    mbarrier_latency_cycles=60,    # semaphore wait analogue
    commit_latency_cycles=60,
    two_sm_speedup=1.0,
    launch_latency_s=2e-6,         # XLA dispatch per program
    pipeline_overlap_alpha=0.90,   # Mosaic double-buffers DMA like TMA alpha
    working_set_scale_bytes=96e6,  # VMEM-residency scale for Eq. 16 blend
    precision_efficiency={"bf16": 1.0, "fp32": 1.0, "int8": 0.95, "fp8": 0.95},
    # Interconnect (per task spec: ~50 GB/s/link; v5e 2D torus, 1 link/axis
    # direction pair here modeled as aggregate per-axis bandwidth).
    ici_link_bw=50e9,
    ici_links_per_axis=1,
    dci_link_bw=12.5e9,            # cross-pod optics, ~ICI/4 (assumption)
    tau_interference_s=10e-6,      # straggler/multi-slice budget term
    tau_interference_gpu_s=25e-6,
)

# ---------------------------------------------------------------------------
# CPU-host: parameter file SELF-CALIBRATED by core/microbench.py at runtime.
# Placeholder values here; microbench.calibrate_host() returns a measured one.
# ---------------------------------------------------------------------------

CPU_HOST = HardwareParams(
    name="cpu_host",
    vendor="host",
    model_family="generic",
    num_sms=1,
    warp_size=1,
    max_resident_warps=1,
    clock_ghz=2.5,
    tensor_peak_flops={"fp32": 200e9, "fp64": 100e9},
    tensor_sustained_flops={"fp32": 120e9, "fp64": 60e9},
    vector_peak_flops={"fp32": 100e9, "fp64": 50e9},
    vector_sustained_flops={"fp32": 60e9, "fp64": 30e9},
    hbm_peak_bw=30e9,
    hbm_sustained_bw=15e9,
    hbm_capacity=64e9,
    launch_latency_s=20e-6,
    pipeline_overlap_alpha=0.0,    # no async pipeline on host path
    working_set_scale_bytes=32e6,
)

REGISTRY: Dict[str, HardwareParams] = {
    p.name: p for p in (B200, H200, MI300A, MI250X, TPU_V5E, CPU_HOST)
}


def get(name: str) -> HardwareParams:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware '{name}'; known: {sorted(REGISTRY)}") from None


def register(params: HardwareParams) -> None:
    REGISTRY[params.name] = params

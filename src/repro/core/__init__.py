"""repro.core — the paper's contribution: microbenchmark-driven analytical
GPU/TPU performance models.

Public API:
    hardware.get(name) / hardware.REGISTRY     parameter files
    workload.Workload / Segment                characterization schema
    predict.predict(w, hw)                     unified routed prediction
    roofline.predict(w, hw)                    naive baseline
    blackwell / cdna3 / tpu / generic          per-architecture models
    calibrate.Calibration / fit_*              disclosed multipliers
    validate.validate_suite                    MAE harness
    segments.predict_app                       multi-segment applications
    collectives.MeshSpec / collective_time     mesh collective costs
    autotune.select_plan                       model-driven plan selection
    sweep.SweepEngine                          batched + memoized prediction
    workload.WorkloadTable                     columnar sweep batches
    workload.LatticeSpec                       lazy sweep lattices (chunked)
    sweep.argmin_table / topk_table            fused sweep reductions
    sweep.argmin_stream / topk_stream          streaming fused reductions
    parallel.reduce_sharded                    multi-worker sweep pricing
    microbench.calibrate_host                  real host microbenchmarks
"""
from . import (autotune, blackwell, cache, calibrate, cdna3, collectives,
               generic, hardware, parallel, predict, roofline, segments,
               sweep, tpu, validate, workload)

__all__ = [
    "autotune", "blackwell", "cache", "calibrate", "cdna3", "collectives",
    "generic", "hardware", "microbench", "parallel", "predict", "roofline",
    "segments", "sweep", "tpu", "validate", "workload",
]


def __getattr__(name):
    # microbench imports jax; keep it lazy so pure-model users stay light.
    if name == "microbench":
        import importlib
        mod = importlib.import_module(".microbench", __name__)
        globals()["microbench"] = mod
        return mod
    raise AttributeError(name)

"""Sharded sweep execution: price lattice shards across a worker pool.

The streaming reductions in ``core.sweep`` bound peak memory by pricing one
chunk at a time; this module adds the throughput half of the contract —
pricing scales with cores instead of leaving N-1 of them idle.  The lattice
row range is split into one contiguous shard per worker; each worker
streams its shard through its own cache-free ``SweepEngine`` and returns
its reducers; the parent merges the partials in shard order.  Merged
winners (index, total, tie-order, breakdown) are bit-identical to a
single-process reduction, which is itself bit-identical to the
materialized ``argmin_table``/``topk_table``/``pareto_table``.

Inputs cross the process boundary two ways:

  * ``LatticeSpec``s are tiny (a base workload + grid arrays) and are
    pickled; workers rebuild their chunks locally via the spec's vectorized
    index arithmetic — zero bulk column traffic.
  * already-built ``WorkloadTable``s (passed directly, the top-level
    source) export their columns into ``multiprocessing.shared_memory``
    once (``SharedTable``); workers attach zero-copy NumPy views, so no
    column bytes are pickled.  A built table nested inside a concat spec
    does NOT get this treatment — it travels inside the pickled spec, so
    pass big built tables directly (or concat them into one table first)
    when sharding.

Portability: the pool prefers the ``fork`` start method (cheapest on
Linux) but passes everything workers need as task arguments, so ``spawn``
/ ``forkserver`` work identically; once ``jax`` is loaded in the parent —
or the parent has ANY live helper thread (a multithreaded process can
hold a malloc/runtime mutex at fork time and deadlock the child; the
serve front end's HTTP handler threads hit exactly this) — the pool
switches to ``forkserver``, whose server process is launched fork+exec
clean and single-threaded, so its forks are safe.  When process pools are unusable at all
(sandboxed /dev/shm, missing semaphores) a thread pool runs the same shard
function in-process — NumPy releases the GIL on the large column kernels,
so threads still overlap.  Worker exceptions propagate to the caller
(``future.result()`` re-raises; a hard worker death surfaces as
``BrokenProcessPool``) — never a silent hang.  With a
``straggler_timeout_s``, a worker past its deadline (or a dead pool) gets
its shard re-dispatched once in the parent — safe because shard pricing
is a pure function and chunk reductions are idempotent and bit-identical
— and ``StragglerError`` surfaces only when both attempts die.  Forked
workers start with
cleared engine caches (``sweep._reinit_after_fork_in_child``) so parent
cache state is never trusted or mutated through copy-on-write.
"""
from __future__ import annotations

import math
import multiprocessing
import sys
import threading
import time
from concurrent.futures import BrokenExecutor, ProcessPoolExecutor, \
    ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from . import sweep as sweep_mod
from . import workload as workload_mod
from ..obs import metrics
from .hardware import HardwareParams

__all__ = ["SharedTable", "StragglerError", "WorkerPool", "map_jobs",
           "processes_available", "reduce_sharded", "reduce_sharded_multi",
           "resolve_jobs"]


# pool-level series (process registry; near-free when metrics are off)
_M_SHARD_S = metrics.histogram(
    "repro_pool_shard_seconds",
    "Shard wall clock from submit to worker completion")
_M_STRAGGLER = metrics.counter(
    "repro_pool_straggler_redispatch_total",
    "Shards re-dispatched in the parent after a straggler timeout or "
    "dead pool")


def _observe_shard(t_submit: float):
    def _cb(_fut) -> None:
        _M_SHARD_S.observe(time.monotonic() - t_submit)
    return _cb


class StragglerError(RuntimeError):
    """A shard failed on its worker AND on the in-parent re-dispatch.

    One straggler (a worker past ``straggler_timeout_s``) or a dead pool
    (``BrokenProcessPool``) is recovered transparently: the shard is
    re-run once in the parent — safe because ``_price_shard`` is pure and
    chunk reductions are idempotent and bit-identical, so a duplicated
    evaluation can only produce the same answer.  Only when that second
    attempt also dies does this error surface, naming the shard and both
    causes."""


def resolve_jobs(jobs=None) -> int:
    """CLI-flag policy: ``None``/0/"auto" -> ``os.cpu_count()``, else N.

    NOTE the deliberate asymmetry with ``sweep.effective_jobs``: at the
    sweep API (``argmin_stream(jobs=None)``) omitting ``jobs`` means
    SERIAL — parallelism is opt-in; calling into THIS module is already
    the opt-in, so here an omitted ``jobs`` means every core."""
    if jobs in (None, 0, "auto"):
        return sweep_mod.effective_jobs(0)
    return sweep_mod.effective_jobs(jobs)


# --------------------------------------------------------------------------
# Shared-memory column transport (zero-pickle table shipping).
# --------------------------------------------------------------------------

def _share_array(arr: np.ndarray):
    from multiprocessing import shared_memory
    shm = shared_memory.SharedMemory(create=True, size=max(arr.nbytes, 1))
    view = np.ndarray(arr.shape, dtype=arr.dtype, buffer=shm.buf)
    view[...] = arr
    return shm, (shm.name, arr.shape, str(arr.dtype))


class SharedTable:
    """A WorkloadTable's columns exported to POSIX shared memory.

    ``handle`` / ``window_handle(lo, hi)`` are small picklable descriptors;
    ``attach`` rebuilds a zero-copy table view in another process.  Window
    handles carry only the window's slice of the per-row ``names`` /
    ``hit_rates`` tuples, so sharding an n-row table pickles n small
    objects in total across all shards — never n per shard.  The creating
    process owns the segments: call ``close()`` + ``unlink()`` when the
    consumers are done.
    """

    def __init__(self, table: workload_mod.WorkloadTable):
        self._shms = []
        descs = []
        try:
            for arr in (table.cols, table.precision_codes,
                        table.wclass_codes):
                shm, desc = _share_array(np.ascontiguousarray(arr))
                self._shms.append(shm)
                descs.append(desc)
        except Exception:
            self.close(unlink=True)
            raise
        self._descs = tuple(descs)
        self._pv = table.precision_vocab
        self._wv = table.wclass_vocab
        self._names = table.names
        self._hit_rates = table.hit_rates
        self._name_offset = table.name_offset
        self.handle = ("shm_table", self._descs, self._pv, self._wv,
                       self._names, self._hit_rates, self._name_offset,
                       0, None)

    def window_handle(self, lo: int, hi: int):
        """Descriptor for rows [lo, hi): full shm arrays (sliced on
        attach), per-row metadata sliced here so only the window's share
        crosses the pickle boundary."""
        names = self._names
        offset = 0
        if isinstance(names, tuple):
            names = names[lo:hi]
        else:
            offset = self._name_offset + lo
        hr = self._hit_rates
        if hr is not None:
            hr = hr[lo:hi]
        return ("shm_table", self._descs, self._pv, self._wv, names, hr,
                offset, lo, hi)

    @staticmethod
    def attach(handle):
        """(table, shms) from a handle; caller closes the shms when done."""
        from multiprocessing import shared_memory
        _, descs, pv, wv, names, hr, offset, lo, hi = handle
        shms, arrs = [], []
        for name, shape, dtype in descs:
            shm = shared_memory.SharedMemory(name=name)
            shms.append(shm)
            a = np.ndarray(shape, dtype=np.dtype(dtype), buffer=shm.buf)
            a = a[lo:hi] if hi is not None else a[lo:]
            a.flags.writeable = False
            arrs.append(a)
        table = workload_mod.WorkloadTable(
            arrs[0], arrs[1], pv, arrs[2], wv, names, hr,
            name_offset=offset)
        return table, shms

    def close(self, unlink: bool = False) -> None:
        for shm in self._shms:
            try:
                shm.close()
                if unlink:
                    shm.unlink()
            except OSError:
                pass


# --------------------------------------------------------------------------
# Pool plumbing.
# --------------------------------------------------------------------------

_PROC_OK: Optional[bool] = None


def _probe() -> int:
    return 42


def _mp_context(allow_fork: bool = True):
    methods = multiprocessing.get_all_start_methods()
    if allow_fork and "fork" in methods and "jax" not in sys.modules \
            and threading.active_count() <= 1:
        return multiprocessing.get_context("fork")   # COW, no re-import
    if "forkserver" in methods:
        # forking a multithreaded process (jax loaded, or any live helper
        # thread — e.g. the serve front end's HTTP handlers) can deadlock
        # in a mutex some other thread held at fork time (malloc arenas,
        # runtime locks).  The forkserver's server process is launched
        # fork+exec clean and single-threaded, so its forks are safe — at
        # the cost of workers re-importing repro.core.
        return multiprocessing.get_context("forkserver")
    return multiprocessing.get_context("spawn")


def processes_available() -> bool:
    """One-shot probe that a worker process can actually start (sandboxes
    commonly break semaphores or /dev/shm); memoized per process."""
    global _PROC_OK
    if _PROC_OK is None:
        try:
            with ProcessPoolExecutor(max_workers=1,
                                     mp_context=_mp_context()) as ex:
                _PROC_OK = ex.submit(_probe).result() == 42
        except Exception:
            _PROC_OK = False
    return _PROC_OK


def _make_pool(njobs: int, use_threads: Optional[bool],
               allow_fork: bool = True):
    """(pool, is_processes).  ``use_threads`` forces the fallback."""
    if use_threads is None:
        use_threads = not processes_available()
    if use_threads:
        return ThreadPoolExecutor(max_workers=njobs), False
    return ProcessPoolExecutor(
        max_workers=njobs, mp_context=_mp_context(allow_fork)), True


class WorkerPool:
    """A reusable worker pool for repeated sharded reductions.

    ``reduce_sharded``/``reduce_sharded_multi`` normally build and tear
    down an executor per call — the right trade for one big sweep, and
    ~100ms of pure overhead per request for a serving front end that
    answers streamed-lattice queries all day.  A ``WorkerPool`` is that
    executor kept alive: pass it as the ``pool=`` argument (or through
    ``argmin_stream(..., pool=...)``) and the shard tasks reuse the same
    worker processes.  Shard workers never retain sweep state between
    tasks — each ``_price_shard`` call builds a fresh cache-free
    ``SweepEngine`` — so reuse cannot serve stale predictions.  Close
    (or use as a context manager) when done.
    """

    def __init__(self, jobs=None, use_threads: Optional[bool] = None,
                 straggler_timeout_s: Optional[float] = None):
        self.njobs = resolve_jobs(jobs)
        #: default per-shard deadline for reductions run through this
        #: pool: a worker past it is treated as a straggler and its shard
        #: re-dispatched once (see ``reduce_sharded_multi``); ``None``
        #: waits forever (the historical behavior)
        self.straggler_timeout_s = straggler_timeout_s
        self._use_threads = use_threads
        self._lock = threading.Lock()
        # never fork: ProcessPoolExecutor starts workers lazily at first
        # submit, so a fork approved while single-threaded here could
        # execute after the caller starts helper threads (the held-mutex
        # child deadlock _mp_context avoids).  Per-call reduce_sharded
        # pools submit immediately inside the same call, so only this
        # long-lived pool needs to give up COW for safety.
        self.executor, self.is_processes = _make_pool(
            self.njobs, use_threads, allow_fork=False)
        self._closed = False

    def recover(self, broken=None) -> None:
        """Replace a broken executor with a fresh one so the *next*
        reduction gets real workers again (a ``BrokenProcessPool`` poisons
        every future submitted to that executor forever).  ``broken``
        guards against concurrent recoveries rebuilding twice: the swap
        only happens if the live executor is still the one that broke."""
        with self._lock:
            if self._closed:
                return
            if broken is not None and self.executor is not broken:
                return
            old = self.executor
            self.executor, self.is_processes = _make_pool(
                self.njobs, self._use_threads, allow_fork=False)
        try:
            old.shutdown(wait=False, cancel_futures=True)
        except Exception:                   # noqa: BLE001 — best effort
            pass

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            _shutdown(self.executor)

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _shutdown(pool) -> None:
    try:
        pool.shutdown(wait=True, cancel_futures=True)
    except TypeError:                        # pragma: no cover (<3.9)
        pool.shutdown(wait=True)


def _open_source(payload):
    """Worker side: payload -> (spec, shms-to-close)."""
    if payload[0] == "shm_table":
        table, shms = SharedTable.attach(payload)
        return sweep_mod.as_spec(table), shms
    return payload[1], []


#: test seam for fault injection: when set, called as ``hook(lo, hi)`` at
#: the top of every shard evaluation.  Lets the fault-injection tests
#: make a specific shard hang or die inside a *threads* pool (process
#: workers re-import this module, so a monkeypatched hook never reaches
#: them — which is exactly why the straggler path needs the seam).
_SHARD_FAULT_HOOK: Optional[Callable[[int, int], None]] = None


def _price_shard(payload, hw: HardwareParams, passes: Sequence[Tuple],
                 lo: int, hi: int, offset_base: int,
                 chunk_size: int) -> List[Sequence]:
    """Worker body: stream rows [lo, hi) of the opened source through a
    private engine, once per (factories, model, calibration) pass, so one
    pool prices every route a caller needs (e.g. model + roofline)."""
    if _SHARD_FAULT_HOOK is not None:
        _SHARD_FAULT_HOOK(lo, hi)
    spec, shms = _open_source(payload)
    try:
        out = []
        for factories, model, calibration in passes:
            reducers = [f() for f in factories]
            sweep_mod.reduce_stream(
                spec, hw, reducers, chunk_size=chunk_size, model=model,
                calibration=calibration,
                engine=sweep_mod.SweepEngine(use_cache=False),
                lo=lo, hi=hi, offset_base=offset_base)
            out.append(reducers)
        return out
    finally:
        for shm in shms:
            shm.close()


def _shard_bounds(n: int, njobs: int, chunk_size: int) -> List[Tuple[int,
                                                                     int]]:
    """Contiguous per-worker row ranges, chunk-aligned so no worker pays a
    ragged sub-chunk in the middle of its shard."""
    chunks_total = math.ceil(n / chunk_size)
    per = math.ceil(chunks_total / njobs)
    bounds = []
    for j in range(njobs):
        lo = min(j * per * chunk_size, n)
        hi = min((j + 1) * per * chunk_size, n)
        if hi > lo:
            bounds.append((lo, hi))
    return bounds


def _shard_result(fut, task: Tuple, timeout_s: Optional[float],
                  pool: Optional["WorkerPool"], executor):
    """One shard's partials, with straggler/dead-worker recovery.

    ``timeout_s=None`` waits forever (historical behavior).  Otherwise a
    worker past the deadline — or a pool that died under it
    (``BrokenProcessPool``) — triggers ONE re-dispatch of the shard,
    executed synchronously in the parent: ``_price_shard`` is a pure
    function of its arguments and chunk reductions are idempotent and
    bit-identical, so pricing the shard twice can only yield the same
    partials (the abandoned worker's result, if it ever lands, is simply
    dropped with its future).  Genuine worker exceptions (a bad model
    name, a ValueError from the backend) propagate unchanged — retrying
    deterministic errors just doubles the cost of raising them.
    """
    if timeout_s is None:
        return fut.result()
    try:
        return fut.result(timeout=timeout_s)
    except (_FutTimeout, BrokenExecutor) as first:
        fut.cancel()
        _M_STRAGGLER.inc()
        if pool is not None and isinstance(first, BrokenExecutor):
            pool.recover(broken=executor)
        payload, hw, passes, lo, hi, base, size = task
        try:
            return _price_shard(payload, hw, passes, lo, hi, base, size)
        except BaseException as second:
            raise StragglerError(
                f"shard rows [{base + lo}, {base + hi}) failed twice: "
                f"worker attempt: {type(first).__name__}: {first}; "
                f"in-parent re-dispatch: {type(second).__name__}: "
                f"{second}") from second


def reduce_sharded(source, hw: HardwareParams,
                   factories: Sequence[Callable[[], object]], *,
                   jobs=None, chunk_size: Optional[int] = None,
                   model: Optional[str] = None,
                   calibration=None,
                   use_threads: Optional[bool] = None,
                   pool: Optional[WorkerPool] = None,
                   straggler_timeout_s: Optional[float] = None) -> Sequence:
    """Run the streaming reducers sharded across a worker pool.

    Returns the merged reducers (same shapes ``sweep.reduce_stream``
    returns); results are bit-identical to a serial reduction.  A worker
    exception (or a hard worker death) propagates to the caller.
    ``pool`` reuses a live ``WorkerPool`` instead of starting (and tearing
    down) an executor for this call.  ``straggler_timeout_s`` bounds each
    shard's wall clock: a straggling or dead worker gets its shard
    re-dispatched once in the parent (bit-identical — see
    ``_shard_result``), and ``StragglerError`` surfaces only when both
    attempts die.
    """
    return reduce_sharded_multi(
        source, hw, [(tuple(factories), model, calibration)], jobs=jobs,
        chunk_size=chunk_size, use_threads=use_threads, pool=pool,
        straggler_timeout_s=straggler_timeout_s)[0]


def reduce_sharded_multi(source, hw: HardwareParams,
                         passes: Sequence[Tuple], *,
                         jobs=None, chunk_size: Optional[int] = None,
                         use_threads: Optional[bool] = None,
                         pool: Optional[WorkerPool] = None,
                         straggler_timeout_s: Optional[float] = None
                         ) -> List[Sequence]:
    """``reduce_sharded`` for several (factories, model, calibration)
    passes over the same source: one pool (and one shared-memory export)
    prices every pass per shard — callers that need multiple routes (e.g.
    ``validate_suite``'s model + roofline columns) pay the pool start
    once.  Returns one merged reducer list per pass, in order."""
    spec = sweep_mod.as_spec(source)
    n = len(spec)
    size = int(chunk_size or workload_mod.DEFAULT_CHUNK_ROWS)
    if straggler_timeout_s is None and pool is not None:
        straggler_timeout_s = pool.straggler_timeout_s
    if pool is not None and jobs is None:
        jobs = pool.njobs
    njobs = min(resolve_jobs(jobs), max(1, math.ceil(n / size)))
    if njobs <= 1:
        return [sweep_mod.reduce_stream(
            spec, hw, [f() for f in factories], chunk_size=size,
            model=model, calibration=calibration,
            engine=sweep_mod.SweepEngine(use_cache=False))
            for factories, model, calibration in passes]

    bounds = _shard_bounds(n, njobs, size)
    procs_ok = pool.is_processes if pool is not None else (
        use_threads is not True and processes_available())
    shared = None
    if isinstance(spec, workload_mod._TableSpec) and procs_ok:
        try:
            shared = SharedTable(spec.table)
        except OSError:
            shared = None                    # pickle the table instead
    if shared is not None:
        # window payloads: shm arrays + only this shard's names/hit_rates
        tasks = [(shared.window_handle(lo, hi), 0, hi - lo, lo)
                 for lo, hi in bounds]
    else:
        tasks = [(("spec", spec), lo, hi, 0) for lo, hi in bounds]

    passes = [(tuple(fs), model, calibration)
              for fs, model, calibration in passes]
    if pool is not None:
        executor, owned = pool.executor, False
    else:
        executor, _procs = _make_pool(njobs, use_threads)
        owned = True
    try:
        futs = []
        for payload, lo, hi, base in tasks:
            t_submit = time.monotonic()
            f = executor.submit(_price_shard, payload, hw, passes,
                                lo, hi, base, size)
            f.add_done_callback(_observe_shard(t_submit))
            futs.append(f)
        partials = [
            _shard_result(f, (payload, hw, passes, lo, hi, base, size),
                          straggler_timeout_s, pool, executor)
            for f, (payload, lo, hi, base) in zip(futs, tasks)]
    finally:
        if owned:
            _shutdown(executor)
        if shared is not None:
            shared.close(unlink=True)

    merged = [list(reducers) for reducers in partials[0]]
    for part in partials[1:]:
        for merged_pass, part_pass in zip(merged, part):
            for r, p in zip(merged_pass, part_pass):
                r.merge(p)
    return merged


def map_jobs(fn: Callable, args_list: Sequence[Tuple], *,
             jobs=None, use_threads: Optional[bool] = None) -> List:
    """Order-preserving parallel map of ``fn(*args)`` over ``args_list``
    (generic shard runner for non-table work, e.g. plan pricing).  Serial
    when one worker suffices (a single task, or ``jobs=1``); an omitted
    ``jobs`` means every core (see ``resolve_jobs``).  Worker exceptions
    propagate."""
    if not args_list:
        return []
    njobs = min(resolve_jobs(jobs), len(args_list))
    if njobs <= 1:
        return [fn(*a) for a in args_list]
    pool, _procs = _make_pool(njobs, use_threads)
    try:
        futs = [pool.submit(fn, *a) for a in args_list]
        return [f.result() for f in futs]
    finally:
        _shutdown(pool)

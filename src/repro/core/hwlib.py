"""Declarative hardware library: schema-validated, file-loadable parameters.

The paper's central portability claim (Obs. 6, §V-E) is that the models
move across architectures by *swapping parameter files, not formulas* —
B200→H200 and MI300A→MI250X port with "no major restructuring".  This
module makes that literal: a ``HardwareParams`` is (de)serializable to a
plain JSON document, every shipped accelerator lives as a data file under
``core/hwdata/*.json``, and adding a new accelerator is a data entry, not
a code change.

File format (schema version 1)::

    {
      "schema_version": 1,
      "params":     { ... every HardwareParams field ... },
      "provenance": { "hbm_sustained_bw": "microbench", ... },
      "units":      { "hbm_sustained_bw": "bytes/s", ... },
      "source":     "free-text citation",
      "notes":      "free text"
    }

``params`` is the output of :func:`to_dict`: scalar fields verbatim,
per-precision throughput dicts as JSON objects, ``cache_levels`` as a
list of ``{name, capacity_bytes, latency_cycles, bandwidth}`` objects
(L1→LLC order; bytes / cycles / bytes-per-second).  JSON numbers
round-trip bit-exactly (Python's shortest-repr floats), so a loaded
entry predicts bit-identically to the constructor it replaced — the
golden parity tests in tests/test_hwlib.py pin this.

``provenance`` mirrors paper Table II's *Source* column: each tag records
whether a value was measured by a microbenchmark, copied from a vendor
datasheet, derived from another value, or assumed.  ``units`` entries are
optional redundancy: when present they must match the canonical unit the
schema assigns to that field (:data:`FIELD_UNITS`) — a file claiming
``"hbm_peak_bw": "GB/s"`` is rejected, because the loader cannot know
whether the *value* was scaled to match the wrong unit.

Validation errors raise :class:`HardwareSchemaError` with the file path
and the offending key; unknown field names include close-match
suggestions.  The process-wide cache token (``_sweep_content_token``,
stashed by ``core.sweep.hardware_key``) is never serialized — it is not a
dataclass field, and tests assert it never leaks into ``to_dict``.
"""
from __future__ import annotations

import dataclasses
import difflib
import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .hardware import BYTES_PER_ELEM, CacheLevel, HardwareParams

SCHEMA_VERSION = 1

#: model_family values the sweep router understands (core.sweep routes
#: blackwell->stage, cdna->wavefront, tpu->tpu, generic->generic).
KNOWN_FAMILIES = ("blackwell", "cdna", "tpu", "generic")

#: paper Table II "Source" column values, plus the two tags honest
#: parameter files need for values the paper/vendor never published.
PROVENANCE_TAGS = ("microbench", "datasheet", "derived", "assumed")

#: top-level keys a data file may carry.
DOC_KEYS = ("schema_version", "params", "provenance", "units", "source",
            "notes")

_NAME_RE = re.compile(r"^[a-z0-9_]+$")

#: canonical unit per field (the ``units`` section must agree).  Scalar
#: fields are seconds/bytes/FLOP-per-second/bytes-per-second exactly as
#: core/hardware.py documents; the two llc_*_mb boundary knobs keep the
#: paper Table III's megabyte convention.
FIELD_UNITS: Dict[str, str] = {
    "num_sms": "count", "warp_size": "count",
    "max_resident_warps": "count", "vgpr_per_cu": "count",
    "ici_links_per_axis": "count",
    "clock_ghz": "GHz",
    "tensor_peak_flops": "flop/s", "tensor_sustained_flops": "flop/s",
    "vector_peak_flops": "flop/s", "vector_sustained_flops": "flop/s",
    "hbm_peak_bw": "bytes/s", "hbm_sustained_bw": "bytes/s",
    "accum_read_bw": "bytes/s", "accum_write_bw": "bytes/s",
    "tma_bandwidth": "bytes/s", "decomp_engine_rate": "bytes/s",
    "h2d_bandwidth": "bytes/s", "d2h_bandwidth": "bytes/s",
    "ici_link_bw": "bytes/s", "dci_link_bw": "bytes/s",
    "hbm_capacity": "bytes", "accum_capacity_bytes": "bytes",
    "working_set_scale_bytes": "bytes",
    "hbm_latency_cycles": "cycles", "tma_latency_cycles": "cycles",
    "mma_latency_cycles": "cycles", "mbarrier_latency_cycles": "cycles",
    "commit_latency_cycles": "cycles",
    "tmem_alloc_latency_s": "seconds", "coherence_latency_s": "seconds",
    "cross_xcd_latency_s": "seconds", "tau_interference_s": "seconds",
    "tau_interference_gpu_s": "seconds", "tau_fusion_s": "seconds",
    "launch_latency_s": "seconds", "tau_memcpy_s": "seconds",
    "tau_sync_s": "seconds",
    "llc_resident_mb": "MB", "llc_capacity_mb": "MB",
    "decomp_efficiency": "ratio", "two_sm_speedup": "ratio",
    "llc_transition_alpha": "ratio", "llc_transition_beta": "ratio",
    "mfma_utilization": "ratio", "pipeline_overlap_alpha": "ratio",
    "class_scales": "ratio", "precision_efficiency": "ratio",
}

_FIELDS = {f.name: f for f in dataclasses.fields(HardwareParams)}
REQUIRED_FIELDS = tuple(
    f.name for f in dataclasses.fields(HardwareParams)
    if f.default is dataclasses.MISSING
    and f.default_factory is dataclasses.MISSING)
_INT_FIELDS = tuple(n for n, f in _FIELDS.items() if f.type == "int")
_STR_FIELDS = ("name", "vendor", "model_family")
_DICT_FIELDS = ("tensor_peak_flops", "tensor_sustained_flops",
                "vector_peak_flops", "vector_sustained_flops",
                "class_scales", "precision_efficiency")
_PRECISION_DICTS = _DICT_FIELDS[:4] + ("precision_efficiency",)
_CACHE_LEVEL_KEYS = ("name", "capacity_bytes", "latency_cycles",
                     "bandwidth")


class HardwareSchemaError(ValueError):
    """A data file / entry dict violates the declarative schema."""


def _fail(where: str, msg: str) -> None:
    raise HardwareSchemaError(f"{where}: {msg}")


def _suggest(key: str, known) -> str:
    close = difflib.get_close_matches(key, list(known), n=1)
    return f" (did you mean {close[0]!r}?)" if close else ""


def _check_number(where: str, key: str, v, *, integer: bool = False):
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        _fail(where, f"field {key!r} must be a number, got "
                     f"{type(v).__name__}")
    if integer and not isinstance(v, int):
        _fail(where, f"field {key!r} must be an integer, got {v!r}")
    return v


# ---------------------------------------------------------------------------
# to_dict / from_dict
# ---------------------------------------------------------------------------

def to_dict(params: HardwareParams) -> Dict:
    """JSON-safe dict of every dataclass field (and nothing else — the
    process-local ``_sweep_content_token`` is not a field and never
    serializes).  ``cache_levels`` become a list of plain dicts."""
    out: Dict = {}
    for name in _FIELDS:
        v = getattr(params, name)
        if name == "cache_levels":
            v = [{"name": c.name, "capacity_bytes": c.capacity_bytes,
                  "latency_cycles": c.latency_cycles,
                  "bandwidth": c.bandwidth} for c in v]
        elif isinstance(v, dict):
            v = dict(v)
        out[name] = v
    return out


def from_dict(d: Dict, *, where: str = "<dict>") -> HardwareParams:
    """Validated inverse of :func:`to_dict`.

    Rejects unknown fields (with a close-match suggestion), missing
    required keys, wrong value types, unknown precisions in the
    per-precision throughput dicts, unknown ``model_family`` values, and
    malformed ``cache_levels`` — each with an error naming ``where``.
    """
    if not isinstance(d, dict):
        _fail(where, f"params must be a JSON object, got "
                     f"{type(d).__name__}")
    unknown = set(d) - set(_FIELDS)
    if unknown:
        key = sorted(unknown)[0]
        _fail(where, f"unknown field {key!r}{_suggest(key, _FIELDS)}; "
                     f"schema fields are defined by HardwareParams")
    missing = [k for k in REQUIRED_FIELDS if k not in d]
    if missing:
        _fail(where, f"missing required field(s): {', '.join(missing)}")

    kw: Dict = {}
    for key, v in d.items():
        if key in _STR_FIELDS:
            if not isinstance(v, str) or not v:
                _fail(where, f"field {key!r} must be a non-empty string")
            if key == "name" and not _NAME_RE.match(v):
                _fail(where, f"name {v!r} must match {_NAME_RE.pattern} "
                             f"(registry keys double as file stems)")
            if key == "model_family" and v not in KNOWN_FAMILIES:
                _fail(where, f"unknown model_family {v!r}; the sweep "
                             f"router knows {KNOWN_FAMILIES}")
        elif key in _DICT_FIELDS:
            if not isinstance(v, dict):
                _fail(where, f"field {key!r} must be an object, got "
                             f"{type(v).__name__}")
            for pk, pv in v.items():
                if key in _PRECISION_DICTS and pk not in BYTES_PER_ELEM:
                    _fail(where, f"{key}[{pk!r}]: unknown precision"
                                 f"{_suggest(pk, BYTES_PER_ELEM)}; known: "
                                 f"{sorted(BYTES_PER_ELEM)}")
                _check_number(where, f"{key}[{pk!r}]", pv)
            v = dict(v)
        elif key == "cache_levels":
            if not isinstance(v, (list, tuple)):
                _fail(where, "cache_levels must be a list (L1->LLC order)")
            levels = []
            for i, c in enumerate(v):
                if not isinstance(c, dict):
                    _fail(where, f"cache_levels[{i}] must be an object")
                bad = set(c) ^ set(_CACHE_LEVEL_KEYS)
                if bad:
                    _fail(where, f"cache_levels[{i}] must have exactly "
                                 f"the keys {_CACHE_LEVEL_KEYS} "
                                 f"(got {sorted(c)})")
                if not isinstance(c["name"], str) or not c["name"]:
                    _fail(where, f"cache_levels[{i}].name must be a "
                                 f"non-empty string")
                for nk in _CACHE_LEVEL_KEYS[1:]:
                    _check_number(where, f"cache_levels[{i}].{nk}", c[nk])
                levels.append(CacheLevel(c["name"], c["capacity_bytes"],
                                         c["latency_cycles"],
                                         c["bandwidth"]))
            v = tuple(levels)
        else:
            _check_number(where, key, v, integer=key in _INT_FIELDS)
        kw[key] = v
    return HardwareParams(**kw)


# ---------------------------------------------------------------------------
# Data files
# ---------------------------------------------------------------------------

@dataclass
class HardwareEntry:
    """One loaded library entry: the parameters plus their audit trail."""

    params: HardwareParams
    provenance: Dict[str, str] = field(default_factory=dict)
    units: Dict[str, str] = field(default_factory=dict)
    source: str = ""
    notes: str = ""
    path: Optional[str] = None

    def to_doc(self) -> Dict:
        doc: Dict = {"schema_version": SCHEMA_VERSION,
                     "params": to_dict(self.params)}
        if self.provenance:
            doc["provenance"] = dict(sorted(self.provenance.items()))
        if self.units:
            doc["units"] = dict(sorted(self.units.items()))
        if self.source:
            doc["source"] = self.source
        if self.notes:
            doc["notes"] = self.notes
        return doc


def load_entry(doc: Dict, *, where: str = "<doc>") -> HardwareEntry:
    """Validate one file-level document (see module docstring) into a
    :class:`HardwareEntry`."""
    if not isinstance(doc, dict):
        _fail(where, f"document must be a JSON object, got "
                     f"{type(doc).__name__}")
    unknown = set(doc) - set(DOC_KEYS)
    if unknown:
        key = sorted(unknown)[0]
        _fail(where, f"unknown top-level key {key!r}"
                     f"{_suggest(key, DOC_KEYS)}; valid: {DOC_KEYS}")
    sv = doc.get("schema_version")
    if sv is None:
        _fail(where, "missing required key 'schema_version'")
    if sv != SCHEMA_VERSION:
        _fail(where, f"schema_version {sv!r} unsupported (this build "
                     f"reads version {SCHEMA_VERSION})")
    if "params" not in doc:
        _fail(where, "missing required key 'params'")
    params = from_dict(doc["params"], where=f"{where}.params")

    prov = doc.get("provenance", {})
    if not isinstance(prov, dict):
        _fail(where, "provenance must be an object")
    for k, v in prov.items():
        if k not in _FIELDS:
            _fail(where, f"provenance names unknown field {k!r}"
                         f"{_suggest(k, _FIELDS)}")
        if v not in PROVENANCE_TAGS:
            _fail(where, f"provenance[{k!r}]: tag {v!r} not in "
                         f"{PROVENANCE_TAGS} (paper Table II Source "
                         f"column)")
    units = doc.get("units", {})
    if not isinstance(units, dict):
        _fail(where, "units must be an object")
    for k, v in units.items():
        want = FIELD_UNITS.get(k)
        if want is None:
            _fail(where, f"units names unknown/unitless field {k!r}"
                         f"{_suggest(k, FIELD_UNITS)}")
        if v != want:
            _fail(where, f"units[{k!r}] is {v!r} but the schema defines "
                         f"{k} in {want!r} — rescale the value, don't "
                         f"redeclare the unit")
    for k in ("source", "notes"):
        if k in doc and not isinstance(doc[k], str):
            _fail(where, f"{k} must be a string")
    return HardwareEntry(params=params, provenance=dict(prov),
                         units=dict(units), source=doc.get("source", ""),
                         notes=doc.get("notes", ""))


def load_file(path: str) -> HardwareEntry:
    """Load + validate one ``*.json`` parameter file."""
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except json.JSONDecodeError as e:
        raise HardwareSchemaError(f"{path}: not valid JSON: {e}") from None
    entry = load_entry(doc, where=os.path.basename(path))
    entry.path = path
    stem = os.path.splitext(os.path.basename(path))[0]
    if entry.params.name != stem:
        _fail(path, f"file stem {stem!r} must equal the entry name "
                    f"{entry.params.name!r} (the registry lazy-loads by "
                    f"stem)")
    return entry


def save_file(path: str, entry: HardwareEntry) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(entry.to_doc(), f, indent=1, sort_keys=False)
        f.write("\n")


def library_file(name: str) -> Optional[str]:
    """Path of the shipped data file for ``name`` under ``core/hwdata``
    (None when the entry is not file-backed — e.g. registered at
    runtime)."""
    from . import hardware
    path = os.path.join(hardware.DATA_DIR, f"{name}.json")
    return path if os.path.isfile(path) else None


def load_dir(dirpath: str) -> List[HardwareEntry]:
    """Validate every ``*.json`` under ``dirpath`` (sorted by name)."""
    out = []
    for fn in sorted(os.listdir(dirpath)):
        if fn.endswith(".json"):
            out.append(load_file(os.path.join(dirpath, fn)))
    return out


def install(path: str, *, overwrite: bool = False) -> HardwareParams:
    """Load a parameter file and register it.

    Goes through :func:`repro.core.hardware.register`, so a bad data file
    cannot silently shadow a shipped entry (``b200`` et al.) — collisions
    raise unless ``overwrite=True``.
    """
    from . import hardware
    entry = load_file(path)
    hardware.register(entry.params, overwrite=overwrite)
    return entry.params


# ---------------------------------------------------------------------------
# diff: the paper's "what changed in the port" as a query
# ---------------------------------------------------------------------------

@dataclass
class ParamDiff:
    """Field-level delta between two parameter files (paper §V-E: the
    B200→H200 port *is* this list).  Keys are dotted/indexed paths —
    ``hbm_peak_bw``, ``tensor_peak_flops.fp8``,
    ``cache_levels[1].bandwidth``."""

    a_name: str
    b_name: str
    changed: Dict[str, Tuple[object, object]] = field(default_factory=dict)
    added: Dict[str, object] = field(default_factory=dict)
    removed: Dict[str, object] = field(default_factory=dict)

    def __bool__(self) -> bool:
        return bool(self.changed or self.added or self.removed)

    def fields(self) -> Tuple[str, ...]:
        """Top-level HardwareParams field names touched by this diff."""
        keys = list(self.changed) + list(self.added) + list(self.removed)
        return tuple(sorted({k.split(".")[0].split("[")[0] for k in keys}))

    def format(self) -> str:
        lines = [f"diff {self.a_name} -> {self.b_name}: "
                 f"{len(self.changed)} changed, {len(self.added)} added, "
                 f"{len(self.removed)} removed"]
        for k in sorted(self.changed):
            a, b = self.changed[k]
            lines.append(f"  ~ {k}: {a!r} -> {b!r}")
        for k in sorted(self.added):
            lines.append(f"  + {k}: {self.added[k]!r}")
        for k in sorted(self.removed):
            lines.append(f"  - {k}: {self.removed[k]!r}")
        return "\n".join(lines)


def diff(a: HardwareParams, b: HardwareParams) -> ParamDiff:
    """Report changed/added/removed parameters between two entries.

    Dict-valued fields (per-precision throughputs, class scales) diff per
    key; ``cache_levels`` diff per level attribute, with whole levels
    added/removed when the hierarchies differ in depth.  Values compare
    by ``==`` (an int 0 and float 0.0 do not count as a change).
    """
    out = ParamDiff(a_name=a.name, b_name=b.name)
    for name in _FIELDS:
        va, vb = getattr(a, name), getattr(b, name)
        if name == "cache_levels":
            for i in range(max(len(va), len(vb))):
                if i >= len(va):
                    out.added[f"cache_levels[{i}]"] = to_dict(b)[
                        "cache_levels"][i]
                elif i >= len(vb):
                    out.removed[f"cache_levels[{i}]"] = to_dict(a)[
                        "cache_levels"][i]
                else:
                    for attr in _CACHE_LEVEL_KEYS:
                        x, y = getattr(va[i], attr), getattr(vb[i], attr)
                        if x != y:
                            out.changed[f"cache_levels[{i}].{attr}"] = (
                                x, y)
        elif isinstance(va, dict):
            for k in sorted(set(va) | set(vb)):
                if k not in va:
                    out.added[f"{name}.{k}"] = vb[k]
                elif k not in vb:
                    out.removed[f"{name}.{k}"] = va[k]
                elif va[k] != vb[k]:
                    out.changed[f"{name}.{k}"] = (va[k], vb[k])
        elif va != vb:
            out.changed[name] = (va, vb)
    return out

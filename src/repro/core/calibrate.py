"""Calibration: per-case multipliers with train/holdout discipline.

Paper §IV-D: "First-principles parameters (bandwidths, T_launch, barrier
latencies) come from microbenchmarks.  Optional per-case multipliers may
align predictions with profiler kernel-sum times; such factors must be
disclosed.  We recommend train/holdout splits when calibration is used."

Paper Obs. 1: on MI300A, host-measured calibration multipliers take the
27-kernel suite from ~5-8% (uncalibrated) to ~0.09% MAE; both numbers are
reported because they serve different purposes.

The calibration is multiplicative per case key (exact name match, then
class match, then global), fitted as measured/predicted on the train split.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .hardware import HardwareParams
from .workload import TimeBreakdown, Workload


@dataclass
class Calibration:
    """Disclosed per-case multipliers (paper's m_case, default 1.0).

    ``skipped`` lists kernels the fit could not use (non-positive
    predicted or measured time) — a degenerate backend must not produce
    an empty calibration that silently claims 0% train MAE.
    """

    per_case: Dict[str, float] = field(default_factory=dict)
    per_class: Dict[str, float] = field(default_factory=dict)
    global_scale: float = 1.0
    skipped: List[str] = field(default_factory=list)

    def multiplier(self, w: Workload) -> float:
        if w.name in self.per_case:
            return self.per_case[w.name]
        if w.wclass in self.per_class:
            return self.per_class[w.wclass]
        return self.global_scale

    def apply(self, w: Workload, pred: TimeBreakdown) -> TimeBreakdown:
        m = self.multiplier(w)
        out = pred.scaled(m)
        out.detail["m_case"] = m
        return out

    def disclose(self) -> Dict[str, object]:
        """Full disclosure of applied factors (paper §IV-D requirement),
        including the kernels the fit had to skip."""
        out: Dict[str, object] = {
            f"case:{k}": v for k, v in self.per_case.items()}
        out.update({f"class:{k}": v for k, v in self.per_class.items()})
        out["global"] = self.global_scale
        if self.skipped:
            out["skipped"] = list(self.skipped)
        return out

    # ------------------------------------------------------- serialization
    def to_dict(self) -> Dict:
        """JSON-safe form — what ``serve.codec`` ships over the wire.
        Multipliers travel in full (the §IV-D disclosure is the payload,
        not an attachment)."""
        return {"per_case": dict(self.per_case),
                "per_class": dict(self.per_class),
                "global_scale": self.global_scale,
                "skipped": list(self.skipped)}

    @staticmethod
    def from_dict(d: Dict) -> "Calibration":
        """Validated inverse of ``to_dict``."""
        if not isinstance(d, dict):
            raise ValueError(f"calibration payload must be a dict, got "
                             f"{type(d).__name__}")
        unknown = set(d) - {"per_case", "per_class", "global_scale",
                            "skipped"}
        if unknown:
            raise ValueError(f"unknown calibration key(s): "
                             f"{sorted(unknown)}")

        def _mults(key: str) -> Dict[str, float]:
            raw = d.get(key, {})
            if not isinstance(raw, dict):
                raise ValueError(f"{key} must be a dict")
            return {str(k): float(v) for k, v in raw.items()}

        return Calibration(
            per_case=_mults("per_case"), per_class=_mults("per_class"),
            global_scale=float(d.get("global_scale", 1.0)),
            skipped=[str(s) for s in d.get("skipped", [])])


PredictFn = Callable[[Workload], TimeBreakdown]


def fit_per_case(workloads: Sequence[Workload],
                 measured: Sequence[float],
                 predict_fn: PredictFn) -> Calibration:
    """m_case = measured / predicted, one per kernel (ceiling accuracy —
    what the paper's ~0.09% MI300A result does)."""
    cal = Calibration()
    for w, t_meas in zip(workloads, measured):
        t_pred = predict_fn(w).total
        if t_pred > 0:
            cal.per_case[w.name] = t_meas / t_pred
        else:
            # a degenerate backend (every prediction 0) must not yield an
            # empty calibration that claims perfect train MAE
            cal.skipped.append(w.name)
    return cal


def fit_per_class(workloads: Sequence[Workload],
                  measured: Sequence[float],
                  predict_fn: PredictFn) -> Calibration:
    """Geometric-mean multiplier per workload class (the paper's
    'separate calibrated scales for memory/compute/balanced/stencil')."""
    logs: Dict[str, List[float]] = {}
    cal = Calibration()
    for w, t_meas in zip(workloads, measured):
        t_pred = predict_fn(w).total
        if t_pred > 0 and t_meas > 0:
            logs.setdefault(w.wclass, []).append(math.log(t_meas / t_pred))
        else:
            cal.skipped.append(w.name)
    for cls, vals in logs.items():
        cal.per_class[cls] = math.exp(sum(vals) / len(vals))
    return cal


def train_holdout_split(
        workloads: Sequence[Workload], measured: Sequence[float],
        *, holdout_fraction: float = 0.3, seed: int = 0,
) -> Tuple[List[int], List[int]]:
    """Deterministic stratified-ish split (paper's recommended discipline)."""
    idx = list(range(len(workloads)))
    rng = random.Random(seed)
    rng.shuffle(idx)
    n_hold = max(1, int(round(len(idx) * holdout_fraction)))
    return idx[n_hold:], idx[:n_hold]


def fit_with_holdout(workloads: Sequence[Workload],
                     measured: Sequence[float],
                     predict_fn: PredictFn, *,
                     mode: str = "class",
                     holdout_fraction: float = 0.3,
                     seed: int = 0) -> Tuple[Calibration, Dict[str, float]]:
    """Fit on train split, report MAE on both splits (no leakage)."""
    from .validate import mae_percent

    train_idx, hold_idx = train_holdout_split(
        workloads, measured, holdout_fraction=holdout_fraction, seed=seed)
    tw = [workloads[i] for i in train_idx]
    tm = [measured[i] for i in train_idx]
    fit = fit_per_case if mode == "case" else fit_per_class
    cal = fit(tw, tm, predict_fn)

    def calibrated(w: Workload) -> float:
        return cal.apply(w, predict_fn(w)).total

    report = {
        "train_mae": mae_percent(
            [calibrated(workloads[i]) for i in train_idx],
            [measured[i] for i in train_idx]),
        "holdout_mae": mae_percent(
            [calibrated(workloads[i]) for i in hold_idx],
            [measured[i] for i in hold_idx]),
        "n_train": float(len(train_idx)),
        "n_holdout": float(len(hold_idx)),
        "n_skipped": float(len(cal.skipped)),
    }
    return cal, report

"""Multi-segment application modeling (paper §V-B 'Rodinia multi-segment
modeling').

Each application is a list of Segments (dominant GPU kernels or repeated
launch patterns).  Architecture-aware ROUTING maps each segment class to the
appropriate validated kernel family:

    stencil       -> memory-bound transpose proxy
    compute-bound -> GEMM family (stage / MFMA path)
    memory-bound  -> vector-copy family (bandwidth path)
    balanced      -> generic calibrated roofline

Segment times multiply by n_exec; host phases (memcpy/sync) add per Eq. 15.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from . import generic, predict as predict_mod
from .hardware import HardwareParams
from .workload import Segment, TimeBreakdown, Workload

# class -> model route per platform family (paper §V-B "architecture-aware
# routing").  GEMM-shaped compute segments take the native (stage/wavefront)
# path; everything else the generic calibrated path with its class scale.
CLASS_ROUTE = {
    "compute": "native",
    "memory": "generic",
    "stencil": "generic",
    "balanced": "generic",
}


def route_for(seg: Segment, hw: HardwareParams) -> str:
    route = CLASS_ROUTE[seg.workload.wclass]
    if route == "native" and (seg.workload.gemm is not None
                              or seg.workload.matrix):
        return {"blackwell": "stage", "cdna": "wavefront",
                "tpu": "tpu"}.get(hw.model_family, "generic")
    return "generic"


def predict_segment(seg: Segment, hw: HardwareParams, *,
                    calibration=None) -> TimeBreakdown:
    one = predict_mod.predict(seg.workload, hw, model=route_for(seg, hw),
                              calibration=calibration)
    out = one.scaled(seg.n_exec)
    overhead = generic.segment_overhead(seg, hw) * seg.n_exec
    return TimeBreakdown(
        total=out.total + overhead,
        compute=out.compute, memory=out.memory,
        io_effective=out.io_effective, sync=out.sync,
        launch=out.launch, writeback=out.writeback,
        collective=out.collective,
        overhead=overhead,
        detail=dict(out.detail, n_exec=float(seg.n_exec)),
    )


@dataclass(frozen=True)
class AppPrediction:
    name: str
    total: float
    per_segment: Dict[str, float]

    def mae_vs(self, measured: float) -> float:
        """Percent absolute error vs one measured total."""
        return abs(self.total - measured) / max(measured, 1e-30) * 100.0


def predict_app(name: str, segs: Sequence[Segment], hw: HardwareParams, *,
                calibration=None) -> AppPrediction:
    per: Dict[str, float] = {}
    total = 0.0
    for seg in segs:
        t = predict_segment(seg, hw, calibration=calibration).total
        per[seg.workload.name] = per.get(seg.workload.name, 0.0) + t
        total += t
    return AppPrediction(name=name, total=total, per_segment=per)

"""Workload / segment schema.

The paper characterizes every kernel or application segment by FLOPs, bytes,
class, tile geometry, working set and execution count, then routes it to the
appropriate model path (§IV-D workflow step 1, §V-B Rodinia segment files).

``Workload`` is a single kernel-level description; ``Segment`` wraps it with
an execution count and optional host phases (memcpy/sync, paper §IV-E);
applications are lists of Segments (``core/segments.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

VALID_CLASSES = ("memory", "compute", "balanced", "stencil")

#: default streaming chunk (rows) — ~1.7 MB of columns so chunk + result
#: columns stay LLC-resident (measured optimum: 8192-row chunks stream a
#: 1M-row lattice ~4x faster than materialize-then-reduce, which pays a
#: DRAM round-trip per column op; see benchmarks/sweep_bench.py), while
#: still amortizing per-chunk NumPy dispatch to noise.
DEFAULT_CHUNK_ROWS = 8_192

#: hard ceiling on one-shot materialization (``LatticeSpec.materialize`` /
#: ``WorkloadTable.cartesian``): beyond this the cartesian product is a
#: host-OOM, not a table.  Streaming (``LatticeSpec.chunks`` + the
#: ``core.sweep`` *_stream reductions) has no such bound.
MAX_MATERIALIZE_ROWS = 2 ** 31

# Layout of the packed numeric vector stashed on every Workload (column
# indices into the float64 matrix the batch backends build with one
# zero-copy np.frombuffer over the concatenated per-workload buffers).
# This is also the column layout of ``WorkloadTable.cols`` — the two forms
# are interconvertible row-for-row, byte-for-byte.
NV_FLOPS, NV_BYTES, NV_WS_OR_BYTES, NV_WS, NV_IRREGULAR, NV_CONCURRENT, \
    NV_DEVICES, NV_K_TILES, NV_NUM_CTAS, NV_BYTES_PER_CTA, NV_TMA_P, \
    NV_COMP_BYTES, NV_COMP_RATIO, NV_VGPR, NV_MATRIX, NV_HAS_GEMM, \
    NV_GM, NV_GN, NV_GK, NV_GMN, NV_BM, NV_BN, NV_BK, \
    NV_NUM_LOADS, NV_ATOMICS, NV_HAS_TILE = range(26)

NV_COLS = 26

_NVEC_PACK = struct.Struct(f"{NV_COLS}d").pack


@dataclass(frozen=True)
class TileConfig:
    """GEMM-style tile geometry (bM, bN, bK per CTA; paper Eq. 3)."""

    bm: int = 128
    bn: int = 128
    bk: int = 32

    @property
    def flops_per_tile_step(self) -> float:
        # one K-step of an MMA tile: 2*bM*bN*bK
        return 2.0 * self.bm * self.bn * self.bk

    def accum_bytes(self, accum_bytes_per_elem: float = 4.0) -> float:
        # accumulator tile resident in TMEM/VGPR: bM x bN
        return self.bm * self.bn * accum_bytes_per_elem


_DEFAULT_TILE = TileConfig()


@dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    def bytes_moved(self, in_bytes: float, out_bytes: float) -> float:
        return (self.m * self.k + self.k * self.n) * in_bytes + \
            self.m * self.n * out_bytes


@dataclass(frozen=True)
class Workload:
    """One kernel: the model's unit of prediction.

    Required inputs per paper §IV-G: for Blackwell, tile dims, K_tiles, bytes
    per CTA, TMA participants P, alpha; for MI300A, tile dims, K_tiles,
    bytes, hit rates, occupancy.  All optional fields default to values that
    route the workload through the generic path.
    """

    name: str
    wclass: str                      # memory | compute | balanced | stencil
    flops: float                     # total FLOPs (profiler- or FP-derived)
    bytes: float                     # total bytes moved to/from HBM
    precision: str = "fp32"
    matrix: bool = False             # uses tensor/matrix units?
    working_set_bytes: float = 0.0   # W for h_LLC(W) / B_eff(W)

    # --- tiled-GEMM path inputs (Blackwell stage model / MI300A tile model)
    gemm: Optional[GemmShape] = None
    tile: Optional[TileConfig] = None
    num_ctas: int = 0                # grid size (Eq. 14)
    k_tiles: int = 0                 # K-step count per CTA
    tma_participants: int = 1        # multicast P (Eq. 4)
    bytes_per_cta: float = 0.0

    # --- MI300A occupancy inputs
    vgpr_per_workitem: int = 64      # -> VGPR per wavefront = 64*vgpr
    hit_rates: Dict[str, float] = field(default_factory=dict)  # h_l1,h_l2,h_llc
    num_loads: float = 0.0           # N_loads for Eq. 10 latency walk

    # --- decompression (Blackwell Eq. 5)
    compressed_bytes: float = 0.0
    compression_ratio: float = 1.0

    # --- irregularity flags (paper Obs. 2: accuracy boundary)
    irregular: bool = False          # pointer-chasing / data-dependent access
    atomics: bool = False

    # --- concurrency (paper §IV-A6 / §IV-B)
    concurrent_kernels: int = 1
    num_devices: int = 1

    def __post_init__(self):
        if self.wclass not in VALID_CLASSES:
            raise ValueError(
                f"workload class {self.wclass!r} not in {VALID_CLASSES}")
        if self.flops < 0 or self.bytes < 0:
            raise ValueError("flops/bytes must be non-negative")

    @property
    def _nvec(self) -> bytes:
        """Packed NV_COLS-double numeric vector, memoized on the (frozen)
        instance.  Lazy so plain construction / ``replace()`` round-trips do
        not pay the struct repack; the buffer is built once on first use by
        the batch backends or the engine's content keys."""
        buf = self.__dict__.get("_nvec_buf")
        if buf is None:
            g, t = self.gemm, self.tile
            buf = _NVEC_PACK(
                self.flops, self.bytes,
                self.working_set_bytes or self.bytes, self.working_set_bytes,
                self.irregular, self.concurrent_kernels, self.num_devices,
                self.k_tiles, self.num_ctas, self.bytes_per_cta,
                self.tma_participants, self.compressed_bytes,
                self.compression_ratio, self.vgpr_per_workitem,
                self.matrix, g is not None,
                g.m if g is not None else 0, g.n if g is not None else 0,
                g.k if g is not None else 0,
                g.m * g.n if g is not None else 0,
                (t or _DEFAULT_TILE).bm, (t or _DEFAULT_TILE).bn,
                (t or _DEFAULT_TILE).bk,
                self.num_loads, self.atomics, t is not None)
            object.__setattr__(self, "_nvec_buf", buf)
        return buf

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)

    def replace(self, **kw) -> "Workload":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- serialization
    def to_dict(self) -> Dict:
        """JSON-safe dict of every field (nested GemmShape/TileConfig as
        dicts) — the wire form ``repro.serve.codec`` ships spec bases in."""
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict) -> "Workload":
        """Inverse of ``to_dict`` (tolerates the plain dicts json emits)."""
        d = dict(d)
        if d.get("gemm") is not None:
            d["gemm"] = GemmShape(**d["gemm"])
        if d.get("tile") is not None:
            d["tile"] = TileConfig(**d["tile"])
        d["hit_rates"] = dict(d.get("hit_rates") or {})
        return Workload(**d)


@dataclass(frozen=True)
class HostPhase:
    """Host-device transfer or sync episode (paper Eq. 15, §IV-E)."""

    kind: str                        # "h2d" | "d2h" | "sync"
    bytes: float = 0.0
    count: int = 1


@dataclass(frozen=True)
class Segment:
    """One application segment: a kernel repeated n_exec times plus host
    phases (paper §V-B 'Rodinia multi-segment modeling')."""

    workload: Workload
    n_exec: int = 1
    host_phases: Tuple[HostPhase, ...] = ()
    extra_kernels: int = 0           # multi-kernel segments (paper §IV-F)

    def __post_init__(self):
        if self.n_exec < 0:
            raise ValueError("n_exec must be >= 0")


@dataclass(frozen=True)
class TimeBreakdown:
    """Prediction output: total + per-stage terms (all seconds)."""

    total: float
    compute: float = 0.0
    memory: float = 0.0
    io_effective: float = 0.0
    sync: float = 0.0
    launch: float = 0.0
    writeback: float = 0.0
    collective: float = 0.0
    overhead: float = 0.0
    detail: Dict[str, float] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute,
                 "memory": max(self.memory, self.io_effective),
                 "collective": self.collective}
        return max(terms, key=terms.get)

    def scaled(self, factor: float) -> "TimeBreakdown":
        return TimeBreakdown(
            total=self.total * factor,
            compute=self.compute * factor,
            memory=self.memory * factor,
            io_effective=self.io_effective * factor,
            sync=self.sync * factor,
            launch=self.launch * factor,
            writeback=self.writeback * factor,
            collective=self.collective * factor,
            overhead=self.overhead * factor,
            detail={k: v * factor for k, v in self.detail.items()},
        )


# ---------------------------------------------------------------------------
# Compact row form of TimeBreakdown (SweepEngine hot path).
#
# A row is ((total, compute, memory, io_effective, sync, launch, writeback,
# collective, overhead), detail_keys, detail_values) — three immutable
# tuples.  Vectorized model backends emit rows via C-level zips of
# ``.tolist()`` columns, the engine memoizes them without defensive copies,
# and full TimeBreakdown objects materialize lazily on access.
# ---------------------------------------------------------------------------

TB_FIELDS = ("total", "compute", "memory", "io_effective", "sync", "launch",
             "writeback", "collective", "overhead")

#: (field_values, detail_keys, detail_values)
Row = Tuple[Tuple[float, ...], Tuple[str, ...], Tuple[float, ...]]


def nvec_matrix(ws) -> np.ndarray:
    """(n, NV_COLS) float64 view over the packed per-workload vectors — the
    zero-copy bulk extraction the batch backends build columns from."""
    return np.frombuffer(b"".join([w._nvec for w in ws]),
                         dtype=np.float64).reshape(len(ws), NV_COLS)


def tb_from_row(row: Row) -> TimeBreakdown:
    """Materialize a TimeBreakdown from its row form (bypasses the frozen
    dataclass __init__/__setattr__ — the row is already validated model
    output)."""
    tb = TimeBreakdown.__new__(TimeBreakdown)
    d = dict(zip(TB_FIELDS, row[0]))
    d["detail"] = dict(zip(row[1], row[2]))
    object.__setattr__(tb, "__dict__", d)
    return tb


def row_from_tb(tb: TimeBreakdown) -> Row:
    """Inverse of ``tb_from_row`` (scalar-fallback paths)."""
    return ((tb.total, tb.compute, tb.memory, tb.io_effective, tb.sync,
             tb.launch, tb.writeback, tb.collective, tb.overhead),
            tuple(tb.detail.keys()), tuple(tb.detail.values()))


# ---------------------------------------------------------------------------
# Columnar prediction output (WorkloadTable hot path).
#
# A model backend's table core returns its nine TimeBreakdown fields and its
# detail terms as whole columns — NumPy arrays, or plain floats for terms
# constant across the batch.  Reductions (argmin/top-k/pareto) run on these
# columns directly; per-row ``Row`` tuples / TimeBreakdowns materialize only
# for the winners.
# ---------------------------------------------------------------------------

class TableCols:
    """Columnar prediction result: one route, uniform detail keys."""

    __slots__ = ("n", "fields", "detail_keys", "detail_vals")

    def __init__(self, n: int, fields: Tuple, detail_keys: Tuple[str, ...],
                 detail_vals: Tuple):
        self.n = n
        self.fields = fields            # 9 items: ndarray or python float
        self.detail_keys = detail_keys
        self.detail_vals = detail_vals  # ndarray or python float each
        # results are cached whole by the engine and column reads hand out
        # these arrays directly — freeze them so a caller's in-place edit
        # (res.totals *= 1e3) raises instead of poisoning the cache
        for c in fields + detail_vals:
            if isinstance(c, np.ndarray) and c.flags.writeable:
                c.flags.writeable = False

    def totals(self) -> np.ndarray:
        t = self.fields[0]
        return t if isinstance(t, np.ndarray) else np.full(self.n, t)

    def field_col(self, j: int) -> np.ndarray:
        f = self.fields[j]
        return f if isinstance(f, np.ndarray) else np.full(self.n, f)

    def row(self, i: int) -> Row:
        f = tuple(float(c[i]) if isinstance(c, np.ndarray) else c
                  for c in self.fields)
        d = tuple(float(v[i]) if isinstance(v, np.ndarray) else v
                  for v in self.detail_vals)
        return (f, self.detail_keys, d)

    def rows(self) -> List[Row]:
        from itertools import repeat
        n = self.n
        cols = [c.tolist() if isinstance(c, np.ndarray) else repeat(c, n)
                for c in self.fields]
        dcols = [v.tolist() if isinstance(v, np.ndarray) else repeat(v, n)
                 for v in self.detail_vals]
        return list(zip(zip(*cols), repeat(self.detail_keys, n),
                        zip(*dcols)))


class RowsCols:
    """Column-interface adapter over precomputed Row tuples (scalar-fallback
    segments, e.g. CDNA3 workloads with explicit hit rates)."""

    __slots__ = ("n", "_rows")

    def __init__(self, rows: List[Row]):
        self._rows = rows
        self.n = len(rows)

    def totals(self) -> np.ndarray:
        return np.fromiter((r[0][0] for r in self._rows), np.float64, self.n)

    def field_col(self, j: int) -> np.ndarray:
        return np.fromiter((r[0][j] for r in self._rows), np.float64, self.n)

    def row(self, i: int) -> Row:
        return self._rows[i]

    def rows(self) -> List[Row]:
        return self._rows


class SegmentedCols:
    """Columnar result assembled from disjoint row-index segments (mixed
    routing inside one table, e.g. tiled-GEMM vs streaming rows on the
    Blackwell stage model — the segments carry different detail keys)."""

    __slots__ = ("n", "segments", "_owner", "_local")

    def __init__(self, n: int, segments: List[Tuple[np.ndarray, object]]):
        self.n = n
        self.segments = segments
        owner = np.empty(n, dtype=np.intp)
        local = np.empty(n, dtype=np.intp)
        for s, (idx, _) in enumerate(segments):
            owner[idx] = s
            local[idx] = np.arange(len(idx))
        self._owner = owner
        self._local = local

    def totals(self) -> np.ndarray:
        out = np.empty(self.n, dtype=np.float64)
        for idx, seg in self.segments:
            out[idx] = seg.totals()
        return out

    def field_col(self, j: int) -> np.ndarray:
        out = np.empty(self.n, dtype=np.float64)
        for idx, seg in self.segments:
            out[idx] = seg.field_col(j)
        return out

    def row(self, i: int) -> Row:
        return self.segments[self._owner[i]][1].row(int(self._local[i]))

    def rows(self) -> List[Row]:
        out: List[Optional[Row]] = [None] * self.n
        for idx, seg in self.segments:
            for i, row in zip(idx.tolist(), seg.rows()):
                out[i] = row
        return out  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# WorkloadTable: struct-of-arrays workload batch.
#
# Sweeps (tile lattices, precision ladders, cartesian what-if grids) never
# need per-config ``Workload`` dataclasses: the table holds the NV_COLS
# float64 matrix directly plus vocab-coded non-numeric columns, and the
# model backends consume the columns as-is.  Scalar ``Workload`` objects
# materialize lazily (``workload(i)``) for winners only.
# ---------------------------------------------------------------------------

#: Workload fields settable as cartesian grid axes -> their NV column.
CARTESIAN_COLS = {
    "flops": NV_FLOPS, "bytes": NV_BYTES,
    "working_set_bytes": NV_WS, "k_tiles": NV_K_TILES,
    "num_ctas": NV_NUM_CTAS, "bytes_per_cta": NV_BYTES_PER_CTA,
    "tma_participants": NV_TMA_P, "compressed_bytes": NV_COMP_BYTES,
    "compression_ratio": NV_COMP_RATIO, "vgpr_per_workitem": NV_VGPR,
    "num_loads": NV_NUM_LOADS, "concurrent_kernels": NV_CONCURRENT,
    "num_devices": NV_DEVICES, "irregular": NV_IRREGULAR,
    "matrix": NV_MATRIX,
}


def _encode(values: List[str]):
    """Small-vocabulary string column -> (codes intp array, vocab tuple)."""
    vocab: Dict[str, int] = {}
    sd = vocab.setdefault
    codes = [sd(v, len(vocab)) for v in values]
    return np.array(codes, dtype=np.intp), tuple(vocab)


def _canonical_codes(codes: np.ndarray, vocab: Tuple[str, ...]):
    """(int64 code bytes, vocab tuple) in a construction-order-invariant
    form: only vocab entries actually used by ``codes`` survive, sorted by
    string, with the codes remapped to match.  Two tables whose rows decode
    to the same per-row strings hash identically no matter which insertion
    order (``concat`` operand order, wire decode order, ``take`` leftovers)
    their vocabularies accumulated in — raw codes would memo-miss them.
    int64 on both 32/64-bit hosts so digests are platform-stable."""
    used = np.unique(codes)
    uniq = sorted({vocab[int(c)] for c in used})
    remap = np.zeros(len(vocab), dtype=np.int64)
    for c in used:
        remap[int(c)] = uniq.index(vocab[int(c)])
    return remap[codes].tobytes(), tuple(uniq)


class WorkloadTable:
    """Struct-of-arrays batch of workloads (the columnar sweep unit).

    Treat instances as immutable: the engine caches results under a content
    token computed once per table.  ``cols`` is the (n, NV_COLS) float64
    matrix in ``NV_*`` column order; ``precision``/``wclass`` are vocab-coded
    per-row; ``hit_rates`` (rarely used — CDNA3 Eq. 10 inputs) is either
    None or a per-row tuple of dicts.
    """

    __slots__ = ("cols", "precision_codes", "precision_vocab",
                 "wclass_codes", "wclass_vocab", "names", "hit_rates",
                 "name_offset", "_token")

    def __init__(self, cols: np.ndarray, precision_codes: np.ndarray,
                 precision_vocab: Tuple[str, ...],
                 wclass_codes: np.ndarray, wclass_vocab: Tuple[str, ...],
                 names=None, hit_rates=None, name_offset: int = 0):
        self.cols = cols
        self.precision_codes = precision_codes
        self.precision_vocab = precision_vocab
        self.wclass_codes = wclass_codes
        self.wclass_vocab = wclass_vocab
        self.names = names          # tuple per-row | shared str | None
        self.hit_rates = hit_rates  # None | tuple of (dict | None)
        # chunk tables cut from a larger lattice keep their global row
        # numbering through this offset, so streamed winners carry the same
        # names a full materialization would
        self.name_offset = name_offset
        self._token = None
        # freeze the code arrays too: a zero-copy wire decode over a
        # writable buffer (bytearray/memoryview) would otherwise hand out
        # mutable codes whose cached content_token goes stale
        for arr in (cols, precision_codes, wclass_codes):
            if arr.flags.writeable:
                arr.flags.writeable = False

    # ------------------------------------------------------------ basics
    def __len__(self) -> int:
        return self.cols.shape[0]

    @property
    def n(self) -> int:
        return self.cols.shape[0]

    def name(self, i: int) -> str:
        if isinstance(self.names, tuple):
            return self.names[i]
        return f"{self.names or 'table'}#{i + self.name_offset}"

    def content_token(self) -> Tuple:
        """Hashable content identity (what the engine's whole-table cache is
        keyed on): a fixed-size blake2b digest of the column bytes + the
        small vocab/hit-rate tuples, so neither the token nor the cache key
        retains a raw copy of the table.  Vocab-coded columns are hashed in
        canonical (used-and-sorted) form, so semantically identical tables
        built with different precision/wclass insertion orders — ``concat``
        operand order, decoded wire tables, ``take`` subsets — share one
        token and hit the memo cache.  Computed once and cached — replays
        of the same table object skip even the digest."""
        tok = self._token
        if tok is None:
            hr = None if self.hit_rates is None else tuple(
                tuple(sorted(h.items())) if h else ()
                for h in self.hit_rates)
            pb, pv = _canonical_codes(self.precision_codes,
                                      self.precision_vocab)
            wb, wv = _canonical_codes(self.wclass_codes, self.wclass_vocab)
            h = hashlib.blake2b(digest_size=16)
            h.update(self.cols.tobytes())
            h.update(pb)
            h.update(wb)
            tok = (h.digest(), len(self), pv, wv, hr)
            self._token = tok
        return tok

    # --------------------------------------------------- vocab broadcasts
    def per_precision(self, fn) -> np.ndarray:
        """Broadcast fn(precision) over rows — fn runs once per distinct
        precision, exactly like the list-path per-batch lookup maps."""
        vals = np.array([fn(p) for p in self.precision_vocab],
                        dtype=np.float64)
        return vals[self.precision_codes]

    def per_precision_matrix(self, fn) -> np.ndarray:
        """Broadcast fn(precision, matrix_flag) over rows; fn runs once per
        distinct (precision, matrix) pair actually present."""
        mat = (self.cols[:, NV_MATRIX] != 0).astype(np.intp)
        pair = self.precision_codes * 2 + mat
        vals = np.empty(2 * len(self.precision_vocab), dtype=np.float64)
        for pid in np.unique(pair):
            vals[pid] = fn(self.precision_vocab[int(pid) // 2],
                           bool(int(pid) % 2))
        return vals[pair]

    def per_wclass(self, fn) -> np.ndarray:
        vals = np.array([fn(c) for c in self.wclass_vocab], dtype=np.float64)
        return vals[self.wclass_codes]

    # ------------------------------------------------------------- views
    def _slice(self, lo: int, hi: int) -> "WorkloadTable":
        """Contiguous zero-copy row window [lo, hi); the cut keeps global
        row naming via ``name_offset``."""
        names = self.names
        offset = 0
        if isinstance(names, tuple):
            names = names[lo:hi]
        else:
            offset = self.name_offset + lo
        hr = self.hit_rates
        if hr is not None:
            hr = hr[lo:hi]
        return WorkloadTable(
            self.cols[lo:hi], self.precision_codes[lo:hi],
            self.precision_vocab, self.wclass_codes[lo:hi],
            self.wclass_vocab, names, hr, name_offset=offset)

    def chunks(self, size: int = DEFAULT_CHUNK_ROWS
               ) -> Iterator["WorkloadTable"]:
        """Yield contiguous row windows of ``size`` rows (zero-copy views)
        — the streaming unit for tables that are already built."""
        size = max(int(size), 1)
        for lo in range(0, len(self), size):
            yield self._slice(lo, min(lo + size, len(self)))

    def take(self, idx: np.ndarray) -> "WorkloadTable":
        """Row-subset table (mixed-route splits inside the backends)."""
        names = self.names
        if isinstance(names, tuple):
            names = tuple(names[i] for i in idx.tolist())
        hr = self.hit_rates
        if hr is not None:
            hr = tuple(hr[i] for i in idx.tolist())
        return WorkloadTable(
            np.ascontiguousarray(self.cols[idx]),
            self.precision_codes[idx], self.precision_vocab,
            self.wclass_codes[idx], self.wclass_vocab, names, hr)

    def workload(self, i: int) -> Workload:
        """Materialize row ``i`` as a scalar Workload (winners / scalar
        fallbacks only — never the sweep hot path)."""
        r = self.cols[i]
        g = GemmShape(int(r[NV_GM]), int(r[NV_GN]), int(r[NV_GK])) \
            if r[NV_HAS_GEMM] != 0 else None
        t = TileConfig(int(r[NV_BM]), int(r[NV_BN]), int(r[NV_BK])) \
            if r[NV_HAS_TILE] != 0 else None
        hr = {}
        if self.hit_rates is not None and self.hit_rates[i]:
            hr = dict(self.hit_rates[i])
        return Workload(
            name=self.name(i),
            wclass=self.wclass_vocab[self.wclass_codes[i]],
            flops=float(r[NV_FLOPS]), bytes=float(r[NV_BYTES]),
            precision=self.precision_vocab[self.precision_codes[i]],
            matrix=bool(r[NV_MATRIX]),
            working_set_bytes=float(r[NV_WS]),
            gemm=g, tile=t,
            num_ctas=int(r[NV_NUM_CTAS]), k_tiles=int(r[NV_K_TILES]),
            tma_participants=int(r[NV_TMA_P]),
            bytes_per_cta=float(r[NV_BYTES_PER_CTA]),
            vgpr_per_workitem=int(r[NV_VGPR]),
            hit_rates=hr, num_loads=float(r[NV_NUM_LOADS]),
            compressed_bytes=float(r[NV_COMP_BYTES]),
            compression_ratio=float(r[NV_COMP_RATIO]),
            irregular=bool(r[NV_IRREGULAR]), atomics=bool(r[NV_ATOMICS]),
            concurrent_kernels=int(r[NV_CONCURRENT]),
            num_devices=int(r[NV_DEVICES]))

    # ------------------------------------------------------ constructors
    @classmethod
    def from_workloads(cls, ws: Sequence[Workload]) -> "WorkloadTable":
        """Columnar view over existing Workload objects (one zero-copy
        frombuffer over the packed per-workload vectors)."""
        pc, pv = _encode([w.precision for w in ws])
        wc, wv = _encode([w.wclass for w in ws])
        hit_rates = None
        if any(w.hit_rates for w in ws):
            hit_rates = tuple(w.hit_rates or None for w in ws)
        return cls(nvec_matrix(ws), pc, pv, wc, wv,
                   tuple(w.name for w in ws), hit_rates)

    @classmethod
    def _from_base(cls, base: Workload, n: int) -> "WorkloadTable":
        cols = np.tile(np.frombuffer(base._nvec, dtype=np.float64), (n, 1))
        codes = np.zeros(n, dtype=np.intp)
        hr = tuple([base.hit_rates] * n) if base.hit_rates else None
        return cls(cols, codes, (base.precision,), codes.copy(),
                   (base.wclass,), base.name, hr)

    @classmethod
    def tile_lattice(cls, base: Workload,
                     tiles: Sequence[TileConfig]) -> "WorkloadTable":
        """Re-tile ``base`` with every candidate tile — columnar analogue of
        ``cdna3._retile`` per candidate, with the derived grid quantities
        (num_ctas, k_tiles, bytes_per_cta) recomputed vectorized when the
        base carries a GEMM shape."""
        return LatticeSpec.tile_lattice(base, tiles).materialize()

    @classmethod
    def cartesian(cls, base: Workload, **field_grids) -> "WorkloadTable":
        """Cross-product sweep over Workload fields, columnar end to end.

        Grid keys: any numeric field in ``CARTESIAN_COLS``, plus
        ``precision`` / ``wclass`` (strings, vocab-coded) and ``tile``
        (TileConfig — sets the raw bM/bN/bK columns only; use
        ``tile_lattice`` when the GEMM grid quantities must follow the
        tile).  Row order is C-order over the grids in keyword order.

        Refuses grids beyond ``MAX_MATERIALIZE_ROWS`` — build the
        ``LatticeSpec`` instead and stream it chunk-wise.
        """
        return LatticeSpec.cartesian(base, **field_grids).materialize()

    @classmethod
    def concat(cls, tables: Sequence["WorkloadTable"]) -> "WorkloadTable":
        """Stack tables row-wise (e.g. per-shape tile lattices into one
        sweep).  Vocabularies are merged and re-coded."""
        if not tables:
            raise ValueError("concat of zero tables")
        cols = np.vstack([t.cols for t in tables])

        def merge(code_attr, vocab_attr):
            vocab: Dict[str, int] = {}
            parts = []
            for t in tables:
                tv = getattr(t, vocab_attr)
                remap = np.array([vocab.setdefault(v, len(vocab))
                                  for v in tv], dtype=np.intp)
                parts.append(remap[getattr(t, code_attr)])
            return np.concatenate(parts), tuple(vocab)

        pc, pv = merge("precision_codes", "precision_vocab")
        wc, wv = merge("wclass_codes", "wclass_vocab")
        names = None
        if all(isinstance(t.names, tuple) for t in tables):
            names = tuple(nm for t in tables for nm in t.names)
        hit_rates = None
        if any(t.hit_rates is not None for t in tables):
            hit_rates = tuple(
                h for t in tables
                for h in (t.hit_rates or (None,) * len(t)))
        return cls(cols, pc, pv, wc, wv, names, hit_rates)


# ---------------------------------------------------------------------------
# LatticeSpec: lazy sweep plans.
#
# A spec knows ``n_rows`` without materializing anything and yields
# WorkloadTable chunks via vectorized index arithmetic (divmod of the global
# row index into grid coordinates, written straight into preallocated column
# buffers — no per-row Python).  Chunks are row-for-row, byte-for-byte
# identical to the corresponding window of the materialized table, so the
# streaming reductions in ``core.sweep`` return bit-identical winners.
# Specs are small (a base workload + grid arrays) and picklable, which is
# what lets ``core.parallel`` ship them to worker processes instead of
# shipping columns.
# ---------------------------------------------------------------------------

class LatticeSpec:
    """Lazy description of a sweep lattice (cartesian / tile-lattice /
    concat algebra over ``WorkloadTable`` construction)."""

    @property
    def n_rows(self) -> int:
        raise NotImplementedError

    def __len__(self) -> int:
        return self.n_rows

    def chunk(self, lo: int, hi: int) -> WorkloadTable:
        """Materialize rows [lo, hi) as a WorkloadTable (bit-identical to
        the same window of ``materialize()``).  Raises on windows outside
        [0, n_rows] — a silently wrapped window would price phantom rows."""
        raise NotImplementedError

    def _check_window(self, lo: int, hi: int) -> None:
        if not 0 <= lo <= hi <= self.n_rows:
            raise ValueError(
                f"chunk window [{lo}, {hi}) outside lattice rows "
                f"[0, {self.n_rows})")

    def _has_row_names(self) -> bool:
        """True when chunks carry per-row name tuples (mirrors
        ``WorkloadTable.concat``'s naming rule)."""
        return False

    def estimated_bytes(self) -> int:
        """Estimated resident size of the fully materialized columns."""
        per_row = NV_COLS * 8 + 2 * np.dtype(np.intp).itemsize
        return self.n_rows * per_row

    def chunks(self, size: int = DEFAULT_CHUNK_ROWS, lo: int = 0,
               hi: Optional[int] = None) -> Iterator[WorkloadTable]:
        """Yield chunk tables of ``size`` rows covering [lo, hi)."""
        size = max(int(size), 1)
        hi = self.n_rows if hi is None else min(hi, self.n_rows)
        for start in range(lo, hi, size):
            yield self.chunk(start, min(start + size, hi))

    def materialize(self) -> WorkloadTable:
        """One-shot table build; refuses lattices beyond
        ``MAX_MATERIALIZE_ROWS`` instead of OOM-killing the host."""
        n = self.n_rows
        if n > MAX_MATERIALIZE_ROWS:
            est = self.estimated_bytes()
            raise ValueError(
                f"materializing this lattice needs {n:,} rows "
                f"(~{est / 1e9:,.1f} GB of columns, > "
                f"{MAX_MATERIALIZE_ROWS:,} rows); keep it as a LatticeSpec "
                f"and stream it instead (LatticeSpec.chunks or the "
                f"core.sweep argmin_stream/topk_stream/pareto_stream "
                f"reductions, optionally sharded via core.parallel)")
        return self.chunk(0, n)

    # --------------------------------------------------------- constructors
    @staticmethod
    def cartesian(base: Workload, **field_grids) -> "LatticeSpec":
        """Lazy cross-product over Workload fields (same grid keys and row
        order as ``WorkloadTable.cartesian``)."""
        return _CartesianSpec(base, field_grids)

    @staticmethod
    def tile_lattice(base: Workload,
                     tiles: Sequence[TileConfig]) -> "LatticeSpec":
        """Lazy per-candidate re-tiling of ``base`` (same semantics as
        ``WorkloadTable.tile_lattice``)."""
        return _TileLatticeSpec(base, tiles)

    @staticmethod
    def concat(specs: Sequence["LatticeSpec"]) -> "LatticeSpec":
        """Row-wise stack of specs (and/or tables via ``from_table``)."""
        return _ConcatSpec(specs)

    @staticmethod
    def from_table(table: WorkloadTable) -> "LatticeSpec":
        """Wrap an already-built table so it streams through the same
        chunked machinery (zero-copy row windows)."""
        return _TableSpec(table)

    # ------------------------------------------------------- serialization
    def to_plan(self, table_sink=None) -> Dict:
        """JSON-safe structural description of this spec — the wire form
        ``repro.serve.codec`` ships lattice plans in (a plan is tiny even
        when the lattice it describes has 10^9 rows).

        Built tables nested in the plan cannot be JSON: ``table_sink``
        is called once per table and must return a small JSON-safe
        reference (the codec appends the table's columns as a binary
        section and returns its index).
        """
        raise NotImplementedError

    @staticmethod
    def from_plan(plan: Dict,
                  tables: Sequence["WorkloadTable"] = ()) -> "LatticeSpec":
        """Rebuild a spec from ``to_plan`` output.  ``tables`` resolves the
        references a ``table_sink`` handed out during encoding."""
        kind = plan.get("kind")
        if kind == "cartesian":
            grids = {}
            for key, vals in plan["grids"].items():
                if key == "tile":
                    vals = [TileConfig(*map(int, t)) for t in vals]
                grids[key] = vals
            return _CartesianSpec(Workload.from_dict(plan["base"]), grids)
        if kind == "tile_lattice":
            return _TileLatticeSpec(
                Workload.from_dict(plan["base"]),
                [TileConfig(*map(int, t)) for t in plan["tiles"]])
        if kind == "concat":
            return _ConcatSpec([LatticeSpec.from_plan(p, tables)
                                for p in plan["children"]])
        if kind == "table":
            ref = plan.get("ref")
            if not isinstance(ref, int) or not 0 <= ref < len(tables):
                raise ValueError(
                    f"plan references table {ref!r} but only "
                    f"{len(tables)} table(s) were provided")
            return _TableSpec(tables[ref])
        raise ValueError(f"unknown lattice plan kind {kind!r}")


class _CartesianSpec(LatticeSpec):
    """Cartesian grid: each chunk decodes global row indices into per-axis
    grid coordinates with one divmod per axis."""

    def __init__(self, base: Workload, field_grids: Dict):
        self.base = base
        self.keys = tuple(field_grids)
        self._plan_grids: Dict[str, List] = {}
        sizes = []
        prepped = []
        for key in self.keys:
            vals = list(field_grids[key])
            # _plan_grids is filled per branch, after validation, so an
            # invalid axis still raises the documented ValueError below
            # (floats for numeric axes keep the plan json-safe even for
            # numpy scalars).
            if key == "precision":
                strs = [str(v) for v in vals]
                codes, vocab = _encode(strs)
                prepped.append(("precision", codes, vocab))
                self._plan_grids[key] = strs
            elif key == "wclass":
                for v in vals:
                    if v not in VALID_CLASSES:
                        raise ValueError(f"workload class {v!r} not in "
                                         f"{VALID_CLASSES}")
                strs = [str(v) for v in vals]
                codes, vocab = _encode(strs)
                prepped.append(("wclass", codes, vocab))
                self._plan_grids[key] = strs
            elif key == "tile":
                prepped.append((
                    "tile",
                    np.array([c.bm for c in vals], dtype=np.float64),
                    np.array([c.bn for c in vals], dtype=np.float64),
                    np.array([c.bk for c in vals], dtype=np.float64)))
                self._plan_grids[key] = [[c.bm, c.bn, c.bk] for c in vals]
            elif key in CARTESIAN_COLS:
                arr = np.array(vals, dtype=np.float64)
                prepped.append(("col", CARTESIAN_COLS[key], arr))
                self._plan_grids[key] = [float(v) for v in arr]
            else:
                raise ValueError(
                    f"cartesian cannot sweep field {key!r}; valid: "
                    f"{sorted(CARTESIAN_COLS)} + precision/wclass/tile")
            sizes.append(len(vals))
        n = 1
        for s in sizes:
            n *= s
        if n == 0:
            raise ValueError("empty cartesian grid")
        self._n = n
        self._sizes = sizes
        strides = []
        acc = 1
        for s in reversed(sizes):
            strides.append(acc)
            acc *= s
        self._strides = list(reversed(strides))
        self._prepped = prepped
        self._ws_fix = ("bytes" in field_grids
                        or "working_set_bytes" in field_grids)

    @property
    def n_rows(self) -> int:
        return self._n

    def to_plan(self, table_sink=None) -> Dict:
        return {"kind": "cartesian", "base": self.base.to_dict(),
                "grids": {k: list(v) for k, v in self._plan_grids.items()}}

    def chunk(self, lo: int, hi: int) -> WorkloadTable:
        self._check_window(lo, hi)
        base = self.base
        t = WorkloadTable._from_base(base, hi - lo)
        cols = t.cols
        cols.flags.writeable = True
        ridx = np.arange(lo, hi, dtype=np.intp)
        prec_codes, prec_vocab = t.precision_codes, t.precision_vocab
        wcls_codes, wcls_vocab = t.wclass_codes, t.wclass_vocab
        for size, stride, prep in zip(self._sizes, self._strides,
                                      self._prepped):
            take = (ridx // stride) % size
            kind = prep[0]
            if kind == "precision":
                prec_codes, prec_vocab = prep[1][take], prep[2]
            elif kind == "wclass":
                wcls_codes, wcls_vocab = prep[1][take], prep[2]
            elif kind == "tile":
                cols[:, NV_BM] = prep[1][take]
                cols[:, NV_BN] = prep[2][take]
                cols[:, NV_BK] = prep[3][take]
                cols[:, NV_HAS_TILE] = 1.0
            else:
                cols[:, prep[1]] = prep[2][take]
        if self._ws_fix:
            ws_col = cols[:, NV_WS]
            cols[:, NV_WS_OR_BYTES] = np.where(ws_col != 0, ws_col,
                                               cols[:, NV_BYTES])
        cols.flags.writeable = False
        return WorkloadTable(cols, prec_codes, prec_vocab, wcls_codes,
                             wcls_vocab, base.name, t.hit_rates,
                             name_offset=lo)


class _TileLatticeSpec(LatticeSpec):
    def __init__(self, base: Workload, tiles: Sequence[TileConfig]):
        self.base = base
        self._bm = np.array([c.bm for c in tiles], dtype=np.int64)
        self._bn = np.array([c.bn for c in tiles], dtype=np.int64)
        self._bk = np.array([c.bk for c in tiles], dtype=np.int64)

    @property
    def n_rows(self) -> int:
        return len(self._bm)

    def to_plan(self, table_sink=None) -> Dict:
        return {"kind": "tile_lattice", "base": self.base.to_dict(),
                "tiles": [[int(m), int(n), int(k)] for m, n, k in
                          zip(self._bm.tolist(), self._bn.tolist(),
                              self._bk.tolist())]}

    def chunk(self, lo: int, hi: int) -> WorkloadTable:
        self._check_window(lo, hi)
        from .hardware import BYTES_PER_ELEM
        base = self.base
        t = WorkloadTable._from_base(base, hi - lo)
        cols = t.cols
        cols.flags.writeable = True
        bm, bn, bk = self._bm[lo:hi], self._bn[lo:hi], self._bk[lo:hi]
        cols[:, NV_BM] = bm
        cols[:, NV_BN] = bn
        cols[:, NV_BK] = bk
        cols[:, NV_HAS_TILE] = 1.0
        if base.gemm is not None:
            g = base.gemm
            cols[:, NV_NUM_CTAS] = (-(-g.m // bm)) * (-(-g.n // bn))
            cols[:, NV_K_TILES] = -(-g.k // bk)
            in_b = BYTES_PER_ELEM[base.precision]
            cols[:, NV_BYTES_PER_CTA] = (bm * bk + bk * bn) * in_b
        cols.flags.writeable = False
        t.name_offset = lo
        return t


class _TableSpec(LatticeSpec):
    def __init__(self, table: WorkloadTable):
        self.table = table

    @property
    def n_rows(self) -> int:
        return len(self.table)

    def _has_row_names(self) -> bool:
        return isinstance(self.table.names, tuple)

    def to_plan(self, table_sink=None) -> Dict:
        if table_sink is None:
            raise TypeError("plan contains a built table; provide a "
                            "table_sink to reference it")
        return {"kind": "table", "ref": table_sink(self.table)}

    def chunk(self, lo: int, hi: int) -> WorkloadTable:
        self._check_window(lo, hi)
        return self.table._slice(lo, hi)

    def materialize(self) -> WorkloadTable:
        return self.table


class _ConcatSpec(LatticeSpec):
    def __init__(self, specs: Sequence[LatticeSpec]):
        if not specs:
            raise ValueError("concat of zero specs")
        self.specs = list(specs)
        self._offsets = [0]
        for s in self.specs:
            self._offsets.append(self._offsets[-1] + s.n_rows)
        self._row_names = all(s._has_row_names() for s in self.specs)

    @property
    def n_rows(self) -> int:
        return self._offsets[-1]

    def to_plan(self, table_sink=None) -> Dict:
        return {"kind": "concat",
                "children": [s.to_plan(table_sink) for s in self.specs]}

    def _has_row_names(self) -> bool:
        return self._row_names

    def chunk(self, lo: int, hi: int) -> WorkloadTable:
        self._check_window(lo, hi)
        parts = []
        for child, start, end in zip(self.specs, self._offsets,
                                     self._offsets[1:]):
            a, b = max(lo, start), min(hi, end)
            if a < b:
                parts.append(child.chunk(a - start, b - start))
        if not parts:                       # empty window (lo == hi)
            parts = [self.specs[0].chunk(0, 0)]
        t = parts[0] if len(parts) == 1 else WorkloadTable.concat(parts)
        if not self._row_names:
            # mirror WorkloadTable.concat naming ("table#<global row>")
            # regardless of which children this window happens to touch
            t.names = None
            t.name_offset = lo
        return t


def gemm_workload(name: str, m: int, n: int, k: int, *,
                  precision: str = "fp16",
                  tile: TileConfig = TileConfig(),
                  wclass: str = "compute",
                  out_precision: Optional[str] = None) -> Workload:
    """Convenience constructor for tiled-GEMM workloads (the paper's
    compute-bound validation class)."""
    from .hardware import BYTES_PER_ELEM

    in_b = BYTES_PER_ELEM[precision]
    out_b = BYTES_PER_ELEM[out_precision or precision]
    shape = GemmShape(m, n, k)
    num_ctas = -(-m // tile.bm) * -(-n // tile.bn)
    k_tiles = -(-k // tile.bk)
    # per-CTA HBM traffic for one K-step: an A tile + a B tile
    bytes_per_cta = (tile.bm * tile.bk + tile.bk * tile.bn) * in_b
    ws = min(shape.bytes_moved(in_b, out_b),
             (m * k + k * n + m * n) * in_b)
    return Workload(
        name=name, wclass=wclass,
        flops=shape.flops,
        bytes=shape.bytes_moved(in_b, out_b),
        precision=precision, matrix=True,
        working_set_bytes=ws,
        gemm=shape, tile=tile,
        num_ctas=num_ctas, k_tiles=k_tiles,
        bytes_per_cta=bytes_per_cta,
    )


def streaming_workload(name: str, nbytes: float, *,
                       flops_per_byte: float = 0.125,
                       precision: str = "fp32",
                       wclass: str = "memory",
                       irregular: bool = False) -> Workload:
    """Memory-bound vector ops (add/copy/transpose/reduction class)."""
    return Workload(
        name=name, wclass=wclass,
        flops=nbytes * flops_per_byte,
        bytes=nbytes,
        precision=precision, matrix=False,
        working_set_bytes=nbytes,
        irregular=irregular,
    )

"""Workload / segment schema.

The paper characterizes every kernel or application segment by FLOPs, bytes,
class, tile geometry, working set and execution count, then routes it to the
appropriate model path (§IV-D workflow step 1, §V-B Rodinia segment files).

``Workload`` is a single kernel-level description; ``Segment`` wraps it with
an execution count and optional host phases (memcpy/sync, paper §IV-E);
applications are lists of Segments (``core/segments.py``).
"""
from __future__ import annotations

import dataclasses
import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

VALID_CLASSES = ("memory", "compute", "balanced", "stencil")

# Layout of the packed numeric vector stashed on every Workload (column
# indices into the float64 matrix the batch backends build with one
# zero-copy np.frombuffer over the concatenated per-workload buffers).
NV_FLOPS, NV_BYTES, NV_WS_OR_BYTES, NV_WS, NV_IRREGULAR, NV_CONCURRENT, \
    NV_DEVICES, NV_K_TILES, NV_NUM_CTAS, NV_BYTES_PER_CTA, NV_TMA_P, \
    NV_COMP_BYTES, NV_COMP_RATIO, NV_VGPR, NV_MATRIX, NV_HAS_GEMM, \
    NV_GM, NV_GN, NV_GK, NV_GMN, NV_BM, NV_BN, NV_BK = range(23)

_NVEC_PACK = struct.Struct("23d").pack


@dataclass(frozen=True)
class TileConfig:
    """GEMM-style tile geometry (bM, bN, bK per CTA; paper Eq. 3)."""

    bm: int = 128
    bn: int = 128
    bk: int = 32

    @property
    def flops_per_tile_step(self) -> float:
        # one K-step of an MMA tile: 2*bM*bN*bK
        return 2.0 * self.bm * self.bn * self.bk

    def accum_bytes(self, accum_bytes_per_elem: float = 4.0) -> float:
        # accumulator tile resident in TMEM/VGPR: bM x bN
        return self.bm * self.bn * accum_bytes_per_elem


_DEFAULT_TILE = TileConfig()


@dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    def bytes_moved(self, in_bytes: float, out_bytes: float) -> float:
        return (self.m * self.k + self.k * self.n) * in_bytes + \
            self.m * self.n * out_bytes


@dataclass(frozen=True)
class Workload:
    """One kernel: the model's unit of prediction.

    Required inputs per paper §IV-G: for Blackwell, tile dims, K_tiles, bytes
    per CTA, TMA participants P, alpha; for MI300A, tile dims, K_tiles,
    bytes, hit rates, occupancy.  All optional fields default to values that
    route the workload through the generic path.
    """

    name: str
    wclass: str                      # memory | compute | balanced | stencil
    flops: float                     # total FLOPs (profiler- or FP-derived)
    bytes: float                     # total bytes moved to/from HBM
    precision: str = "fp32"
    matrix: bool = False             # uses tensor/matrix units?
    working_set_bytes: float = 0.0   # W for h_LLC(W) / B_eff(W)

    # --- tiled-GEMM path inputs (Blackwell stage model / MI300A tile model)
    gemm: Optional[GemmShape] = None
    tile: Optional[TileConfig] = None
    num_ctas: int = 0                # grid size (Eq. 14)
    k_tiles: int = 0                 # K-step count per CTA
    tma_participants: int = 1        # multicast P (Eq. 4)
    bytes_per_cta: float = 0.0

    # --- MI300A occupancy inputs
    vgpr_per_workitem: int = 64      # -> VGPR per wavefront = 64*vgpr
    hit_rates: Dict[str, float] = field(default_factory=dict)  # h_l1,h_l2,h_llc
    num_loads: float = 0.0           # N_loads for Eq. 10 latency walk

    # --- decompression (Blackwell Eq. 5)
    compressed_bytes: float = 0.0
    compression_ratio: float = 1.0

    # --- irregularity flags (paper Obs. 2: accuracy boundary)
    irregular: bool = False          # pointer-chasing / data-dependent access
    atomics: bool = False

    # --- concurrency (paper §IV-A6 / §IV-B)
    concurrent_kernels: int = 1
    num_devices: int = 1

    def __post_init__(self):
        if self.wclass not in VALID_CLASSES:
            raise ValueError(
                f"workload class {self.wclass!r} not in {VALID_CLASSES}")
        if self.flops < 0 or self.bytes < 0:
            raise ValueError("flops/bytes must be non-negative")
        g, t = self.gemm, self.tile
        object.__setattr__(self, "_nvec", _NVEC_PACK(
            self.flops, self.bytes,
            self.working_set_bytes or self.bytes, self.working_set_bytes,
            self.irregular, self.concurrent_kernels, self.num_devices,
            self.k_tiles, self.num_ctas, self.bytes_per_cta,
            self.tma_participants, self.compressed_bytes,
            self.compression_ratio, self.vgpr_per_workitem,
            self.matrix, g is not None,
            g.m if g is not None else 0, g.n if g is not None else 0,
            g.k if g is not None else 0,
            g.m * g.n if g is not None else 0,
            (t or _DEFAULT_TILE).bm, (t or _DEFAULT_TILE).bn,
            (t or _DEFAULT_TILE).bk))

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)

    def replace(self, **kw) -> "Workload":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class HostPhase:
    """Host-device transfer or sync episode (paper Eq. 15, §IV-E)."""

    kind: str                        # "h2d" | "d2h" | "sync"
    bytes: float = 0.0
    count: int = 1


@dataclass(frozen=True)
class Segment:
    """One application segment: a kernel repeated n_exec times plus host
    phases (paper §V-B 'Rodinia multi-segment modeling')."""

    workload: Workload
    n_exec: int = 1
    host_phases: Tuple[HostPhase, ...] = ()
    extra_kernels: int = 0           # multi-kernel segments (paper §IV-F)

    def __post_init__(self):
        if self.n_exec < 0:
            raise ValueError("n_exec must be >= 0")


@dataclass(frozen=True)
class TimeBreakdown:
    """Prediction output: total + per-stage terms (all seconds)."""

    total: float
    compute: float = 0.0
    memory: float = 0.0
    io_effective: float = 0.0
    sync: float = 0.0
    launch: float = 0.0
    writeback: float = 0.0
    collective: float = 0.0
    overhead: float = 0.0
    detail: Dict[str, float] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute,
                 "memory": max(self.memory, self.io_effective),
                 "collective": self.collective}
        return max(terms, key=terms.get)

    def scaled(self, factor: float) -> "TimeBreakdown":
        return TimeBreakdown(
            total=self.total * factor,
            compute=self.compute * factor,
            memory=self.memory * factor,
            io_effective=self.io_effective * factor,
            sync=self.sync * factor,
            launch=self.launch * factor,
            writeback=self.writeback * factor,
            collective=self.collective * factor,
            overhead=self.overhead * factor,
            detail={k: v * factor for k, v in self.detail.items()},
        )


# ---------------------------------------------------------------------------
# Compact row form of TimeBreakdown (SweepEngine hot path).
#
# A row is ((total, compute, memory, io_effective, sync, launch, writeback,
# collective, overhead), detail_keys, detail_values) — three immutable
# tuples.  Vectorized model backends emit rows via C-level zips of
# ``.tolist()`` columns, the engine memoizes them without defensive copies,
# and full TimeBreakdown objects materialize lazily on access.
# ---------------------------------------------------------------------------

TB_FIELDS = ("total", "compute", "memory", "io_effective", "sync", "launch",
             "writeback", "collective", "overhead")

#: (field_values, detail_keys, detail_values)
Row = Tuple[Tuple[float, ...], Tuple[str, ...], Tuple[float, ...]]


def nvec_matrix(ws) -> np.ndarray:
    """(n, 23) float64 view over the packed per-workload vectors — the
    zero-copy bulk extraction the batch backends build columns from."""
    return np.frombuffer(b"".join([w._nvec for w in ws]),
                         dtype=np.float64).reshape(len(ws), 23)


def tb_from_row(row: Row) -> TimeBreakdown:
    """Materialize a TimeBreakdown from its row form (bypasses the frozen
    dataclass __init__/__setattr__ — the row is already validated model
    output)."""
    tb = TimeBreakdown.__new__(TimeBreakdown)
    d = dict(zip(TB_FIELDS, row[0]))
    d["detail"] = dict(zip(row[1], row[2]))
    object.__setattr__(tb, "__dict__", d)
    return tb


def row_from_tb(tb: TimeBreakdown) -> Row:
    """Inverse of ``tb_from_row`` (scalar-fallback paths)."""
    return ((tb.total, tb.compute, tb.memory, tb.io_effective, tb.sync,
             tb.launch, tb.writeback, tb.collective, tb.overhead),
            tuple(tb.detail.keys()), tuple(tb.detail.values()))


def gemm_workload(name: str, m: int, n: int, k: int, *,
                  precision: str = "fp16",
                  tile: TileConfig = TileConfig(),
                  wclass: str = "compute",
                  out_precision: Optional[str] = None) -> Workload:
    """Convenience constructor for tiled-GEMM workloads (the paper's
    compute-bound validation class)."""
    from .hardware import BYTES_PER_ELEM

    in_b = BYTES_PER_ELEM[precision]
    out_b = BYTES_PER_ELEM[out_precision or precision]
    shape = GemmShape(m, n, k)
    num_ctas = -(-m // tile.bm) * -(-n // tile.bn)
    k_tiles = -(-k // tile.bk)
    # per-CTA HBM traffic for one K-step: an A tile + a B tile
    bytes_per_cta = (tile.bm * tile.bk + tile.bk * tile.bn) * in_b
    ws = min(shape.bytes_moved(in_b, out_b),
             (m * k + k * n + m * n) * in_b)
    return Workload(
        name=name, wclass=wclass,
        flops=shape.flops,
        bytes=shape.bytes_moved(in_b, out_b),
        precision=precision, matrix=True,
        working_set_bytes=ws,
        gemm=shape, tile=tile,
        num_ctas=num_ctas, k_tiles=k_tiles,
        bytes_per_cta=bytes_per_cta,
    )


def streaming_workload(name: str, nbytes: float, *,
                       flops_per_byte: float = 0.125,
                       precision: str = "fp32",
                       wclass: str = "memory",
                       irregular: bool = False) -> Workload:
    """Memory-bound vector ops (add/copy/transpose/reduction class)."""
    return Workload(
        name=name, wclass=wclass,
        flops=nbytes * flops_per_byte,
        bytes=nbytes,
        precision=precision, matrix=False,
        working_set_bytes=nbytes,
        irregular=irregular,
    )

"""Workload / segment schema.

The paper characterizes every kernel or application segment by FLOPs, bytes,
class, tile geometry, working set and execution count, then routes it to the
appropriate model path (§IV-D workflow step 1, §V-B Rodinia segment files).

``Workload`` is a single kernel-level description; ``Segment`` wraps it with
an execution count and optional host phases (memcpy/sync, paper §IV-E);
applications are lists of Segments (``core/segments.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

VALID_CLASSES = ("memory", "compute", "balanced", "stencil")

# Layout of the packed numeric vector stashed on every Workload (column
# indices into the float64 matrix the batch backends build with one
# zero-copy np.frombuffer over the concatenated per-workload buffers).
# This is also the column layout of ``WorkloadTable.cols`` — the two forms
# are interconvertible row-for-row, byte-for-byte.
NV_FLOPS, NV_BYTES, NV_WS_OR_BYTES, NV_WS, NV_IRREGULAR, NV_CONCURRENT, \
    NV_DEVICES, NV_K_TILES, NV_NUM_CTAS, NV_BYTES_PER_CTA, NV_TMA_P, \
    NV_COMP_BYTES, NV_COMP_RATIO, NV_VGPR, NV_MATRIX, NV_HAS_GEMM, \
    NV_GM, NV_GN, NV_GK, NV_GMN, NV_BM, NV_BN, NV_BK, \
    NV_NUM_LOADS, NV_ATOMICS, NV_HAS_TILE = range(26)

NV_COLS = 26

_NVEC_PACK = struct.Struct(f"{NV_COLS}d").pack


@dataclass(frozen=True)
class TileConfig:
    """GEMM-style tile geometry (bM, bN, bK per CTA; paper Eq. 3)."""

    bm: int = 128
    bn: int = 128
    bk: int = 32

    @property
    def flops_per_tile_step(self) -> float:
        # one K-step of an MMA tile: 2*bM*bN*bK
        return 2.0 * self.bm * self.bn * self.bk

    def accum_bytes(self, accum_bytes_per_elem: float = 4.0) -> float:
        # accumulator tile resident in TMEM/VGPR: bM x bN
        return self.bm * self.bn * accum_bytes_per_elem


_DEFAULT_TILE = TileConfig()


@dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    def bytes_moved(self, in_bytes: float, out_bytes: float) -> float:
        return (self.m * self.k + self.k * self.n) * in_bytes + \
            self.m * self.n * out_bytes


@dataclass(frozen=True)
class Workload:
    """One kernel: the model's unit of prediction.

    Required inputs per paper §IV-G: for Blackwell, tile dims, K_tiles, bytes
    per CTA, TMA participants P, alpha; for MI300A, tile dims, K_tiles,
    bytes, hit rates, occupancy.  All optional fields default to values that
    route the workload through the generic path.
    """

    name: str
    wclass: str                      # memory | compute | balanced | stencil
    flops: float                     # total FLOPs (profiler- or FP-derived)
    bytes: float                     # total bytes moved to/from HBM
    precision: str = "fp32"
    matrix: bool = False             # uses tensor/matrix units?
    working_set_bytes: float = 0.0   # W for h_LLC(W) / B_eff(W)

    # --- tiled-GEMM path inputs (Blackwell stage model / MI300A tile model)
    gemm: Optional[GemmShape] = None
    tile: Optional[TileConfig] = None
    num_ctas: int = 0                # grid size (Eq. 14)
    k_tiles: int = 0                 # K-step count per CTA
    tma_participants: int = 1        # multicast P (Eq. 4)
    bytes_per_cta: float = 0.0

    # --- MI300A occupancy inputs
    vgpr_per_workitem: int = 64      # -> VGPR per wavefront = 64*vgpr
    hit_rates: Dict[str, float] = field(default_factory=dict)  # h_l1,h_l2,h_llc
    num_loads: float = 0.0           # N_loads for Eq. 10 latency walk

    # --- decompression (Blackwell Eq. 5)
    compressed_bytes: float = 0.0
    compression_ratio: float = 1.0

    # --- irregularity flags (paper Obs. 2: accuracy boundary)
    irregular: bool = False          # pointer-chasing / data-dependent access
    atomics: bool = False

    # --- concurrency (paper §IV-A6 / §IV-B)
    concurrent_kernels: int = 1
    num_devices: int = 1

    def __post_init__(self):
        if self.wclass not in VALID_CLASSES:
            raise ValueError(
                f"workload class {self.wclass!r} not in {VALID_CLASSES}")
        if self.flops < 0 or self.bytes < 0:
            raise ValueError("flops/bytes must be non-negative")

    @property
    def _nvec(self) -> bytes:
        """Packed NV_COLS-double numeric vector, memoized on the (frozen)
        instance.  Lazy so plain construction / ``replace()`` round-trips do
        not pay the struct repack; the buffer is built once on first use by
        the batch backends or the engine's content keys."""
        buf = self.__dict__.get("_nvec_buf")
        if buf is None:
            g, t = self.gemm, self.tile
            buf = _NVEC_PACK(
                self.flops, self.bytes,
                self.working_set_bytes or self.bytes, self.working_set_bytes,
                self.irregular, self.concurrent_kernels, self.num_devices,
                self.k_tiles, self.num_ctas, self.bytes_per_cta,
                self.tma_participants, self.compressed_bytes,
                self.compression_ratio, self.vgpr_per_workitem,
                self.matrix, g is not None,
                g.m if g is not None else 0, g.n if g is not None else 0,
                g.k if g is not None else 0,
                g.m * g.n if g is not None else 0,
                (t or _DEFAULT_TILE).bm, (t or _DEFAULT_TILE).bn,
                (t or _DEFAULT_TILE).bk,
                self.num_loads, self.atomics, t is not None)
            object.__setattr__(self, "_nvec_buf", buf)
        return buf

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)

    def replace(self, **kw) -> "Workload":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class HostPhase:
    """Host-device transfer or sync episode (paper Eq. 15, §IV-E)."""

    kind: str                        # "h2d" | "d2h" | "sync"
    bytes: float = 0.0
    count: int = 1


@dataclass(frozen=True)
class Segment:
    """One application segment: a kernel repeated n_exec times plus host
    phases (paper §V-B 'Rodinia multi-segment modeling')."""

    workload: Workload
    n_exec: int = 1
    host_phases: Tuple[HostPhase, ...] = ()
    extra_kernels: int = 0           # multi-kernel segments (paper §IV-F)

    def __post_init__(self):
        if self.n_exec < 0:
            raise ValueError("n_exec must be >= 0")


@dataclass(frozen=True)
class TimeBreakdown:
    """Prediction output: total + per-stage terms (all seconds)."""

    total: float
    compute: float = 0.0
    memory: float = 0.0
    io_effective: float = 0.0
    sync: float = 0.0
    launch: float = 0.0
    writeback: float = 0.0
    collective: float = 0.0
    overhead: float = 0.0
    detail: Dict[str, float] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute,
                 "memory": max(self.memory, self.io_effective),
                 "collective": self.collective}
        return max(terms, key=terms.get)

    def scaled(self, factor: float) -> "TimeBreakdown":
        return TimeBreakdown(
            total=self.total * factor,
            compute=self.compute * factor,
            memory=self.memory * factor,
            io_effective=self.io_effective * factor,
            sync=self.sync * factor,
            launch=self.launch * factor,
            writeback=self.writeback * factor,
            collective=self.collective * factor,
            overhead=self.overhead * factor,
            detail={k: v * factor for k, v in self.detail.items()},
        )


# ---------------------------------------------------------------------------
# Compact row form of TimeBreakdown (SweepEngine hot path).
#
# A row is ((total, compute, memory, io_effective, sync, launch, writeback,
# collective, overhead), detail_keys, detail_values) — three immutable
# tuples.  Vectorized model backends emit rows via C-level zips of
# ``.tolist()`` columns, the engine memoizes them without defensive copies,
# and full TimeBreakdown objects materialize lazily on access.
# ---------------------------------------------------------------------------

TB_FIELDS = ("total", "compute", "memory", "io_effective", "sync", "launch",
             "writeback", "collective", "overhead")

#: (field_values, detail_keys, detail_values)
Row = Tuple[Tuple[float, ...], Tuple[str, ...], Tuple[float, ...]]


def nvec_matrix(ws) -> np.ndarray:
    """(n, NV_COLS) float64 view over the packed per-workload vectors — the
    zero-copy bulk extraction the batch backends build columns from."""
    return np.frombuffer(b"".join([w._nvec for w in ws]),
                         dtype=np.float64).reshape(len(ws), NV_COLS)


def tb_from_row(row: Row) -> TimeBreakdown:
    """Materialize a TimeBreakdown from its row form (bypasses the frozen
    dataclass __init__/__setattr__ — the row is already validated model
    output)."""
    tb = TimeBreakdown.__new__(TimeBreakdown)
    d = dict(zip(TB_FIELDS, row[0]))
    d["detail"] = dict(zip(row[1], row[2]))
    object.__setattr__(tb, "__dict__", d)
    return tb


def row_from_tb(tb: TimeBreakdown) -> Row:
    """Inverse of ``tb_from_row`` (scalar-fallback paths)."""
    return ((tb.total, tb.compute, tb.memory, tb.io_effective, tb.sync,
             tb.launch, tb.writeback, tb.collective, tb.overhead),
            tuple(tb.detail.keys()), tuple(tb.detail.values()))


# ---------------------------------------------------------------------------
# Columnar prediction output (WorkloadTable hot path).
#
# A model backend's table core returns its nine TimeBreakdown fields and its
# detail terms as whole columns — NumPy arrays, or plain floats for terms
# constant across the batch.  Reductions (argmin/top-k/pareto) run on these
# columns directly; per-row ``Row`` tuples / TimeBreakdowns materialize only
# for the winners.
# ---------------------------------------------------------------------------

class TableCols:
    """Columnar prediction result: one route, uniform detail keys."""

    __slots__ = ("n", "fields", "detail_keys", "detail_vals")

    def __init__(self, n: int, fields: Tuple, detail_keys: Tuple[str, ...],
                 detail_vals: Tuple):
        self.n = n
        self.fields = fields            # 9 items: ndarray or python float
        self.detail_keys = detail_keys
        self.detail_vals = detail_vals  # ndarray or python float each
        # results are cached whole by the engine and column reads hand out
        # these arrays directly — freeze them so a caller's in-place edit
        # (res.totals *= 1e3) raises instead of poisoning the cache
        for c in fields + detail_vals:
            if isinstance(c, np.ndarray) and c.flags.writeable:
                c.flags.writeable = False

    def totals(self) -> np.ndarray:
        t = self.fields[0]
        return t if isinstance(t, np.ndarray) else np.full(self.n, t)

    def field_col(self, j: int) -> np.ndarray:
        f = self.fields[j]
        return f if isinstance(f, np.ndarray) else np.full(self.n, f)

    def row(self, i: int) -> Row:
        f = tuple(float(c[i]) if isinstance(c, np.ndarray) else c
                  for c in self.fields)
        d = tuple(float(v[i]) if isinstance(v, np.ndarray) else v
                  for v in self.detail_vals)
        return (f, self.detail_keys, d)

    def rows(self) -> List[Row]:
        from itertools import repeat
        n = self.n
        cols = [c.tolist() if isinstance(c, np.ndarray) else repeat(c, n)
                for c in self.fields]
        dcols = [v.tolist() if isinstance(v, np.ndarray) else repeat(v, n)
                 for v in self.detail_vals]
        return list(zip(zip(*cols), repeat(self.detail_keys, n),
                        zip(*dcols)))


class RowsCols:
    """Column-interface adapter over precomputed Row tuples (scalar-fallback
    segments, e.g. CDNA3 workloads with explicit hit rates)."""

    __slots__ = ("n", "_rows")

    def __init__(self, rows: List[Row]):
        self._rows = rows
        self.n = len(rows)

    def totals(self) -> np.ndarray:
        return np.fromiter((r[0][0] for r in self._rows), np.float64, self.n)

    def field_col(self, j: int) -> np.ndarray:
        return np.fromiter((r[0][j] for r in self._rows), np.float64, self.n)

    def row(self, i: int) -> Row:
        return self._rows[i]

    def rows(self) -> List[Row]:
        return self._rows


class SegmentedCols:
    """Columnar result assembled from disjoint row-index segments (mixed
    routing inside one table, e.g. tiled-GEMM vs streaming rows on the
    Blackwell stage model — the segments carry different detail keys)."""

    __slots__ = ("n", "segments", "_owner", "_local")

    def __init__(self, n: int, segments: List[Tuple[np.ndarray, object]]):
        self.n = n
        self.segments = segments
        owner = np.empty(n, dtype=np.intp)
        local = np.empty(n, dtype=np.intp)
        for s, (idx, _) in enumerate(segments):
            owner[idx] = s
            local[idx] = np.arange(len(idx))
        self._owner = owner
        self._local = local

    def totals(self) -> np.ndarray:
        out = np.empty(self.n, dtype=np.float64)
        for idx, seg in self.segments:
            out[idx] = seg.totals()
        return out

    def field_col(self, j: int) -> np.ndarray:
        out = np.empty(self.n, dtype=np.float64)
        for idx, seg in self.segments:
            out[idx] = seg.field_col(j)
        return out

    def row(self, i: int) -> Row:
        return self.segments[self._owner[i]][1].row(int(self._local[i]))

    def rows(self) -> List[Row]:
        out: List[Optional[Row]] = [None] * self.n
        for idx, seg in self.segments:
            for i, row in zip(idx.tolist(), seg.rows()):
                out[i] = row
        return out  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# WorkloadTable: struct-of-arrays workload batch.
#
# Sweeps (tile lattices, precision ladders, cartesian what-if grids) never
# need per-config ``Workload`` dataclasses: the table holds the NV_COLS
# float64 matrix directly plus vocab-coded non-numeric columns, and the
# model backends consume the columns as-is.  Scalar ``Workload`` objects
# materialize lazily (``workload(i)``) for winners only.
# ---------------------------------------------------------------------------

#: Workload fields settable as cartesian grid axes -> their NV column.
CARTESIAN_COLS = {
    "flops": NV_FLOPS, "bytes": NV_BYTES,
    "working_set_bytes": NV_WS, "k_tiles": NV_K_TILES,
    "num_ctas": NV_NUM_CTAS, "bytes_per_cta": NV_BYTES_PER_CTA,
    "tma_participants": NV_TMA_P, "compressed_bytes": NV_COMP_BYTES,
    "compression_ratio": NV_COMP_RATIO, "vgpr_per_workitem": NV_VGPR,
    "num_loads": NV_NUM_LOADS, "concurrent_kernels": NV_CONCURRENT,
    "num_devices": NV_DEVICES, "irregular": NV_IRREGULAR,
    "matrix": NV_MATRIX,
}


def _encode(values: List[str]):
    """Small-vocabulary string column -> (codes intp array, vocab tuple)."""
    vocab: Dict[str, int] = {}
    sd = vocab.setdefault
    codes = [sd(v, len(vocab)) for v in values]
    return np.array(codes, dtype=np.intp), tuple(vocab)


class WorkloadTable:
    """Struct-of-arrays batch of workloads (the columnar sweep unit).

    Treat instances as immutable: the engine caches results under a content
    token computed once per table.  ``cols`` is the (n, NV_COLS) float64
    matrix in ``NV_*`` column order; ``precision``/``wclass`` are vocab-coded
    per-row; ``hit_rates`` (rarely used — CDNA3 Eq. 10 inputs) is either
    None or a per-row tuple of dicts.
    """

    __slots__ = ("cols", "precision_codes", "precision_vocab",
                 "wclass_codes", "wclass_vocab", "names", "hit_rates",
                 "_token")

    def __init__(self, cols: np.ndarray, precision_codes: np.ndarray,
                 precision_vocab: Tuple[str, ...],
                 wclass_codes: np.ndarray, wclass_vocab: Tuple[str, ...],
                 names=None, hit_rates=None):
        self.cols = cols
        self.precision_codes = precision_codes
        self.precision_vocab = precision_vocab
        self.wclass_codes = wclass_codes
        self.wclass_vocab = wclass_vocab
        self.names = names          # tuple per-row | shared str | None
        self.hit_rates = hit_rates  # None | tuple of (dict | None)
        self._token = None
        if cols.flags.writeable:
            cols.flags.writeable = False

    # ------------------------------------------------------------ basics
    def __len__(self) -> int:
        return self.cols.shape[0]

    @property
    def n(self) -> int:
        return self.cols.shape[0]

    def name(self, i: int) -> str:
        if isinstance(self.names, tuple):
            return self.names[i]
        return f"{self.names or 'table'}#{i}"

    def content_token(self) -> Tuple:
        """Hashable content identity (what the engine's whole-table cache is
        keyed on): a fixed-size blake2b digest of the column bytes + the
        small vocab/hit-rate tuples, so neither the token nor the cache key
        retains a raw copy of the table.  Computed once and cached —
        replays of the same table object skip even the digest."""
        tok = self._token
        if tok is None:
            hr = None if self.hit_rates is None else tuple(
                tuple(sorted(h.items())) if h else ()
                for h in self.hit_rates)
            h = hashlib.blake2b(digest_size=16)
            h.update(self.cols.tobytes())
            h.update(self.precision_codes.tobytes())
            h.update(self.wclass_codes.tobytes())
            tok = (h.digest(), len(self), self.precision_vocab,
                   self.wclass_vocab, hr)
            self._token = tok
        return tok

    # --------------------------------------------------- vocab broadcasts
    def per_precision(self, fn) -> np.ndarray:
        """Broadcast fn(precision) over rows — fn runs once per distinct
        precision, exactly like the list-path per-batch lookup maps."""
        vals = np.array([fn(p) for p in self.precision_vocab],
                        dtype=np.float64)
        return vals[self.precision_codes]

    def per_precision_matrix(self, fn) -> np.ndarray:
        """Broadcast fn(precision, matrix_flag) over rows; fn runs once per
        distinct (precision, matrix) pair actually present."""
        mat = (self.cols[:, NV_MATRIX] != 0).astype(np.intp)
        pair = self.precision_codes * 2 + mat
        vals = np.empty(2 * len(self.precision_vocab), dtype=np.float64)
        for pid in np.unique(pair):
            vals[pid] = fn(self.precision_vocab[int(pid) // 2],
                           bool(int(pid) % 2))
        return vals[pair]

    def per_wclass(self, fn) -> np.ndarray:
        vals = np.array([fn(c) for c in self.wclass_vocab], dtype=np.float64)
        return vals[self.wclass_codes]

    # ------------------------------------------------------------- views
    def take(self, idx: np.ndarray) -> "WorkloadTable":
        """Row-subset table (mixed-route splits inside the backends)."""
        names = self.names
        if isinstance(names, tuple):
            names = tuple(names[i] for i in idx.tolist())
        hr = self.hit_rates
        if hr is not None:
            hr = tuple(hr[i] for i in idx.tolist())
        return WorkloadTable(
            np.ascontiguousarray(self.cols[idx]),
            self.precision_codes[idx], self.precision_vocab,
            self.wclass_codes[idx], self.wclass_vocab, names, hr)

    def workload(self, i: int) -> Workload:
        """Materialize row ``i`` as a scalar Workload (winners / scalar
        fallbacks only — never the sweep hot path)."""
        r = self.cols[i]
        g = GemmShape(int(r[NV_GM]), int(r[NV_GN]), int(r[NV_GK])) \
            if r[NV_HAS_GEMM] != 0 else None
        t = TileConfig(int(r[NV_BM]), int(r[NV_BN]), int(r[NV_BK])) \
            if r[NV_HAS_TILE] != 0 else None
        hr = {}
        if self.hit_rates is not None and self.hit_rates[i]:
            hr = dict(self.hit_rates[i])
        return Workload(
            name=self.name(i),
            wclass=self.wclass_vocab[self.wclass_codes[i]],
            flops=float(r[NV_FLOPS]), bytes=float(r[NV_BYTES]),
            precision=self.precision_vocab[self.precision_codes[i]],
            matrix=bool(r[NV_MATRIX]),
            working_set_bytes=float(r[NV_WS]),
            gemm=g, tile=t,
            num_ctas=int(r[NV_NUM_CTAS]), k_tiles=int(r[NV_K_TILES]),
            tma_participants=int(r[NV_TMA_P]),
            bytes_per_cta=float(r[NV_BYTES_PER_CTA]),
            vgpr_per_workitem=int(r[NV_VGPR]),
            hit_rates=hr, num_loads=float(r[NV_NUM_LOADS]),
            compressed_bytes=float(r[NV_COMP_BYTES]),
            compression_ratio=float(r[NV_COMP_RATIO]),
            irregular=bool(r[NV_IRREGULAR]), atomics=bool(r[NV_ATOMICS]),
            concurrent_kernels=int(r[NV_CONCURRENT]),
            num_devices=int(r[NV_DEVICES]))

    # ------------------------------------------------------ constructors
    @classmethod
    def from_workloads(cls, ws: Sequence[Workload]) -> "WorkloadTable":
        """Columnar view over existing Workload objects (one zero-copy
        frombuffer over the packed per-workload vectors)."""
        pc, pv = _encode([w.precision for w in ws])
        wc, wv = _encode([w.wclass for w in ws])
        hit_rates = None
        if any(w.hit_rates for w in ws):
            hit_rates = tuple(w.hit_rates or None for w in ws)
        return cls(nvec_matrix(ws), pc, pv, wc, wv,
                   tuple(w.name for w in ws), hit_rates)

    @classmethod
    def _from_base(cls, base: Workload, n: int) -> "WorkloadTable":
        cols = np.tile(np.frombuffer(base._nvec, dtype=np.float64), (n, 1))
        codes = np.zeros(n, dtype=np.intp)
        hr = tuple([base.hit_rates] * n) if base.hit_rates else None
        return cls(cols, codes, (base.precision,), codes.copy(),
                   (base.wclass,), base.name, hr)

    @classmethod
    def tile_lattice(cls, base: Workload,
                     tiles: Sequence[TileConfig]) -> "WorkloadTable":
        """Re-tile ``base`` with every candidate tile — columnar analogue of
        ``cdna3._retile`` per candidate, with the derived grid quantities
        (num_ctas, k_tiles, bytes_per_cta) recomputed vectorized when the
        base carries a GEMM shape."""
        from .hardware import BYTES_PER_ELEM
        n = len(tiles)
        t = cls._from_base(base, n)
        cols = t.cols
        cols.flags.writeable = True
        bm = np.array([c.bm for c in tiles], dtype=np.int64)
        bn = np.array([c.bn for c in tiles], dtype=np.int64)
        bk = np.array([c.bk for c in tiles], dtype=np.int64)
        cols[:, NV_BM] = bm
        cols[:, NV_BN] = bn
        cols[:, NV_BK] = bk
        cols[:, NV_HAS_TILE] = 1.0
        if base.gemm is not None:
            g = base.gemm
            cols[:, NV_NUM_CTAS] = (-(-g.m // bm)) * (-(-g.n // bn))
            cols[:, NV_K_TILES] = -(-g.k // bk)
            in_b = BYTES_PER_ELEM[base.precision]
            cols[:, NV_BYTES_PER_CTA] = (bm * bk + bk * bn) * in_b
        cols.flags.writeable = False
        return t

    @classmethod
    def cartesian(cls, base: Workload, **field_grids) -> "WorkloadTable":
        """Cross-product sweep over Workload fields, columnar end to end.

        Grid keys: any numeric field in ``CARTESIAN_COLS``, plus
        ``precision`` / ``wclass`` (strings, vocab-coded) and ``tile``
        (TileConfig — sets the raw bM/bN/bK columns only; use
        ``tile_lattice`` when the GEMM grid quantities must follow the
        tile).  Row order is C-order over the grids in keyword order.
        """
        keys = list(field_grids)
        grids = [list(field_grids[k]) for k in keys]
        sizes = [len(g) for g in grids]
        n = 1
        for s in sizes:
            n *= s
        if n == 0:
            raise ValueError("empty cartesian grid")
        t = cls._from_base(base, n)
        cols = t.cols
        cols.flags.writeable = True
        idx = np.indices(sizes).reshape(len(sizes), -1)
        prec_codes, prec_vocab = t.precision_codes, t.precision_vocab
        wcls_codes, wcls_vocab = t.wclass_codes, t.wclass_vocab
        for dim, (key, vals) in enumerate(zip(keys, grids)):
            take = idx[dim]
            if key == "precision":
                codes, vocab = _encode([str(v) for v in vals])
                prec_codes, prec_vocab = codes[take], vocab
            elif key == "wclass":
                for v in vals:
                    if v not in VALID_CLASSES:
                        raise ValueError(f"workload class {v!r} not in "
                                         f"{VALID_CLASSES}")
                codes, vocab = _encode([str(v) for v in vals])
                wcls_codes, wcls_vocab = codes[take], vocab
            elif key == "tile":
                cols[:, NV_BM] = np.array([c.bm for c in vals],
                                          dtype=np.float64)[take]
                cols[:, NV_BN] = np.array([c.bn for c in vals],
                                          dtype=np.float64)[take]
                cols[:, NV_BK] = np.array([c.bk for c in vals],
                                          dtype=np.float64)[take]
                cols[:, NV_HAS_TILE] = 1.0
            elif key in CARTESIAN_COLS:
                arr = np.array(vals, dtype=np.float64)[take]
                cols[:, CARTESIAN_COLS[key]] = arr
            else:
                raise ValueError(
                    f"cartesian cannot sweep field {key!r}; valid: "
                    f"{sorted(CARTESIAN_COLS)} + precision/wclass/tile")
        if "bytes" in field_grids or "working_set_bytes" in field_grids:
            ws_col = cols[:, NV_WS]
            cols[:, NV_WS_OR_BYTES] = np.where(ws_col != 0, ws_col,
                                               cols[:, NV_BYTES])
        cols.flags.writeable = False
        return cls(cols, prec_codes, prec_vocab, wcls_codes, wcls_vocab,
                   base.name, t.hit_rates)

    @classmethod
    def concat(cls, tables: Sequence["WorkloadTable"]) -> "WorkloadTable":
        """Stack tables row-wise (e.g. per-shape tile lattices into one
        sweep).  Vocabularies are merged and re-coded."""
        if not tables:
            raise ValueError("concat of zero tables")
        cols = np.vstack([t.cols for t in tables])

        def merge(code_attr, vocab_attr):
            vocab: Dict[str, int] = {}
            parts = []
            for t in tables:
                tv = getattr(t, vocab_attr)
                remap = np.array([vocab.setdefault(v, len(vocab))
                                  for v in tv], dtype=np.intp)
                parts.append(remap[getattr(t, code_attr)])
            return np.concatenate(parts), tuple(vocab)

        pc, pv = merge("precision_codes", "precision_vocab")
        wc, wv = merge("wclass_codes", "wclass_vocab")
        names = None
        if all(isinstance(t.names, tuple) for t in tables):
            names = tuple(nm for t in tables for nm in t.names)
        hit_rates = None
        if any(t.hit_rates is not None for t in tables):
            hit_rates = tuple(
                h for t in tables
                for h in (t.hit_rates or (None,) * len(t)))
        return cls(cols, pc, pv, wc, wv, names, hit_rates)


def gemm_workload(name: str, m: int, n: int, k: int, *,
                  precision: str = "fp16",
                  tile: TileConfig = TileConfig(),
                  wclass: str = "compute",
                  out_precision: Optional[str] = None) -> Workload:
    """Convenience constructor for tiled-GEMM workloads (the paper's
    compute-bound validation class)."""
    from .hardware import BYTES_PER_ELEM

    in_b = BYTES_PER_ELEM[precision]
    out_b = BYTES_PER_ELEM[out_precision or precision]
    shape = GemmShape(m, n, k)
    num_ctas = -(-m // tile.bm) * -(-n // tile.bn)
    k_tiles = -(-k // tile.bk)
    # per-CTA HBM traffic for one K-step: an A tile + a B tile
    bytes_per_cta = (tile.bm * tile.bk + tile.bk * tile.bn) * in_b
    ws = min(shape.bytes_moved(in_b, out_b),
             (m * k + k * n + m * n) * in_b)
    return Workload(
        name=name, wclass=wclass,
        flops=shape.flops,
        bytes=shape.bytes_moved(in_b, out_b),
        precision=precision, matrix=True,
        working_set_bytes=ws,
        gemm=shape, tile=tile,
        num_ctas=num_ctas, k_tiles=k_tiles,
        bytes_per_cta=bytes_per_cta,
    )


def streaming_workload(name: str, nbytes: float, *,
                       flops_per_byte: float = 0.125,
                       precision: str = "fp32",
                       wclass: str = "memory",
                       irregular: bool = False) -> Workload:
    """Memory-bound vector ops (add/copy/transpose/reduction class)."""
    return Workload(
        name=name, wclass=wclass,
        flops=nbytes * flops_per_byte,
        bytes=nbytes,
        precision=precision, matrix=False,
        working_set_bytes=nbytes,
        irregular=irregular,
    )

"""Naive roofline baseline (paper §II-A, Table VI context column).

    T_roofline = max(FLOPs / P_peak, bytes / B_HBM)

Uses ONLY datasheet peaks, ignores cache hierarchies, pipeline stages,
occupancy and launch latency — by design.  The paper keeps it as context to
show why architecture-specific modeling is necessary (>94% error on all
platforms).  We implement it verbatim so benchmarks can reproduce that gap.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from .hardware import HardwareParams
from .workload import Row, TimeBreakdown, Workload, tb_from_row


def predict(w: Workload, hw: HardwareParams) -> TimeBreakdown:
    """Naive roofline prediction: datasheet peaks only."""
    peak = hw.peak_flops(w.precision, matrix=w.matrix)
    t_compute = w.flops / peak if peak > 0 else 0.0
    t_memory = w.bytes / hw.hbm_peak_bw if hw.hbm_peak_bw > 0 else 0.0
    total = max(t_compute, t_memory)
    return TimeBreakdown(total=total, compute=t_compute, memory=t_memory,
                         detail={"path": 0.0})


def predict_table_cols(table, hw: HardwareParams):
    """Columnar ``predict`` over a WorkloadTable (bit-identical per row)."""
    from .workload import NV_BYTES, NV_FLOPS, TableCols
    raw = table.cols
    peak = table.per_precision_matrix(
        lambda p, m: hw.peak_flops(p, matrix=m))
    flops, nbytes = raw[:, NV_FLOPS], raw[:, NV_BYTES]
    with np.errstate(divide="ignore", invalid="ignore"):
        t_compute = np.where(peak > 0, flops / peak, 0.0)
    if hw.hbm_peak_bw > 0:
        t_memory = nbytes / hw.hbm_peak_bw
    else:
        t_memory = np.zeros_like(nbytes)
    total = np.maximum(t_compute, t_memory)
    return TableCols(
        len(table),
        (total, t_compute, t_memory, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0),
        ("path",), (0.0,))


def predict_rows(ws: Sequence[Workload],
                 hw: HardwareParams) -> List[Row]:
    """Vectorized ``predict`` over a workload batch, in row form
    (bit-identical)."""
    from .workload import WorkloadTable
    return predict_table_cols(WorkloadTable.from_workloads(ws), hw).rows()


def predict_batch(ws: Sequence[Workload],
                  hw: HardwareParams) -> List[TimeBreakdown]:
    """Materialized form of ``predict_rows``."""
    return [tb_from_row(r) for r in predict_rows(ws, hw)]


def ridge_intensity(hw: HardwareParams, precision: str = "fp16",
                    matrix: bool = True) -> float:
    """Arithmetic intensity at the roofline ridge point (FLOPs/byte)."""
    return hw.peak_flops(precision, matrix) / hw.hbm_peak_bw

"""Naive roofline baseline (paper §II-A, Table VI context column).

    T_roofline = max(FLOPs / P_peak, bytes / B_HBM)

Uses ONLY datasheet peaks, ignores cache hierarchies, pipeline stages,
occupancy and launch latency — by design.  The paper keeps it as context to
show why architecture-specific modeling is necessary (>94% error on all
platforms).  We implement it verbatim so benchmarks can reproduce that gap.
"""
from __future__ import annotations

from .hardware import HardwareParams
from .workload import TimeBreakdown, Workload


def predict(w: Workload, hw: HardwareParams) -> TimeBreakdown:
    """Naive roofline prediction: datasheet peaks only."""
    peak = hw.peak_flops(w.precision, matrix=w.matrix)
    t_compute = w.flops / peak if peak > 0 else 0.0
    t_memory = w.bytes / hw.hbm_peak_bw if hw.hbm_peak_bw > 0 else 0.0
    total = max(t_compute, t_memory)
    return TimeBreakdown(total=total, compute=t_compute, memory=t_memory,
                         detail={"path": 0.0})


def ridge_intensity(hw: HardwareParams, precision: str = "fp16",
                    matrix: bool = True) -> float:
    """Arithmetic intensity at the roofline ridge point (FLOPs/byte)."""
    return hw.peak_flops(precision, matrix) / hw.hbm_peak_bw

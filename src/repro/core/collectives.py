"""Mesh collective cost model (beyond-paper TPU extension; DESIGN.md §3).

The paper models single-GPU kernels and folds multi-device effects into an
interference term (N-1)*tau.  Our deployment is a 2x16x16 TPU v5e mesh, so
collectives are a first-class pipeline stage.  Ring algorithms on a 2D ICI
torus; the `pod` axis crosses slower DCI links.

Cost of moving B bytes (per-chip shard size) over an axis of size n:

    all-gather       : B * (n-1)          / BW_axis
    reduce-scatter   : B * (n-1) / n      / BW_axis   (B = full tensor/chip view)
    all-reduce       : 2 * B * (n-1) / n  / BW_axis   (RS + AG)
    all-to-all       : B * (n-1) / n      / BW_axis
    collective-permute: B                 / BW_axis   (one hop)

where BW_axis = links_per_axis * link_bw (bidirectional ring: a v5e chip has
one ICI link per mesh direction; both directions usable -> 2x).  We follow
the task-spec roofline convention (collective_bytes / (chips * link_bw)) for
the reported roofline TERM, and this richer model for predicted step time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Sequence, Tuple

from .hardware import HardwareParams

RING_FACTORS = {
    "all-gather": lambda n: float(n - 1),
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


@dataclass(frozen=True)
class MeshSpec:
    """Logical mesh: axis names -> sizes, and which axes are cross-pod."""

    axes: Tuple[Tuple[str, int], ...]          # ordered (name, size)
    slow_axes: Tuple[str, ...] = ("pod",)      # DCI-connected axes

    @property
    def num_devices(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n

    def size(self, axis: str) -> int:
        for name, s in self.axes:
            if name == axis:
                return s
        raise KeyError(f"axis {axis!r} not in mesh {self.axes}")


def axis_bandwidth(mesh: MeshSpec, axis: str, hw: HardwareParams) -> float:
    """Usable bytes/s along one mesh axis (both ring directions)."""
    if axis in mesh.slow_axes:
        return max(hw.dci_link_bw, 1.0) * 2.0
    return max(hw.ici_link_bw, 1.0) * hw.ici_links_per_axis * 2.0


def collective_time(op: str, shard_bytes: float, axis: str,
                    mesh: MeshSpec, hw: HardwareParams) -> float:
    """Seconds for one collective of `op` moving `shard_bytes` per chip."""
    if op not in RING_FACTORS:
        raise ValueError(f"unknown collective {op!r}")
    n = mesh.size(axis)
    if n <= 1:
        return 0.0
    bw = axis_bandwidth(mesh, axis, hw)
    return RING_FACTORS[op](n) * shard_bytes / bw


def schedule_time(ops: Sequence[Tuple[str, float, str]], mesh: MeshSpec,
                  hw: HardwareParams, *, overlap_alpha: float = 0.0
                  ) -> Dict[str, float]:
    """Total + exposed time of a collective schedule.

    ops: sequence of (op_name, shard_bytes, axis).
    overlap_alpha: fraction hidden behind compute (paper's alpha reused).
    Returns dict with total, exposed, and per-op breakdown.
    """
    per_op: Dict[str, float] = {}
    total = 0.0
    for op, nbytes, axis in ops:
        t = collective_time(op, nbytes, axis, mesh, hw)
        per_op[f"{op}@{axis}"] = per_op.get(f"{op}@{axis}", 0.0) + t
        total += t
    return {"total": total,
            "exposed": (1.0 - overlap_alpha) * total,
            **per_op}


def roofline_collective_term(collective_bytes: float, num_chips: int,
                             link_bw: float) -> float:
    """Task-spec roofline term: collective_bytes / (chips * link_bw)."""
    return collective_bytes / (max(num_chips, 1) * link_bw)

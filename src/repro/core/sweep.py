"""Batched + columnar sweep-prediction engine with two-tier memoization.

The paper's headline workflow prices thousands of candidate
(workload x hardware x precision x tile) configurations through the
analytical models and returns the argmin (§IV-B adaptive tile selection,
§IV-D routing).  A scalar Python ``predict()`` call per configuration makes
that the slowest path in the repo; microbenchmark sweeps span 10^3-10^4
points per kernel family — exactly the regime where batching pays off.

Two batched front ends share the NumPy-vectorized model backends
(``blackwell``/``cdna3``/``tpu``/``generic``/``roofline``):

``SweepEngine.predict_batch(workloads, hw)``
    List-of-``Workload`` batches.  Backends emit compact immutable row
    tuples; ``TimeBreakdown`` objects materialize lazily when a result is
    indexed.  Rows are memoized per row under a content key (the workload's
    packed ``_nvec`` buffer + non-numeric fields + HardwareParams content +
    route) in a bounded LRU, and whole batches short-circuit through a
    batch-digest tier so replaying an identical sweep never walks the
    per-row cache.

``predict_table(table, hw)`` / ``SweepEngine.predict_table``
    Columnar ``WorkloadTable`` sweeps.  The backends run directly on the
    table's column arrays and return columns; nothing per-row is built
    until a winner is materialized.  Fused reductions ``argmin_table``,
    ``topk_table`` and ``pareto_table`` reduce on the column arrays and
    materialize only the winning rows' ``TimeBreakdown``s.  Whole tables
    memoize under a per-table content token (tier 1); there is no per-row
    tier for tables — a table is the unit of reuse.

Columnar-table contract (when to use what):

  * scalar ``predict.predict(w, hw)`` — one-off questions, host phases,
    anything that wants a single ``TimeBreakdown`` now.  Delegates here as
    a batch of one and is memoized per row.
  * ``predict_batch`` — you already hold ``Workload`` objects (validation
    suites, calibration fits that need per-case TimeBreakdowns).
  * ``WorkloadTable`` + ``predict_table``/``argmin_table``/``topk_table``
    — sweeps you *construct*: tile lattices (``WorkloadTable.tile_lattice``),
    cartesian what-if grids (``WorkloadTable.cartesian``).  Never builds
    per-config Workload dataclasses, never builds per-config rows; ~an
    order of magnitude faster end to end than predict_batch over a
    freshly-built Workload list (benchmarks/sweep_bench.py).

Guarantees:
  * batch-of-1 results are bit-identical to the pre-refactor scalar
    ``predict(w, hw)`` for every route (verified by tests/test_sweep.py),
    and table results are bit-identical per row to predict_batch
    (tests/test_workload_table.py),
  * cached rows are immutable tuples — no defensive copies, no
    cache-poisoning via caller-mutated detail dicts,
  * calibration is applied at materialization time, after the cache, so
    one cache entry serves calibrated and uncalibrated callers,
  * all caches are LRU-bounded (``max_entries`` rows, ``max_batch_entries``
    batch digests, ``max_table_entries`` table results) and lock-protected;
    concurrent ``predict_batch`` calls from many threads return identical
    results with the bounds maintained.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import blackwell, cdna3, generic, roofline
from .hardware import HardwareParams
from .workload import Row, TB_FIELDS, TimeBreakdown, Workload, \
    WorkloadTable, row_from_tb, tb_from_row

ROUTES = ("stage", "wavefront", "tpu", "generic", "roofline")

#: below this many cache misses the engine evaluates via the scalar model
#: functions — NumPy dispatch overhead on near-empty arrays costs more than
#: the per-call Python it saves (crossover measured ~10-15 workloads).
SCALAR_CUTOFF = 16

_FAMILY_ROUTE = {
    "blackwell": "stage",
    "cdna": "wavefront",
    "tpu": "tpu",
    "generic": "generic",
}


def default_route(hw: HardwareParams) -> str:
    """Architecture routing (paper §IV-D workflow step 2/3)."""
    return _FAMILY_ROUTE.get(hw.model_family, "generic")


def _rows_fn(route: str):
    if route == "stage":
        return blackwell.predict_rows
    if route == "wavefront":
        return cdna3.predict_rows
    if route == "tpu":
        from . import tpu  # local import: tpu.py depends on collectives
        return tpu.predict_rows
    if route == "generic":
        return generic.predict_rows
    if route == "roofline":
        return roofline.predict_rows
    raise ValueError(f"unknown model route {route!r}")


def _cols_fn(route: str):
    if route == "stage":
        return blackwell.predict_table_cols
    if route == "wavefront":
        return cdna3.predict_table_cols
    if route == "tpu":
        from . import tpu
        return tpu.predict_table_cols
    if route == "generic":
        return generic.predict_table_cols
    if route == "roofline":
        return roofline.predict_table_cols
    raise ValueError(f"unknown model route {route!r}")


def _scalar_fn(route: str):
    if route == "stage":
        return blackwell.predict
    if route == "wavefront":
        return cdna3.predict
    if route == "tpu":
        from . import tpu
        return tpu.predict
    if route == "generic":
        return generic.predict
    if route == "roofline":
        return roofline.predict
    raise ValueError(f"unknown model route {route!r}")


def _eval_rows(route: str, ws: Sequence[Workload],
               hw: HardwareParams) -> List[Row]:
    """Vectorized for real batches, scalar-reference for tiny ones
    (identical results either way — that equivalence is the engine's core
    invariant, enforced by tests/test_sweep.py)."""
    if len(ws) < SCALAR_CUTOFF:
        fn = _scalar_fn(route)
        return [row_from_tb(fn(w, hw)) for w in ws]
    return _rows_fn(route)(ws, hw)


def workload_key(w: Workload) -> Tuple:
    """Content key for a workload: the packed numeric vector (every
    model-visible numeric field, one memoized bytes object) plus the
    non-numeric fields.  ``name`` is excluded — predictions depend only on
    the characterization, so renamed duplicates share cache entries."""
    return (w._nvec, w.wclass, w.precision,
            tuple(sorted(w.hit_rates.items())) if w.hit_rates else ())


_HW_TOKENS: Dict[Tuple, Tuple[str, int]] = {}
_HW_TOKENS_LOCK = threading.Lock()


def hardware_key(hw: HardwareParams) -> Tuple[str, int]:
    """Compact content token for a parameter file.  The registry allows
    re-registering updated parameters under the same name (e.g. a
    re-calibrated ``cpu_host``), so the name alone would serve stale
    predictions.  The full field tuple is interned to a small (name, id)
    token — cache keys must stay cheap to hash, and the content tuple is
    ~50 nested fields — and the token is stashed on the (frozen) instance
    so the content walk happens once per HardwareParams object."""
    cached = getattr(hw, "_sweep_content_token", None)
    if cached is not None:
        return cached
    out = []
    for f in dataclasses.fields(hw):
        v = getattr(hw, f.name)
        if isinstance(v, dict):
            v = tuple(sorted(v.items()))
        out.append(v)
    content = tuple(out)
    with _HW_TOKENS_LOCK:
        token = _HW_TOKENS.get(content)
        if token is None:
            token = (hw.name, len(_HW_TOKENS))
            _HW_TOKENS[content] = token
    try:
        object.__setattr__(hw, "_sweep_content_token", token)
    except Exception:
        pass
    return token


class BatchResult(Sequence):
    """Lazy sequence view over prediction rows.

    Indexing / iterating materializes ``TimeBreakdown`` objects (with
    calibration applied, when given); ``totals`` exposes the raw totals as
    a NumPy array without materializing anything — the argmin fast path.
    """

    __slots__ = ("_rows", "_calibration", "_workloads")

    def __init__(self, rows: List[Row], workloads: Sequence[Workload],
                 calibration: Optional[object] = None):
        self._rows = rows
        self._workloads = workloads
        self._calibration = calibration

    def __len__(self) -> int:
        return len(self._rows)

    def _materialize(self, i: int) -> TimeBreakdown:
        tb = tb_from_row(self._rows[i])
        if self._calibration is not None:
            tb = self._calibration.apply(self._workloads[i], tb)
        return tb

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._materialize(j) for j in range(len(self))[i]]
        return self._materialize(range(len(self))[i])

    def __iter__(self) -> Iterator[TimeBreakdown]:
        return (self._materialize(i) for i in range(len(self)))

    @property
    def totals(self) -> np.ndarray:
        """Total seconds per workload (calibration applied if present)."""
        t = np.fromiter((r[0][0] for r in self._rows), np.float64,
                        len(self._rows))
        if self._calibration is not None:
            m = np.fromiter(
                (self._calibration.multiplier(w) for w in self._workloads),
                np.float64, len(self._rows))
            t = t * m
        return t

    def argmin(self) -> int:
        """Index of the cheapest configuration (the paper's argmin)."""
        return int(np.argmin(self.totals))


class TableResult(Sequence):
    """Lazy sequence view over a columnar table prediction.

    ``totals`` (and ``field_totals``) are whole-column NumPy reads with
    calibration folded in; indexing materializes a single row's
    ``TimeBreakdown`` — the only per-row Python in the table path.
    """

    __slots__ = ("_cols", "_table", "_calibration", "_mult", "_totals")

    def __init__(self, cols, table: WorkloadTable,
                 calibration: Optional[object] = None):
        self._cols = cols
        self._table = table
        self._calibration = calibration
        self._mult = None
        self._totals = None

    def __len__(self) -> int:
        return self._cols.n

    def _multipliers(self) -> Optional[np.ndarray]:
        """Per-row calibration multipliers replicating
        ``Calibration.multiplier`` (exact name, then class, then global)."""
        cal = self._calibration
        if cal is None:
            return None
        m = self._mult
        if m is None:
            t = self._table
            if cal.per_class:
                m = t.per_wclass(
                    lambda c: cal.per_class.get(c, cal.global_scale))
            else:
                m = np.full(len(t), cal.global_scale)
            if cal.per_case:
                m = m.copy()
                for i in range(len(t)):
                    v = cal.per_case.get(t.name(i))
                    if v is not None:
                        m[i] = v
            self._mult = m
        return m

    @property
    def totals(self) -> np.ndarray:
        t = self._totals
        if t is None:
            t = self._cols.totals()
            m = self._multipliers()
            if m is not None:
                t = t * m
            self._totals = t
        return t

    def field_totals(self, field: str) -> np.ndarray:
        """One TimeBreakdown field as a column (calibration applied) —
        the pareto-front input."""
        t = self._cols.field_col(TB_FIELDS.index(field))
        m = self._multipliers()
        return t if m is None else t * m

    def _materialize(self, i: int) -> TimeBreakdown:
        tb = tb_from_row(self._cols.row(i))
        m = self._multipliers()
        if m is not None:
            scale = float(m[i])
            tb = tb.scaled(scale)
            tb.detail["m_case"] = scale
        return tb

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._materialize(j) for j in range(len(self))[i]]
        return self._materialize(range(len(self))[i])

    def __iter__(self) -> Iterator[TimeBreakdown]:
        return (self._materialize(i) for i in range(len(self)))

    def argmin(self) -> int:
        return int(np.argmin(self.totals))


class SweepEngine:
    """Batched, memoizing front end over the analytical model backends."""

    def __init__(self, *, use_cache: bool = True,
                 max_entries: int = 200_000,
                 max_batch_entries: int = 32,
                 max_table_entries: int = 32):
        self.use_cache = use_cache
        self.max_entries = max_entries
        self.max_batch_entries = max_batch_entries
        self.max_table_entries = max_table_entries
        self._cache: "OrderedDict[Tuple, Row]" = OrderedDict()
        self._batch_cache: "OrderedDict[Tuple, List[Row]]" = OrderedDict()
        self._table_cache: "OrderedDict[Tuple, object]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------- queries
    def predict_batch(self, workloads: Sequence[Workload],
                      hw: HardwareParams, *,
                      model: Optional[str] = None,
                      calibration: Optional[object] = None) -> BatchResult:
        """Predict every workload on ``hw``; order-preserving.

        ``model`` overrides routing exactly as in ``predict.predict``;
        ``calibration`` (core.calibrate.Calibration) is applied per result
        on materialization.  Returns a lazy ``BatchResult`` sequence whose
        items equal the scalar ``predict`` outputs bit-for-bit.
        """
        route = model or default_route(hw)
        _rows_fn(route)                       # raises on unknown route
        n = len(workloads)

        if not self.use_cache:
            self.misses += n
            return BatchResult(_eval_rows(route, workloads, hw),
                               workloads, calibration)

        hwk = hardware_key(hw)

        # tier 1: whole-batch digest — an identical replayed sweep returns
        # its cached rows without touching the per-row cache at all.  The
        # key is a fixed-size blake2b digest (plus the tiny string tuples),
        # not the concatenated buffers, so cached batches don't pin a raw
        # copy of every workload vector.
        bkey = None
        if n >= SCALAR_CUTOFF:
            h = hashlib.blake2b(b"".join([w._nvec for w in workloads]),
                                digest_size=16)
            bkey = (hwk, route, h.digest(), n,
                    tuple(w.precision for w in workloads),
                    tuple(w.wclass for w in workloads),
                    tuple(tuple(sorted(w.hit_rates.items()))
                          if w.hit_rates else () for w in workloads)
                    if any(w.hit_rates for w in workloads) else None)
            with self._lock:
                hit = self._batch_cache.get(bkey)
                if hit is not None:
                    self._batch_cache.move_to_end(bkey)
                    self.hits += n
                    return BatchResult(hit, workloads, calibration)

        # tier 2: per-row content keys (LRU)
        rows: List[Optional[Row]] = [None] * n
        miss_idx: List[int] = []
        keys: List[Tuple] = [None] * n  # type: ignore[list-item]
        cache_get = self._cache.get
        move_to_end = self._cache.move_to_end
        with self._lock:
            for i, w in enumerate(workloads):
                k = (hwk, route, workload_key(w))
                keys[i] = k
                row = cache_get(k)
                if row is not None:
                    move_to_end(k)
                    rows[i] = row
                else:
                    miss_idx.append(i)
            self.hits += n - len(miss_idx)
            self.misses += len(miss_idx)

        if miss_idx:
            if len(miss_idx) == n:
                fresh = _eval_rows(route, workloads, hw)
                rows = fresh
            else:
                fresh = _eval_rows(
                    route, [workloads[i] for i in miss_idx], hw)
                for i, row in zip(miss_idx, fresh):
                    rows[i] = row
            with self._lock:
                for i, row in zip(miss_idx, fresh):
                    self._cache[keys[i]] = row
                while len(self._cache) > self.max_entries:
                    self._cache.popitem(last=False)

        if bkey is not None:
            with self._lock:
                self._batch_cache[bkey] = rows
                while len(self._batch_cache) > self.max_batch_entries:
                    self._batch_cache.popitem(last=False)

        return BatchResult(rows, workloads, calibration)  # type: ignore

    def predict_table(self, table: WorkloadTable, hw: HardwareParams, *,
                      model: Optional[str] = None,
                      calibration: Optional[object] = None) -> TableResult:
        """Columnar prediction over a WorkloadTable.

        Runs the route's table core directly on the column arrays; the
        result is memoized whole under the table's content token, so
        replaying a sweep is one token hash + dict hit (strictly faster
        than recomputing — benchmarks/sweep_bench.py asserts it).
        """
        route = model or default_route(hw)
        cols_fn = _cols_fn(route)
        n = len(table)

        if not self.use_cache:
            self.misses += n
            return TableResult(cols_fn(table, hw), table, calibration)

        key = (hardware_key(hw), route, table.content_token())
        with self._lock:
            hit = self._table_cache.get(key)
            if hit is not None:
                self._table_cache.move_to_end(key)
                self.hits += n
                return TableResult(hit, table, calibration)
        cols = cols_fn(table, hw)
        with self._lock:
            self.misses += n
            self._table_cache[key] = cols
            while len(self._table_cache) > self.max_table_entries:
                self._table_cache.popitem(last=False)
        return TableResult(cols, table, calibration)

    def predict(self, w: Workload, hw: HardwareParams, *,
                model: Optional[str] = None,
                calibration: Optional[object] = None) -> TimeBreakdown:
        """Scalar entry point: a batch of one."""
        return self.predict_batch(
            [w], hw, model=model, calibration=calibration)[0]

    # --------------------------------------------------------------- admin
    def cache_stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._cache),
                "batch_entries": len(self._batch_cache),
                "table_entries": len(self._table_cache)}

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()
            self._batch_cache.clear()
            self._table_cache.clear()
            self.hits = self.misses = 0


_DEFAULT: Optional[SweepEngine] = None
_DEFAULT_LOCK = threading.Lock()


def default_engine() -> SweepEngine:
    """Process-wide shared engine (what ``predict.predict`` delegates to)."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = SweepEngine()
    return _DEFAULT


# ---------------------------------------------------------------------------
# Table-native entry points + fused reductions (paper's argmin, columnar).
# ---------------------------------------------------------------------------

def predict_table(table: WorkloadTable, hw: HardwareParams, *,
                  model: Optional[str] = None,
                  calibration: Optional[object] = None,
                  engine: Optional[SweepEngine] = None) -> TableResult:
    """Module-level columnar prediction through the shared engine."""
    return (engine or default_engine()).predict_table(
        table, hw, model=model, calibration=calibration)


@dataclass(frozen=True)
class SweepWinner:
    """One selected configuration from a fused table reduction."""

    index: int
    name: str
    total: float
    breakdown: TimeBreakdown


def _winner(res: TableResult, table: WorkloadTable, i: int) -> SweepWinner:
    return SweepWinner(index=i, name=table.name(i),
                       total=float(res.totals[i]), breakdown=res[i])


def argmin_table(table: WorkloadTable, hw: HardwareParams, *,
                 model: Optional[str] = None,
                 calibration: Optional[object] = None,
                 engine: Optional[SweepEngine] = None) -> SweepWinner:
    """Fused argmin: reduce on the totals column, materialize one row.

    Ties resolve to the lowest row index (matching a stable sort of the
    full materialization)."""
    res = predict_table(table, hw, model=model, calibration=calibration,
                        engine=engine)
    return _winner(res, table, int(np.argmin(res.totals)))


def topk_table(table: WorkloadTable, hw: HardwareParams, k: int, *,
               model: Optional[str] = None,
               calibration: Optional[object] = None,
               engine: Optional[SweepEngine] = None) -> List[SweepWinner]:
    """Fused top-k cheapest configurations, ascending; ties break by row
    index (stable argsort — bit-identical ordering to sorting a full
    materialization by (total, index))."""
    res = predict_table(table, hw, model=model, calibration=calibration,
                        engine=engine)
    order = np.argsort(res.totals, kind="stable")[:max(k, 0)]
    return [_winner(res, table, int(i)) for i in order]


def pareto_table(table: WorkloadTable, hw: HardwareParams, *,
                 objectives: Sequence[str] = ("compute", "memory"),
                 model: Optional[str] = None,
                 calibration: Optional[object] = None,
                 engine: Optional[SweepEngine] = None) -> List[SweepWinner]:
    """Non-dominated (all objectives minimized) configurations.

    ``objectives`` are TimeBreakdown field names (``total``, ``compute``,
    ``memory``, ...).  A row is dominated if some other row is <= on every
    objective and < on at least one.  Duplicate points are all kept.
    Returns winners ordered by (first objective, index).  Reduction runs on
    the column arrays (chunked O(n^2/chunk) dominance test); only the
    front's rows materialize.
    """
    if not objectives:
        raise ValueError("pareto_table needs at least one objective")
    res = predict_table(table, hw, model=model, calibration=calibration,
                        engine=engine)
    pts = np.stack([res.field_totals(f) for f in objectives], axis=1)
    n = pts.shape[0]
    keep = np.ones(n, dtype=bool)
    chunk = max(1, 262_144 // max(n, 1))
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        block = pts[lo:hi]                       # (c, d)
        le = (pts[None, :, :] <= block[:, None, :]).all(-1)   # (c, n)
        lt = (pts[None, :, :] < block[:, None, :]).any(-1)
        dominated = (le & lt).any(1)
        keep[lo:hi] &= ~dominated
    front = np.flatnonzero(keep)
    order = front[np.argsort(pts[front, 0], kind="stable")]
    return [_winner(res, table, int(i)) for i in order]

"""Batched + columnar sweep-prediction engine with two-tier memoization.

The paper's headline workflow prices thousands of candidate
(workload x hardware x precision x tile) configurations through the
analytical models and returns the argmin (§IV-B adaptive tile selection,
§IV-D routing).  A scalar Python ``predict()`` call per configuration makes
that the slowest path in the repo; microbenchmark sweeps span 10^3-10^4
points per kernel family — exactly the regime where batching pays off.

Two batched front ends share the NumPy-vectorized model backends
(``blackwell``/``cdna3``/``tpu``/``generic``/``roofline``):

``SweepEngine.predict_batch(workloads, hw)``
    List-of-``Workload`` batches.  Backends emit compact immutable row
    tuples; ``TimeBreakdown`` objects materialize lazily when a result is
    indexed.  Rows are memoized per row under a content key (the workload's
    packed ``_nvec`` buffer + non-numeric fields + HardwareParams content +
    route) in a bounded LRU, and whole batches short-circuit through a
    batch-digest tier so replaying an identical sweep never walks the
    per-row cache.

``predict_table(table, hw)`` / ``SweepEngine.predict_table``
    Columnar ``WorkloadTable`` sweeps.  The backends run directly on the
    table's column arrays and return columns; nothing per-row is built
    until a winner is materialized.  Fused reductions ``argmin_table``,
    ``topk_table`` and ``pareto_table`` reduce on the column arrays and
    materialize only the winning rows' ``TimeBreakdown``s.  Whole tables
    memoize under a per-table content token (tier 1); there is no per-row
    tier for tables — a table is the unit of reuse.

Columnar-table contract (when to use what):

  * scalar ``predict.predict(w, hw)`` — one-off questions, host phases,
    anything that wants a single ``TimeBreakdown`` now.  Delegates here as
    a batch of one and is memoized per row.
  * ``predict_batch`` — you already hold ``Workload`` objects (validation
    suites, calibration fits that need per-case TimeBreakdowns).
  * ``WorkloadTable`` + ``predict_table``/``argmin_table``/``topk_table``
    — sweeps you *construct*: tile lattices (``WorkloadTable.tile_lattice``),
    cartesian what-if grids (``WorkloadTable.cartesian``).  Never builds
    per-config Workload dataclasses, never builds per-config rows; ~an
    order of magnitude faster end to end than predict_batch over a
    freshly-built Workload list (benchmarks/sweep_bench.py).

Guarantees:
  * batch-of-1 results are bit-identical to the pre-refactor scalar
    ``predict(w, hw)`` for every route (verified by tests/test_sweep.py),
    and table results are bit-identical per row to predict_batch
    (tests/test_workload_table.py),
  * cached rows are immutable tuples — no defensive copies, no
    cache-poisoning via caller-mutated detail dicts,
  * calibration is applied at materialization time, after the cache, so
    one cache entry serves calibrated and uncalibrated callers,
  * all caches are LRU-bounded (``max_entries`` rows, ``max_batch_entries``
    batch digests, ``max_table_entries`` table results) and lock-protected;
    concurrent ``predict_batch`` calls from many threads return identical
    results with the bounds maintained.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import math
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import blackwell, cdna3, generic, roofline
from ..obs import metrics
from .hardware import HardwareParams
from .workload import DEFAULT_CHUNK_ROWS, LatticeSpec, Row, TB_FIELDS, \
    TimeBreakdown, Workload, WorkloadTable, row_from_tb, tb_from_row

ROUTES = ("stage", "wavefront", "tpu", "generic", "roofline")

#: below this many cache misses the engine evaluates via the scalar model
#: functions — NumPy dispatch overhead on near-empty arrays costs more than
#: the per-call Python it saves (crossover measured ~10-15 workloads).
SCALAR_CUTOFF = 16

_FAMILY_ROUTE = {
    "blackwell": "stage",
    "cdna": "wavefront",
    "tpu": "tpu",
    "generic": "generic",
}


def default_route(hw: HardwareParams) -> str:
    """Architecture routing (paper §IV-D workflow step 2/3)."""
    return _FAMILY_ROUTE.get(hw.model_family, "generic")


def _rows_fn(route: str):
    if route == "stage":
        return blackwell.predict_rows
    if route == "wavefront":
        return cdna3.predict_rows
    if route == "tpu":
        from . import tpu  # local import: tpu.py depends on collectives
        return tpu.predict_rows
    if route == "generic":
        return generic.predict_rows
    if route == "roofline":
        return roofline.predict_rows
    raise ValueError(f"unknown model route {route!r}")


def _cols_fn(route: str):
    if route == "stage":
        return blackwell.predict_table_cols
    if route == "wavefront":
        return cdna3.predict_table_cols
    if route == "tpu":
        from . import tpu
        return tpu.predict_table_cols
    if route == "generic":
        return generic.predict_table_cols
    if route == "roofline":
        return roofline.predict_table_cols
    raise ValueError(f"unknown model route {route!r}")


def _scalar_fn(route: str):
    if route == "stage":
        return blackwell.predict
    if route == "wavefront":
        return cdna3.predict
    if route == "tpu":
        from . import tpu
        return tpu.predict
    if route == "generic":
        return generic.predict
    if route == "roofline":
        return roofline.predict
    raise ValueError(f"unknown model route {route!r}")


def _eval_rows(route: str, ws: Sequence[Workload],
               hw: HardwareParams) -> List[Row]:
    """Vectorized for real batches, scalar-reference for tiny ones
    (identical results either way — that equivalence is the engine's core
    invariant, enforced by tests/test_sweep.py)."""
    if len(ws) < SCALAR_CUTOFF:
        fn = _scalar_fn(route)
        return [row_from_tb(fn(w, hw)) for w in ws]
    return _rows_fn(route)(ws, hw)


def workload_key(w: Workload) -> Tuple:
    """Content key for a workload: the packed numeric vector (every
    model-visible numeric field, one memoized bytes object) plus the
    non-numeric fields.  ``name`` is excluded — predictions depend only on
    the characterization, so renamed duplicates share cache entries."""
    return (w._nvec, w.wclass, w.precision,
            tuple(sorted(w.hit_rates.items())) if w.hit_rates else ())


_HW_TOKENS: Dict[Tuple, Tuple[str, int]] = {}
_HW_TOKENS_LOCK = threading.Lock()


def hardware_key(hw: HardwareParams) -> Tuple[str, int]:
    """Compact content token for a parameter file.  The registry allows
    re-registering updated parameters under the same name (e.g. a
    re-calibrated ``cpu_host``), so the name alone would serve stale
    predictions.  The full field tuple is interned to a small (name, id)
    token — cache keys must stay cheap to hash, and the content tuple is
    ~50 nested fields — and the token is stashed on the (frozen) instance
    so the content walk happens once per HardwareParams object."""
    cached = getattr(hw, "_sweep_content_token", None)
    if cached is not None:
        return cached
    out = []
    for f in dataclasses.fields(hw):
        v = getattr(hw, f.name)
        if isinstance(v, dict):
            v = tuple(sorted(v.items()))
        out.append(v)
    content = tuple(out)
    with _HW_TOKENS_LOCK:
        token = _HW_TOKENS.get(content)
        if token is None:
            token = (hw.name, len(_HW_TOKENS))
            _HW_TOKENS[content] = token
    try:
        object.__setattr__(hw, "_sweep_content_token", token)
    except Exception:
        pass
    return token


class BatchResult(Sequence):
    """Lazy sequence view over prediction rows.

    Indexing / iterating materializes ``TimeBreakdown`` objects (with
    calibration applied, when given); ``totals`` exposes the raw totals as
    a NumPy array without materializing anything — the argmin fast path.
    """

    __slots__ = ("_rows", "_calibration", "_workloads")

    def __init__(self, rows: List[Row], workloads: Sequence[Workload],
                 calibration: Optional[object] = None):
        self._rows = rows
        self._workloads = workloads
        self._calibration = calibration

    def __len__(self) -> int:
        return len(self._rows)

    def _materialize(self, i: int) -> TimeBreakdown:
        tb = tb_from_row(self._rows[i])
        if self._calibration is not None:
            tb = self._calibration.apply(self._workloads[i], tb)
        return tb

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._materialize(j) for j in range(len(self))[i]]
        return self._materialize(range(len(self))[i])

    def __iter__(self) -> Iterator[TimeBreakdown]:
        return (self._materialize(i) for i in range(len(self)))

    @property
    def totals(self) -> np.ndarray:
        """Total seconds per workload (calibration applied if present)."""
        t = np.fromiter((r[0][0] for r in self._rows), np.float64,
                        len(self._rows))
        if self._calibration is not None:
            m = np.fromiter(
                (self._calibration.multiplier(w) for w in self._workloads),
                np.float64, len(self._rows))
            t = t * m
        return t

    def argmin(self) -> int:
        """Index of the cheapest configuration (the paper's argmin)."""
        return int(np.argmin(self.totals))


class TableResult(Sequence):
    """Lazy sequence view over a columnar table prediction.

    ``totals`` (and ``field_totals``) are whole-column NumPy reads with
    calibration folded in; indexing materializes a single row's
    ``TimeBreakdown`` — the only per-row Python in the table path.
    """

    __slots__ = ("_cols", "_table", "_calibration", "_mult", "_totals")

    def __init__(self, cols, table: WorkloadTable,
                 calibration: Optional[object] = None):
        self._cols = cols
        self._table = table
        self._calibration = calibration
        self._mult = None
        self._totals = None

    def __len__(self) -> int:
        return self._cols.n

    def _multipliers(self) -> Optional[np.ndarray]:
        """Per-row calibration multipliers replicating
        ``Calibration.multiplier`` (exact name, then class, then global)."""
        cal = self._calibration
        if cal is None:
            return None
        m = self._mult
        if m is None:
            t = self._table
            if cal.per_class:
                m = t.per_wclass(
                    lambda c: cal.per_class.get(c, cal.global_scale))
            else:
                m = np.full(len(t), cal.global_scale)
            if cal.per_case:
                m = m.copy()
                for i in range(len(t)):
                    v = cal.per_case.get(t.name(i))
                    if v is not None:
                        m[i] = v
            self._mult = m
        return m

    @property
    def totals(self) -> np.ndarray:
        t = self._totals
        if t is None:
            t = self._cols.totals()
            m = self._multipliers()
            if m is not None:
                t = t * m
            self._totals = t
        return t

    def field_totals(self, field: str) -> np.ndarray:
        """One TimeBreakdown field as a column (calibration applied) —
        the pareto-front input."""
        t = self._cols.field_col(TB_FIELDS.index(field))
        m = self._multipliers()
        return t if m is None else t * m

    def _materialize(self, i: int) -> TimeBreakdown:
        tb = tb_from_row(self._cols.row(i))
        m = self._multipliers()
        if m is not None:
            scale = float(m[i])
            tb = tb.scaled(scale)
            tb.detail["m_case"] = scale
        return tb

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._materialize(j) for j in range(len(self))[i]]
        return self._materialize(range(len(self))[i])

    def __iter__(self) -> Iterator[TimeBreakdown]:
        return (self._materialize(i) for i in range(len(self)))

    def argmin(self) -> int:
        return int(np.argmin(self.totals))


class SweepEngine:
    """Batched, memoizing front end over the analytical model backends."""

    def __init__(self, *, use_cache: bool = True,
                 max_entries: int = 200_000,
                 max_batch_entries: int = 32,
                 max_table_entries: int = 32):
        self.use_cache = use_cache
        self.max_entries = max_entries
        self.max_batch_entries = max_batch_entries
        self.max_table_entries = max_table_entries
        self._cache: "OrderedDict[Tuple, Row]" = OrderedDict()
        self._batch_cache: "OrderedDict[Tuple, List[Row]]" = OrderedDict()
        self._table_cache: "OrderedDict[Tuple, object]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self._m_table_s = {c: metrics.histogram(
            "repro_sweep_predict_table_seconds",
            "predict_table latency split by cache outcome", cache=c)
            for c in ("hit", "miss")}
        self._m_rows = {c: metrics.counter(
            "repro_sweep_rows_total",
            "Rows priced through the engine, split by cache outcome",
            cache=c) for c in ("hit", "miss")}

    # ------------------------------------------------------------- queries
    def predict_batch(self, workloads: Sequence[Workload],
                      hw: HardwareParams, *,
                      model: Optional[str] = None,
                      calibration: Optional[object] = None) -> BatchResult:
        """Predict every workload on ``hw``; order-preserving.

        ``model`` overrides routing exactly as in ``predict.predict``;
        ``calibration`` (core.calibrate.Calibration) is applied per result
        on materialization.  Returns a lazy ``BatchResult`` sequence whose
        items equal the scalar ``predict`` outputs bit-for-bit.
        """
        route = model or default_route(hw)
        _rows_fn(route)                       # raises on unknown route
        n = len(workloads)

        if not self.use_cache:
            with self._lock:
                self.misses += n
            self._m_rows["miss"].inc(n)
            return BatchResult(_eval_rows(route, workloads, hw),
                               workloads, calibration)

        hwk = hardware_key(hw)

        # tier 1: whole-batch digest — an identical replayed sweep returns
        # its cached rows without touching the per-row cache at all.  The
        # key is a fixed-size blake2b digest (plus the tiny string tuples),
        # not the concatenated buffers, so cached batches don't pin a raw
        # copy of every workload vector.
        bkey = None
        if n >= SCALAR_CUTOFF:
            h = hashlib.blake2b(b"".join([w._nvec for w in workloads]),
                                digest_size=16)
            bkey = (hwk, route, h.digest(), n,
                    tuple(w.precision for w in workloads),
                    tuple(w.wclass for w in workloads),
                    tuple(tuple(sorted(w.hit_rates.items()))
                          if w.hit_rates else () for w in workloads)
                    if any(w.hit_rates for w in workloads) else None)
            with self._lock:
                hit = self._batch_cache.get(bkey)
                if hit is not None:
                    self._batch_cache.move_to_end(bkey)
                    self.hits += n
                    self._m_rows["hit"].inc(n)
                    return BatchResult(hit, workloads, calibration)

        # tier 2: per-row content keys (LRU)
        rows: List[Optional[Row]] = [None] * n
        miss_idx: List[int] = []
        keys: List[Tuple] = [None] * n  # type: ignore[list-item]
        cache_get = self._cache.get
        move_to_end = self._cache.move_to_end
        with self._lock:
            for i, w in enumerate(workloads):
                k = (hwk, route, workload_key(w))
                keys[i] = k
                row = cache_get(k)
                if row is not None:
                    move_to_end(k)
                    rows[i] = row
                else:
                    miss_idx.append(i)
            self.hits += n - len(miss_idx)
            self.misses += len(miss_idx)
        if n > len(miss_idx):
            self._m_rows["hit"].inc(n - len(miss_idx))
        if miss_idx:
            self._m_rows["miss"].inc(len(miss_idx))

        if miss_idx:
            if len(miss_idx) == n:
                fresh = _eval_rows(route, workloads, hw)
                rows = fresh
            else:
                fresh = _eval_rows(
                    route, [workloads[i] for i in miss_idx], hw)
                for i, row in zip(miss_idx, fresh):
                    rows[i] = row
            with self._lock:
                for i, row in zip(miss_idx, fresh):
                    self._cache[keys[i]] = row
                while len(self._cache) > self.max_entries:
                    self._cache.popitem(last=False)

        if bkey is not None:
            with self._lock:
                self._batch_cache[bkey] = rows
                while len(self._batch_cache) > self.max_batch_entries:
                    self._batch_cache.popitem(last=False)

        return BatchResult(rows, workloads, calibration)  # type: ignore

    def predict_table(self, table: WorkloadTable, hw: HardwareParams, *,
                      model: Optional[str] = None,
                      calibration: Optional[object] = None,
                      cache: Optional[bool] = None) -> TableResult:
        """Columnar prediction over a WorkloadTable.

        Runs the route's table core directly on the column arrays; the
        result is memoized whole under the table's content token, so
        replaying a sweep is one token hash + dict hit (strictly faster
        than recomputing — benchmarks/sweep_bench.py asserts it).

        ``cache`` overrides ``self.use_cache`` for this call — the
        streaming reductions pass ``cache=False`` so transient lattice
        chunks neither pay the content-token hash nor churn the table LRU.
        """
        route = model or default_route(hw)
        cols_fn = _cols_fn(route)
        n = len(table)
        t0 = time.monotonic()

        if not (self.use_cache if cache is None else cache):
            cols = cols_fn(table, hw)
            with self._lock:
                self.misses += n
            self._m_rows["miss"].inc(n)
            self._m_table_s["miss"].observe(time.monotonic() - t0)
            return TableResult(cols, table, calibration)

        key = (hardware_key(hw), route, table.content_token())
        with self._lock:
            hit = self._table_cache.get(key)
            if hit is not None:
                self._table_cache.move_to_end(key)
                self.hits += n
        if hit is not None:
            self._m_rows["hit"].inc(n)
            self._m_table_s["hit"].observe(time.monotonic() - t0)
            return TableResult(hit, table, calibration)
        cols = cols_fn(table, hw)
        with self._lock:
            self.misses += n
            self._table_cache[key] = cols
            while len(self._table_cache) > self.max_table_entries:
                self._table_cache.popitem(last=False)
        self._m_rows["miss"].inc(n)
        self._m_table_s["miss"].observe(time.monotonic() - t0)
        return TableResult(cols, table, calibration)

    def predict(self, w: Workload, hw: HardwareParams, *,
                model: Optional[str] = None,
                calibration: Optional[object] = None) -> TimeBreakdown:
        """Scalar entry point: a batch of one."""
        return self.predict_batch(
            [w], hw, model=model, calibration=calibration)[0]

    # --------------------------------------------------------------- admin
    def cache_stats(self) -> Dict[str, int]:
        """Consistent snapshot: counters and sizes read under the cache
        lock, so ``hits + misses`` can never tear mid-update."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._cache),
                    "batch_entries": len(self._batch_cache),
                    "table_entries": len(self._table_cache)}

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()
            self._batch_cache.clear()
            self._table_cache.clear()
            self.hits = self.misses = 0


_DEFAULT: Optional[SweepEngine] = None
_DEFAULT_LOCK = threading.Lock()


def default_engine() -> SweepEngine:
    """Process-wide shared engine (what ``predict.predict`` delegates to)."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = SweepEngine()
    return _DEFAULT


def _reinit_after_fork_in_child() -> None:
    """Fork safety for the module-level engine (``core.parallel`` workers).

    A forked child inherits the parent's engine through copy-on-write; its
    locks may be held by parent threads that do not exist in the child, and
    any entries it appends would silently diverge from the parent's LRU
    accounting.  Re-key every module lock and start the child's caches
    empty — workers must never rely on (or appear to mutate) parent cache
    state."""
    global _DEFAULT_LOCK, _HW_TOKENS_LOCK
    _DEFAULT_LOCK = threading.Lock()
    _HW_TOKENS_LOCK = threading.Lock()
    eng = _DEFAULT
    if eng is not None:
        eng._lock = threading.Lock()
        eng._cache.clear()
        eng._batch_cache.clear()
        eng._table_cache.clear()
        eng.hits = eng.misses = 0


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reinit_after_fork_in_child)


# ---------------------------------------------------------------------------
# Table-native entry points + fused reductions (paper's argmin, columnar).
# ---------------------------------------------------------------------------

def predict_table(table: WorkloadTable, hw: HardwareParams, *,
                  model: Optional[str] = None,
                  calibration: Optional[object] = None,
                  engine: Optional[SweepEngine] = None) -> TableResult:
    """Module-level columnar prediction through the shared engine."""
    return (engine or default_engine()).predict_table(
        table, hw, model=model, calibration=calibration)


@dataclass(frozen=True)
class SweepWinner:
    """One selected configuration from a fused table reduction."""

    index: int
    name: str
    total: float
    breakdown: TimeBreakdown


def _winner(res: TableResult, table: WorkloadTable, i: int) -> SweepWinner:
    return SweepWinner(index=i, name=table.name(i),
                       total=float(res.totals[i]), breakdown=res[i])


# The *_from_result reductions operate on an already-priced TableResult
# window [lo, hi): this is what lets one fused columnar evaluation answer
# many independent requests (the serving front end coalesces concurrent
# small tables into one ``predict_table`` call and reduces each request's
# row window separately).  ``table`` names the window's rows locally (row
# ``lo`` of ``res`` is row 0 of ``table``); winner indices are local to
# the window, so the answers are bit-identical to evaluating each window's
# table on its own — the model backends are row-elementwise.

def argmin_from_result(res: TableResult, table: WorkloadTable,
                       lo: int = 0, hi: Optional[int] = None) -> SweepWinner:
    """Fused argmin over a priced window: reduce on the totals column,
    materialize one row.  Ties resolve to the lowest row index (matching a
    stable sort of the full materialization)."""
    t = res.totals[lo:hi]
    if not len(t):
        raise ValueError("argmin of an empty sweep")
    i = int(np.argmin(t))
    return SweepWinner(index=i, name=table.name(i), total=float(t[i]),
                       breakdown=res[lo + i])


def topk_from_result(res: TableResult, table: WorkloadTable, k: int,
                     lo: int = 0, hi: Optional[int] = None
                     ) -> List[SweepWinner]:
    """Top-k cheapest rows of a priced window, ascending; ties break by
    row index (stable argsort)."""
    t = res.totals[lo:hi]
    order = np.argsort(t, kind="stable")[:max(k, 0)]
    return [SweepWinner(index=int(i), name=table.name(int(i)),
                        total=float(t[i]), breakdown=res[lo + int(i)])
            for i in order]


def pareto_from_result(res: TableResult, table: WorkloadTable,
                       objectives: Sequence[str] = ("compute", "memory"),
                       lo: int = 0, hi: Optional[int] = None
                       ) -> List[SweepWinner]:
    """Non-dominated rows of a priced window, ordered by (first objective,
    index)."""
    if not objectives:
        raise ValueError("pareto needs at least one objective")
    pts = np.stack([res.field_totals(f)[lo:hi] for f in objectives],
                   axis=1)
    t = res.totals[lo:hi]
    front = np.flatnonzero(_pareto_front_mask(pts))
    order = front[np.argsort(pts[front, 0], kind="stable")]
    return [SweepWinner(index=int(i), name=table.name(int(i)),
                        total=float(t[i]), breakdown=res[lo + int(i)])
            for i in order]


def argmin_table(table: WorkloadTable, hw: HardwareParams, *,
                 model: Optional[str] = None,
                 calibration: Optional[object] = None,
                 engine: Optional[SweepEngine] = None) -> SweepWinner:
    """Fused argmin: reduce on the totals column, materialize one row.

    Ties resolve to the lowest row index (matching a stable sort of the
    full materialization)."""
    res = predict_table(table, hw, model=model, calibration=calibration,
                        engine=engine)
    return argmin_from_result(res, table)


def topk_table(table: WorkloadTable, hw: HardwareParams, k: int, *,
               model: Optional[str] = None,
               calibration: Optional[object] = None,
               engine: Optional[SweepEngine] = None) -> List[SweepWinner]:
    """Fused top-k cheapest configurations, ascending; ties break by row
    index (stable argsort — bit-identical ordering to sorting a full
    materialization by (total, index))."""
    res = predict_table(table, hw, model=model, calibration=calibration,
                        engine=engine)
    return topk_from_result(res, table, k)


def pareto_table(table: WorkloadTable, hw: HardwareParams, *,
                 objectives: Sequence[str] = ("compute", "memory"),
                 model: Optional[str] = None,
                 calibration: Optional[object] = None,
                 engine: Optional[SweepEngine] = None) -> List[SweepWinner]:
    """Non-dominated (all objectives minimized) configurations.

    ``objectives`` are TimeBreakdown field names (``total``, ``compute``,
    ``memory``, ...).  A row is dominated if some other row is <= on every
    objective and < on at least one.  Duplicate points are all kept.
    Returns winners ordered by (first objective, index).  Reduction runs on
    the column arrays (chunked O(n^2/chunk) dominance test); only the
    front's rows materialize.
    """
    if not objectives:
        raise ValueError("pareto_table needs at least one objective")
    res = predict_table(table, hw, model=model, calibration=calibration,
                        engine=engine)
    return pareto_from_result(res, table, objectives)


def _dominated_mask(points: np.ndarray, against: np.ndarray) -> np.ndarray:
    """Row mask: points strictly dominated (<= everywhere, < somewhere) by
    some row of ``against``.  Blocked O(|points|*|against|/block) so the
    broadcast temporaries stay bounded."""
    n = points.shape[0]
    out = np.zeros(n, dtype=bool)
    if not len(against):
        return out
    block_rows = max(1, 262_144 // max(len(against), 1))
    for lo in range(0, n, block_rows):
        block = points[lo:lo + block_rows]            # (c, d)
        le = (against[None, :, :] <= block[:, None, :]).all(-1)   # (c, m)
        lt = (against[None, :, :] < block[:, None, :]).any(-1)
        out[lo:lo + block_rows] = (le & lt).any(1)
    return out


def _pareto_front_mask(pts: np.ndarray) -> np.ndarray:
    """True for non-dominated rows (duplicates all kept — equal points
    never strictly dominate each other)."""
    return ~_dominated_mask(pts, pts)


# ---------------------------------------------------------------------------
# Streaming fused reductions (O(chunk) peak memory, bit-identical winners).
#
# ``reduce_stream`` walks a LatticeSpec (or an already-built table) chunk by
# chunk, prices each chunk through the columnar path with the table cache
# bypassed, and folds the chunk's columns into constant-size reducer state:
# argmin keeps one winner, top-k a bounded heap, pareto an incremental
# frontier.  Winners (index, total, tie-order, name, breakdown) are
# bit-identical to the materialized argmin_table/topk_table/pareto_table —
# chunk columns are byte-identical windows of the full table and every
# comparison uses the same floats in the same order.
#
# Reducers are picklable and mergeable: ``core.parallel`` ships fresh ones
# to worker processes (each worker streams its own shard through its own
# SweepEngine) and merges the partials in shard order.
# ---------------------------------------------------------------------------

def as_spec(source) -> LatticeSpec:
    """Coerce a sweep source (LatticeSpec | WorkloadTable) to a spec."""
    if isinstance(source, LatticeSpec):
        return source
    if isinstance(source, WorkloadTable):
        return LatticeSpec.from_table(source)
    raise TypeError(f"expected LatticeSpec or WorkloadTable, "
                    f"got {type(source).__name__}")


def effective_jobs(jobs) -> int:
    """Worker-count policy: ``None``/1 -> in-process serial; 0 or "auto" ->
    ``os.cpu_count()``; N -> N."""
    if jobs is None:
        return 1
    if jobs == 0 or jobs == "auto":
        return max(1, os.cpu_count() or 1)
    return max(1, int(jobs))


class ArgminStream:
    """O(1)-state streaming argmin; cross-chunk ties keep the earlier
    global row (strict <), matching ``np.argmin`` over the full column —
    including NaN semantics (``np.argmin`` returns the first NaN position
    when any total is NaN, so an incoming NaN beats any finite best and an
    established NaN best is never displaced)."""

    def __init__(self):
        self.best_total = math.inf
        self.best_index = -1
        self.best_name = None
        self.best_tb = None

    def _beats(self, total: float) -> bool:
        if self.best_index < 0:
            return True
        if math.isnan(self.best_total):
            return False                     # earliest NaN already won
        return math.isnan(total) or total < self.best_total

    def update(self, offset: int, table: WorkloadTable,
               res: TableResult) -> None:
        t = res.totals
        i = int(np.argmin(t))                # first NaN if the chunk has one
        if self._beats(float(t[i])):
            self.best_total = float(t[i])
            self.best_index = offset + i
            self.best_name = table.name(i)
            self.best_tb = res[i]

    def merge(self, other: "ArgminStream") -> None:
        """``other`` covers a LATER shard (merge runs in shard order)."""
        if other.best_index >= 0 and self._beats(other.best_total):
            self.best_total = other.best_total
            self.best_index = other.best_index
            self.best_name = other.best_name
            self.best_tb = other.best_tb

    def result(self) -> SweepWinner:
        if self.best_index < 0:
            raise ValueError("argmin of an empty sweep")
        return SweepWinner(index=self.best_index, name=self.best_name,
                           total=self.best_total, breakdown=self.best_tb)


class TopkStream:
    """Bounded max-heap of the k cheapest rows ordered by (total, index) —
    the same lexicographic order a stable argsort of the full totals column
    yields, so the final list is bit-identical to ``topk_table``.  NaN
    totals sort after every finite total in original index order (stable
    argsort semantics): they are kept in a side list and only surface when
    the whole sweep has fewer than k finite rows."""

    def __init__(self, k: int):
        self.k = int(k)
        self._heap: List[Tuple] = []   # (-total, -gidx, name, breakdown)
        self._nans: List[Tuple] = []   # (gidx, name, total, breakdown)

    def update(self, offset: int, table: WorkloadTable,
               res: TableResult) -> None:
        k = self.k
        if k <= 0:
            return
        t = res.totals
        heap = self._heap
        if len(heap) == k and float(t.min()) >= -heap[0][0]:
            # chunks stream in ascending index order, so an incoming row
            # that merely equals the current worst loses the tie (a NaN
            # t.min() compares False and falls through to the full scan)
            return
        kk = min(k, len(t))
        thresh = np.partition(t, kk - 1)[kk - 1]
        if math.isnan(thresh):
            # fewer than kk finite totals in this chunk: every finite row
            # is a candidate, NaN rows go to the side list below
            cand = np.flatnonzero(~np.isnan(t))
        else:
            cand = np.flatnonzero(t <= thresh)   # NaN compares False
        cand = cand[np.argsort(t[cand], kind="stable")]
        for li in cand.tolist():
            total = float(t[li])
            gidx = offset + li
            if len(heap) < k:
                heapq.heappush(heap, (-total, -gidx, table.name(li),
                                      res[li]))
            elif (-total, -gidx) > heap[0][:2]:
                heapq.heapreplace(heap, (-total, -gidx, table.name(li),
                                         res[li]))
            else:
                break   # candidates are ascending: the rest lose too
        if len(heap) < k and len(self._nans) < k:
            # NaNs can only surface when the whole sweep has < k finite
            # rows, i.e. when the heap never fills — so a full heap makes
            # this scan (and all future ones) unnecessary
            for li in np.flatnonzero(np.isnan(t)).tolist():
                if len(self._nans) >= k:
                    break
                self._nans.append((offset + li, table.name(li),
                                   float(t[li]), res[li]))

    def merge(self, other: "TopkStream") -> None:
        entries = sorted(self._heap + other._heap,
                         key=lambda e: (-e[0], -e[1]))[:self.k]
        self._heap = entries
        heapq.heapify(self._heap)
        self._nans = sorted(self._nans + other._nans,
                            key=lambda e: e[0])[:self.k]

    def result(self) -> List[SweepWinner]:
        entries = sorted(self._heap, key=lambda e: (-e[0], -e[1]))
        out = [SweepWinner(index=-e[1], name=e[2], total=-e[0],
                           breakdown=e[3]) for e in entries]
        for gidx, name, total, tb in self._nans[:self.k - len(out)]:
            out.append(SweepWinner(index=gidx, name=name, total=total,
                                   breakdown=tb))
        return out


class ParetoStream:
    """Incremental pareto frontier: each chunk's non-dominated rows are
    cross-filtered against the running frontier both ways.  Dominance is
    transitive, so pruning dominated points early never changes the final
    front; ordering is restored at ``result()``."""

    def __init__(self, objectives: Sequence[str] = ("compute", "memory")):
        if not objectives:
            raise ValueError("pareto needs at least one objective")
        self.objectives = tuple(objectives)
        self.pts = np.empty((0, len(self.objectives)))
        self.entries: List[Tuple] = []   # (gidx, name, total, breakdown)

    def update(self, offset: int, table: WorkloadTable,
               res: TableResult) -> None:
        pts = np.stack([res.field_totals(f) for f in self.objectives],
                       axis=1)
        keep = _pareto_front_mask(pts)
        if self.entries:
            kidx = np.flatnonzero(keep)
            if len(kidx):
                keep[kidx[_dominated_mask(pts[kidx], self.pts)]] = False
        cand = np.flatnonzero(keep)
        if not len(cand):
            return
        cand_pts = pts[cand]
        if self.entries:
            dead = _dominated_mask(self.pts, cand_pts)
            if dead.any():
                alive = ~dead
                self.pts = self.pts[alive]
                self.entries = [e for e, a in zip(self.entries, alive) if a]
        t = res.totals
        for li in cand.tolist():
            self.entries.append((offset + li, table.name(li), float(t[li]),
                                 res[li]))
        self.pts = np.concatenate([self.pts, cand_pts], axis=0)

    def merge(self, other: "ParetoStream") -> None:
        if not other.entries:
            return
        if not self.entries:
            self.pts, self.entries = other.pts, other.entries
            return
        mine_dead = _dominated_mask(self.pts, other.pts)
        theirs_dead = _dominated_mask(other.pts, self.pts)
        self.pts = np.concatenate([self.pts[~mine_dead],
                                   other.pts[~theirs_dead]], axis=0)
        self.entries = \
            [e for e, d in zip(self.entries, mine_dead) if not d] + \
            [e for e, d in zip(other.entries, theirs_dead) if not d]

    def result(self) -> List[SweepWinner]:
        def key(j):
            v = self.pts[j, 0]
            # stable-argsort order: finite ascending, NaN last by index
            if math.isnan(v):
                return (1, 0.0, self.entries[j][0])
            return (0, float(v), self.entries[j][0])

        order = sorted(range(len(self.entries)), key=key)
        return [SweepWinner(index=self.entries[j][0],
                            name=self.entries[j][1],
                            total=self.entries[j][2],
                            breakdown=self.entries[j][3]) for j in order]


class TotalsStream:
    """Collects the (calibrated) totals column chunk by chunk — the
    streaming analogue of ``TableResult.totals`` for consumers that need
    every row's total (validation suites) but not the result columns."""

    def __init__(self):
        self._parts: List[Tuple[int, np.ndarray]] = []

    def update(self, offset: int, table: WorkloadTable,
               res: TableResult) -> None:
        self._parts.append((offset, res.totals))

    def merge(self, other: "TotalsStream") -> None:
        self._parts.extend(other._parts)

    def result(self) -> np.ndarray:
        if not self._parts:
            return np.empty(0)
        return np.concatenate([p for _, p in sorted(self._parts,
                                                    key=lambda x: x[0])])


def reduce_stream(source, hw: HardwareParams, reducers: Sequence, *,
                  chunk_size: Optional[int] = None,
                  model: Optional[str] = None,
                  calibration: Optional[object] = None,
                  engine: Optional[SweepEngine] = None,
                  lo: int = 0, hi: Optional[int] = None,
                  offset_base: int = 0) -> Sequence:
    """Price ``source`` chunk by chunk and fold every chunk into the given
    reducers.  Peak memory is O(chunk): one chunk's columns + its result
    columns are live at a time; nothing is memoized (``cache=False``).

    ``offset_base`` shifts the reducers' global row numbering — sharded
    workers that hold only a window of the full lattice pass the window's
    global start so merged winners keep full-lattice indices."""
    spec = as_spec(source)
    eng = engine or default_engine()
    size = int(chunk_size or DEFAULT_CHUNK_ROWS)
    offset = offset_base + lo
    for chunk in spec.chunks(size, lo=lo, hi=hi):
        res = eng.predict_table(chunk, hw, model=model,
                                calibration=calibration, cache=False)
        for r in reducers:
            r.update(offset, chunk, res)
        offset += len(chunk)
    return reducers


def _run_reducers(source, hw: HardwareParams,
                  factories: Sequence[Callable[[], object]], *,
                  chunk_size: Optional[int], model: Optional[str],
                  calibration: Optional[object],
                  engine: Optional[SweepEngine], jobs,
                  pool=None) -> Sequence:
    njobs = pool.njobs if (pool is not None and jobs is None) \
        else effective_jobs(jobs)
    if njobs > 1:
        from . import parallel
        return parallel.reduce_sharded(
            source, hw, factories, jobs=njobs, chunk_size=chunk_size,
            model=model, calibration=calibration, pool=pool)
    return reduce_stream(source, hw, [f() for f in factories],
                         chunk_size=chunk_size, model=model,
                         calibration=calibration, engine=engine)


def argmin_stream(source, hw: HardwareParams, *,
                  chunk_size: Optional[int] = None,
                  model: Optional[str] = None,
                  calibration: Optional[object] = None,
                  engine: Optional[SweepEngine] = None,
                  jobs=None, pool=None) -> SweepWinner:
    """Streaming argmin over a LatticeSpec or WorkloadTable — bit-identical
    winner to ``argmin_table`` on the materialized lattice, peak memory
    O(chunk).  ``jobs`` > 1 (or 0/"auto" for ``os.cpu_count()``) shards the
    lattice across a worker pool (``core.parallel``)."""
    (red,) = _run_reducers(source, hw, [ArgminStream],
                           chunk_size=chunk_size, model=model,
                           calibration=calibration, engine=engine, jobs=jobs,
                           pool=pool)
    return red.result()


def topk_stream(source, hw: HardwareParams, k: int, *,
                chunk_size: Optional[int] = None,
                model: Optional[str] = None,
                calibration: Optional[object] = None,
                engine: Optional[SweepEngine] = None,
                jobs=None, pool=None) -> List[SweepWinner]:
    """Streaming top-k cheapest (bounded heap) — bit-identical list to
    ``topk_table`` including tie order."""
    (red,) = _run_reducers(source, hw, [partial(TopkStream, k)],
                           chunk_size=chunk_size, model=model,
                           calibration=calibration, engine=engine, jobs=jobs,
                           pool=pool)
    return red.result()


def pareto_stream(source, hw: HardwareParams, *,
                  objectives: Sequence[str] = ("compute", "memory"),
                  chunk_size: Optional[int] = None,
                  model: Optional[str] = None,
                  calibration: Optional[object] = None,
                  engine: Optional[SweepEngine] = None,
                  jobs=None, pool=None) -> List[SweepWinner]:
    """Streaming pareto frontier (incremental) — bit-identical front and
    ordering to ``pareto_table``."""
    (red,) = _run_reducers(source, hw,
                           [partial(ParetoStream, tuple(objectives))],
                           chunk_size=chunk_size, model=model,
                           calibration=calibration, engine=engine, jobs=jobs,
                           pool=pool)
    return red.result()


def predict_totals_stream(source, hw: HardwareParams, *,
                          chunk_size: Optional[int] = None,
                          model: Optional[str] = None,
                          calibration: Optional[object] = None,
                          engine: Optional[SweepEngine] = None,
                          jobs=None, pool=None) -> np.ndarray:
    """Every row's (calibrated) total, streamed — same floats as
    ``predict_table(...).totals`` with intermediates bounded by chunk."""
    (red,) = _run_reducers(source, hw, [TotalsStream],
                           chunk_size=chunk_size, model=model,
                           calibration=calibration, engine=engine, jobs=jobs,
                           pool=pool)
    return red.result()

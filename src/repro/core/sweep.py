"""Batched sweep-prediction engine with keyed memoization.

The paper's headline workflow prices thousands of candidate
(workload x hardware x precision x tile) configurations through the
analytical models and returns the argmin (§IV-B adaptive tile selection,
§IV-D routing).  A scalar Python ``predict()`` call per configuration makes
that the slowest path in the repo; microbenchmark sweeps span 10^3-10^4
points per kernel family — exactly the regime where batching pays off.

``SweepEngine.predict_batch(workloads, hw)`` routes a whole batch to the
NumPy-vectorized model backends (``blackwell.predict_rows``,
``cdna3.predict_rows``, ``tpu.predict_rows``, ``generic.predict_rows``,
``roofline.predict_rows``).  Backends emit compact immutable row tuples
(struct-of-arrays assembled by C-level zips); ``TimeBreakdown`` objects
materialize lazily when a result is indexed, so argmin-style consumers
never pay per-config Python object construction.  Each row is memoized
under a content key (Workload fields + HardwareParams content + route) so
repeated autotune/hillclimb queries are O(1) dictionary hits.

Guarantees:
  * batch-of-1 results are bit-identical to the pre-refactor scalar
    ``predict(w, hw)`` for every route (verified by tests/test_sweep.py),
  * cached rows are immutable tuples — no defensive copies, no
    cache-poisoning via caller-mutated detail dicts,
  * calibration is applied at materialization time, after the cache, so
    one cache entry serves calibrated and uncalibrated callers.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import blackwell, cdna3, generic, roofline
from .hardware import HardwareParams
from .workload import Row, TimeBreakdown, Workload, row_from_tb, tb_from_row

ROUTES = ("stage", "wavefront", "tpu", "generic", "roofline")

#: below this many cache misses the engine evaluates via the scalar model
#: functions — NumPy dispatch overhead on near-empty arrays costs more than
#: the per-call Python it saves (crossover measured ~10-15 workloads).
SCALAR_CUTOFF = 16

_FAMILY_ROUTE = {
    "blackwell": "stage",
    "cdna": "wavefront",
    "tpu": "tpu",
    "generic": "generic",
}


def default_route(hw: HardwareParams) -> str:
    """Architecture routing (paper §IV-D workflow step 2/3)."""
    return _FAMILY_ROUTE.get(hw.model_family, "generic")


def _rows_fn(route: str):
    if route == "stage":
        return blackwell.predict_rows
    if route == "wavefront":
        return cdna3.predict_rows
    if route == "tpu":
        from . import tpu  # local import: tpu.py depends on collectives
        return tpu.predict_rows
    if route == "generic":
        return generic.predict_rows
    if route == "roofline":
        return roofline.predict_rows
    raise ValueError(f"unknown model route {route!r}")


def _scalar_fn(route: str):
    if route == "stage":
        return blackwell.predict
    if route == "wavefront":
        return cdna3.predict
    if route == "tpu":
        from . import tpu
        return tpu.predict
    if route == "generic":
        return generic.predict
    if route == "roofline":
        return roofline.predict
    raise ValueError(f"unknown model route {route!r}")


def _eval_rows(route: str, ws: Sequence[Workload],
               hw: HardwareParams) -> List[Row]:
    """Vectorized for real batches, scalar-reference for tiny ones
    (identical results either way — that equivalence is the engine's core
    invariant, enforced by tests/test_sweep.py)."""
    if len(ws) < SCALAR_CUTOFF:
        fn = _scalar_fn(route)
        return [row_from_tb(fn(w, hw)) for w in ws]
    return _rows_fn(route)(ws, hw)


def workload_key(w: Workload) -> Tuple:
    """Content key for a workload: every model-visible field (``name`` is
    excluded — predictions depend only on the characterization, so renamed
    duplicates share cache entries)."""
    g, t = w.gemm, w.tile
    return (
        w.wclass, w.flops, w.bytes, w.precision, w.matrix,
        w.working_set_bytes,
        (g.m, g.n, g.k) if g is not None else None,
        (t.bm, t.bn, t.bk) if t is not None else None,
        w.num_ctas, w.k_tiles, w.tma_participants, w.bytes_per_cta,
        w.vgpr_per_workitem,
        tuple(sorted(w.hit_rates.items())) if w.hit_rates else (),
        w.num_loads, w.compressed_bytes, w.compression_ratio,
        w.irregular, w.atomics, w.concurrent_kernels, w.num_devices,
    )


_HW_TOKENS: Dict[Tuple, Tuple[str, int]] = {}
_HW_TOKENS_LOCK = threading.Lock()


def hardware_key(hw: HardwareParams) -> Tuple[str, int]:
    """Compact content token for a parameter file.  The registry allows
    re-registering updated parameters under the same name (e.g. a
    re-calibrated ``cpu_host``), so the name alone would serve stale
    predictions.  The full field tuple is interned to a small (name, id)
    token — cache keys must stay cheap to hash, and the content tuple is
    ~50 nested fields — and the token is stashed on the (frozen) instance
    so the content walk happens once per HardwareParams object."""
    cached = getattr(hw, "_sweep_content_token", None)
    if cached is not None:
        return cached
    out = []
    for f in dataclasses.fields(hw):
        v = getattr(hw, f.name)
        if isinstance(v, dict):
            v = tuple(sorted(v.items()))
        out.append(v)
    content = tuple(out)
    with _HW_TOKENS_LOCK:
        token = _HW_TOKENS.get(content)
        if token is None:
            token = (hw.name, len(_HW_TOKENS))
            _HW_TOKENS[content] = token
    try:
        object.__setattr__(hw, "_sweep_content_token", token)
    except Exception:
        pass
    return token


class BatchResult(Sequence):
    """Lazy sequence view over prediction rows.

    Indexing / iterating materializes ``TimeBreakdown`` objects (with
    calibration applied, when given); ``totals`` exposes the raw totals as
    a NumPy array without materializing anything — the argmin fast path.
    """

    __slots__ = ("_rows", "_calibration", "_workloads")

    def __init__(self, rows: List[Row], workloads: Sequence[Workload],
                 calibration: Optional[object] = None):
        self._rows = rows
        self._workloads = workloads
        self._calibration = calibration

    def __len__(self) -> int:
        return len(self._rows)

    def _materialize(self, i: int) -> TimeBreakdown:
        tb = tb_from_row(self._rows[i])
        if self._calibration is not None:
            tb = self._calibration.apply(self._workloads[i], tb)
        return tb

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._materialize(j) for j in range(len(self))[i]]
        return self._materialize(range(len(self))[i])

    def __iter__(self) -> Iterator[TimeBreakdown]:
        return (self._materialize(i) for i in range(len(self)))

    @property
    def totals(self) -> np.ndarray:
        """Total seconds per workload (calibration applied if present)."""
        t = np.fromiter((r[0][0] for r in self._rows), np.float64,
                        len(self._rows))
        if self._calibration is not None:
            m = np.fromiter(
                (self._calibration.multiplier(w) for w in self._workloads),
                np.float64, len(self._rows))
            t = t * m
        return t

    def argmin(self) -> int:
        """Index of the cheapest configuration (the paper's argmin)."""
        return int(np.argmin(self.totals))


class SweepEngine:
    """Batched, memoizing front end over the analytical model backends."""

    def __init__(self, *, use_cache: bool = True,
                 max_entries: int = 200_000):
        self.use_cache = use_cache
        self.max_entries = max_entries
        self._cache: "OrderedDict[Tuple, Row]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------- queries
    def predict_batch(self, workloads: Sequence[Workload],
                      hw: HardwareParams, *,
                      model: Optional[str] = None,
                      calibration: Optional[object] = None) -> BatchResult:
        """Predict every workload on ``hw``; order-preserving.

        ``model`` overrides routing exactly as in ``predict.predict``;
        ``calibration`` (core.calibrate.Calibration) is applied per result
        on materialization.  Returns a lazy ``BatchResult`` sequence whose
        items equal the scalar ``predict`` outputs bit-for-bit.
        """
        route = model or default_route(hw)
        _rows_fn(route)                       # raises on unknown route
        n = len(workloads)

        if not self.use_cache:
            self.misses += n
            return BatchResult(_eval_rows(route, workloads, hw),
                               workloads, calibration)

        hwk = hardware_key(hw)
        rows: List[Optional[Row]] = [None] * n
        miss_idx: List[int] = []
        keys: List[Tuple] = [None] * n  # type: ignore[list-item]
        cache_get = self._cache.get
        with self._lock:
            for i, w in enumerate(workloads):
                k = (hwk, route, workload_key(w))
                keys[i] = k
                row = cache_get(k)
                if row is not None:
                    rows[i] = row
                else:
                    miss_idx.append(i)
            self.hits += n - len(miss_idx)
            self.misses += len(miss_idx)

        if miss_idx:
            if len(miss_idx) == n:
                fresh = _eval_rows(route, workloads, hw)
                rows = fresh
            else:
                fresh = _eval_rows(
                    route, [workloads[i] for i in miss_idx], hw)
                for i, row in zip(miss_idx, fresh):
                    rows[i] = row
            with self._lock:
                for i, row in zip(miss_idx, fresh):
                    self._cache[keys[i]] = row
                while len(self._cache) > self.max_entries:
                    self._cache.popitem(last=False)

        return BatchResult(rows, workloads, calibration)  # type: ignore

    def predict(self, w: Workload, hw: HardwareParams, *,
                model: Optional[str] = None,
                calibration: Optional[object] = None) -> TimeBreakdown:
        """Scalar entry point: a batch of one."""
        return self.predict_batch(
            [w], hw, model=model, calibration=calibration)[0]

    # --------------------------------------------------------------- admin
    def cache_stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._cache)}

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()
            self.hits = self.misses = 0


_DEFAULT: Optional[SweepEngine] = None
_DEFAULT_LOCK = threading.Lock()


def default_engine() -> SweepEngine:
    """Process-wide shared engine (what ``predict.predict`` delegates to)."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = SweepEngine()
    return _DEFAULT

"""Generic calibrated roofline path (paper §IV-F) + host phases (§IV-E).

Used when a segment does not map to a full Blackwell stage model or a
validated GEMM/tile case:
  * separate calibrated scales per class (memory / compute / balanced /
    stencil),
  * optional precision-specific tensor efficiency multipliers,
  * working-set-aware bandwidth B_eff(W) (Eq. 16),
  * multi-kernel segments: extra launch latency beyond the first kernel,
  * host-device transfer T_memcpy = S/B_eff + tau_memcpy (Eq. 15) and
    per-sync-point T_host_sync = tau_sync.

Sustained (microbenchmark) values drive this path; datasheet peaks are kept
for upper-bound comparisons only (paper §V-A).
"""
from __future__ import annotations

from .cache import working_set_blend
from .hardware import HardwareParams
from .workload import HostPhase, Segment, TimeBreakdown, Workload


def predict(w: Workload, hw: HardwareParams, *,
            class_scale: float = 0.0) -> TimeBreakdown:
    """Generic roofline with calibrated class scale + Eq. 16 blend."""
    scale = class_scale or hw.class_scales.get(w.wclass, 1.0)
    bw = working_set_blend(w.working_set_bytes or w.bytes, hw)
    t_mem = w.bytes / bw
    eff = hw.precision_efficiency.get(w.precision, 1.0)
    rate = hw.sustained_flops(w.precision, matrix=w.matrix) * eff
    t_comp = w.flops / rate if w.flops > 0 else 0.0
    if w.irregular:
        t_mem *= 4.0
    body = max(t_comp, t_mem) * scale
    total = hw.launch_latency_s + body
    total += (w.concurrent_kernels - 1) * hw.tau_interference_s
    total += (w.num_devices - 1) * hw.tau_interference_gpu_s
    return TimeBreakdown(total=total, compute=t_comp, memory=t_mem,
                         io_effective=t_mem,
                         launch=hw.launch_latency_s,
                         detail={"bw_eff": bw, "class_scale": scale})


def host_phase_time(phase: HostPhase, hw: HardwareParams) -> float:
    """Eq. 15 / §IV-E. Conservative: no copy/compute overlap modeled."""
    if phase.kind == "sync":
        return phase.count * hw.tau_sync_s
    bw = hw.h2d_bandwidth if phase.kind == "h2d" else hw.d2h_bandwidth
    return phase.count * (phase.bytes / bw + hw.tau_memcpy_s)


def segment_overhead(seg: Segment, hw: HardwareParams) -> float:
    """Host phases + extra kernel launches (multi-kernel segments)."""
    t = sum(host_phase_time(p, hw) for p in seg.host_phases)
    t += seg.extra_kernels * hw.launch_latency_s
    return t

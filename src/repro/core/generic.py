"""Generic calibrated roofline path (paper §IV-F) + host phases (§IV-E).

Used when a segment does not map to a full Blackwell stage model or a
validated GEMM/tile case:
  * separate calibrated scales per class (memory / compute / balanced /
    stencil),
  * optional precision-specific tensor efficiency multipliers,
  * working-set-aware bandwidth B_eff(W) (Eq. 16),
  * multi-kernel segments: extra launch latency beyond the first kernel,
  * host-device transfer T_memcpy = S/B_eff + tau_memcpy (Eq. 15) and
    per-sync-point T_host_sync = tau_sync.

Sustained (microbenchmark) values drive this path; datasheet peaks are kept
for upper-bound comparisons only (paper §V-A).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from .cache import working_set_blend, working_set_blend_batch
from .hardware import HardwareParams
from .workload import HostPhase, Row, Segment, TimeBreakdown, Workload, \
    tb_from_row


def predict(w: Workload, hw: HardwareParams, *,
            class_scale: float = 0.0) -> TimeBreakdown:
    """Generic roofline with calibrated class scale + Eq. 16 blend."""
    scale = class_scale or hw.class_scales.get(w.wclass, 1.0)
    bw = working_set_blend(w.working_set_bytes or w.bytes, hw)
    t_mem = w.bytes / bw
    eff = hw.precision_efficiency.get(w.precision, 1.0)
    rate = hw.sustained_flops(w.precision, matrix=w.matrix) * eff
    t_comp = w.flops / rate if w.flops > 0 else 0.0
    if w.irregular:
        t_mem *= 4.0
    body = max(t_comp, t_mem) * scale
    total = hw.launch_latency_s + body
    total += (w.concurrent_kernels - 1) * hw.tau_interference_s
    total += (w.num_devices - 1) * hw.tau_interference_gpu_s
    return TimeBreakdown(total=total, compute=t_comp, memory=t_mem,
                         io_effective=t_mem,
                         launch=hw.launch_latency_s,
                         detail={"bw_eff": bw, "class_scale": scale})


def predict_table_cols(table, hw: HardwareParams):
    """Columnar ``predict`` over a WorkloadTable (class_scale taken from the
    parameter file, as in the scalar default).  Bit-identical per row to
    scalar ``predict(w, hw)``."""
    from .workload import NV_BYTES, NV_WS_OR_BYTES, NV_FLOPS, \
        NV_IRREGULAR, NV_CONCURRENT, NV_DEVICES, TableCols
    raw = table.cols
    nbytes, wsb, flops = raw[:, NV_BYTES], raw[:, NV_WS_OR_BYTES], \
        raw[:, NV_FLOPS]
    scale = table.per_wclass(lambda c: hw.class_scales.get(c, 1.0))
    bw = working_set_blend_batch(wsb, hw)
    t_mem = nbytes / bw

    rate = table.per_precision_matrix(
        lambda p, m: hw.sustained_flops(p, matrix=m)
        * hw.precision_efficiency.get(p, 1.0))
    with np.errstate(divide="ignore", invalid="ignore"):
        t_comp = np.where(flops > 0, flops / rate, 0.0)
    t_mem = np.where(raw[:, NV_IRREGULAR] != 0, t_mem * 4.0, t_mem)
    body = np.maximum(t_comp, t_mem) * scale
    total = hw.launch_latency_s + body
    total = total + (raw[:, NV_CONCURRENT] - 1) * hw.tau_interference_s
    total = total + (raw[:, NV_DEVICES] - 1) * hw.tau_interference_gpu_s

    return TableCols(
        len(table),
        (total, t_comp, t_mem, t_mem, 0.0, hw.launch_latency_s,
         0.0, 0.0, 0.0),
        ("bw_eff", "class_scale"), (bw, scale))


def predict_rows(ws: Sequence[Workload],
                 hw: HardwareParams) -> List[Row]:
    """Vectorized ``predict`` over a workload batch, in row form.
    Bit-identical to per-workload ``predict(w, hw)`` calls."""
    from .workload import WorkloadTable
    return predict_table_cols(WorkloadTable.from_workloads(ws), hw).rows()


def predict_batch(ws: Sequence[Workload],
                  hw: HardwareParams) -> List[TimeBreakdown]:
    """Materialized form of ``predict_rows``."""
    return [tb_from_row(r) for r in predict_rows(ws, hw)]


def host_phase_time(phase: HostPhase, hw: HardwareParams) -> float:
    """Eq. 15 / §IV-E. Conservative: no copy/compute overlap modeled."""
    if phase.kind == "sync":
        return phase.count * hw.tau_sync_s
    bw = hw.h2d_bandwidth if phase.kind == "h2d" else hw.d2h_bandwidth
    return phase.count * (phase.bytes / bw + hw.tau_memcpy_s)


def segment_overhead(seg: Segment, hw: HardwareParams) -> float:
    """Host phases + extra kernel launches (multi-kernel segments)."""
    t = sum(host_phase_time(p, hw) for p in seg.host_phases)
    t += seg.extra_kernels * hw.launch_latency_s
    return t

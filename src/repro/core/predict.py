"""Unified prediction entry point with architecture routing (paper §IV-C/D).

Workflow (paper §IV-D):
  1. characterize the workload (class, AI, working set, tiles),
  2. select the parameter file,
  3. apply the appropriate formula:
       Blackwell-family -> stage-centric model (core.blackwell)
       CDNA-family      -> wavefront-centric model (core.cdna3)
       TPU              -> TPU-adapted stage model (core.tpu)
       otherwise        -> generic calibrated roofline (core.generic)

Class-based routing for application segments mirrors §V-B: stencil ->
transpose proxy, compute-bound -> GEMM family, memory-bound -> vector copy.
"""
from __future__ import annotations

from typing import Optional

from . import blackwell, cdna3, generic, roofline
from .hardware import HardwareParams
from .workload import TimeBreakdown, Workload


def predict(w: Workload, hw: HardwareParams, *,
            model: Optional[str] = None,
            calibration: Optional["object"] = None) -> TimeBreakdown:
    """Predict execution time of one kernel on one accelerator.

    ``model`` overrides routing: "stage" | "wavefront" | "generic" |
    "roofline" | "tpu".  ``calibration`` is an optional
    ``core.calibrate.Calibration`` applied multiplicatively per case.
    """
    route = model or _default_route(hw)
    if route == "roofline":
        out = roofline.predict(w, hw)
    elif route == "stage":
        out = blackwell.predict(w, hw)
    elif route == "wavefront":
        out = cdna3.predict(w, hw)
    elif route == "tpu":
        from . import tpu  # local import: tpu.py depends on collectives
        out = tpu.predict(w, hw)
    elif route == "generic":
        out = generic.predict(w, hw)
    else:
        raise ValueError(f"unknown model route {route!r}")

    if calibration is not None:
        out = calibration.apply(w, out)
    return out


def _default_route(hw: HardwareParams) -> str:
    return {
        "blackwell": "stage",
        "cdna": "wavefront",
        "tpu": "tpu",
        "generic": "generic",
    }.get(hw.model_family, "generic")

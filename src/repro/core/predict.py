"""Unified prediction entry point with architecture routing (paper §IV-C/D).

Workflow (paper §IV-D):
  1. characterize the workload (class, AI, working set, tiles),
  2. select the parameter file,
  3. apply the appropriate formula:
       Blackwell-family -> stage-centric model (core.blackwell)
       CDNA-family      -> wavefront-centric model (core.cdna3)
       TPU              -> TPU-adapted stage model (core.tpu)
       otherwise        -> generic calibrated roofline (core.generic)

Class-based routing for application segments mirrors §V-B: stencil ->
transpose proxy, compute-bound -> GEMM family, memory-bound -> vector copy.

Batched prediction
------------------
Scalar ``predict`` delegates to the shared ``core.sweep.SweepEngine`` as a
batch of one, so every call is memoized under a content key (Workload +
HardwareParams + route) and repeated autotune/hillclimb queries are O(1).
For sweeps — tile searches, precision ladders, portfolio pricing — call the
engine directly and amortize the Python dispatch over the whole batch:

    from repro.core import hardware, sweep
    from repro.core.workload import TileConfig, gemm_workload

    engine = sweep.default_engine()
    candidates = [gemm_workload("g", 8192, 8192, 8192,
                                tile=TileConfig(bm, bn, bk))
                  for bm in (64, 128, 256)
                  for bn in (64, 128, 256)
                  for bk in (32, 64, 128)]
    times = engine.predict_batch(candidates, hardware.B200)
    best = candidates[min(range(len(times)), key=lambda i: times[i].total)]

``predict_batch`` is NumPy-vectorized per route (10^3-10^4-point sweeps run
>=10x faster than a scalar loop; see benchmarks/sweep_bench.py) and
bit-identical to the scalar path (tests/test_sweep.py).
"""
from __future__ import annotations

from typing import Optional

from .hardware import HardwareParams
from .workload import TimeBreakdown, Workload


def predict(w: Workload, hw: HardwareParams, *,
            model: Optional[str] = None,
            calibration: Optional["object"] = None) -> TimeBreakdown:
    """Predict execution time of one kernel on one accelerator.

    ``model`` overrides routing: "stage" | "wavefront" | "generic" |
    "roofline" | "tpu".  ``calibration`` is an optional
    ``core.calibrate.Calibration`` applied multiplicatively per case.
    """
    from . import sweep
    return sweep.default_engine().predict(
        w, hw, model=model, calibration=calibration)


def _default_route(hw: HardwareParams) -> str:
    from . import sweep
    return sweep.default_route(hw)

"""TPU v5e adaptation of the paper's stage-centric model (DESIGN.md §3).

TPU execution has Blackwell-like *explicit* stages — compiler-scheduled
HBM->VMEM DMA (the TMA analogue), VMEM-resident tiles/accumulators (the TMEM
analogue), the MXU systolic array (the tensor-core analogue) — plus a stage
the paper lacks: ICI/DCI collectives.  Following the paper's structure:

    T_step = max(T_mxu + T_vpu, T_io_eff, T_coll_exposed) + T_sync
    T_io_eff = (1 - alpha) * T_dma                                (Eq. 7)
    T_dma    = L_dma + bytes / B_eff(W)                           (Eq. 4/16)
    T_mxu    = matrix_flops / (197 TF/s * util(precision, align))
    T_coll   = ring model per core.collectives
    T_total  = T_launch + T_step + (N-1) * tau_interf  (straggler budget)

There is no occupancy (one program per core); overlap is the compiler's
double/triple-buffering, so we reuse the paper's alpha in [0.85, 0.95].

This module is also the consumer of dry-run artifacts: ``from_cost_analysis``
builds a Workload from compiled.cost_analysis() + parsed collective bytes,
and ``roofline_report`` emits the three task-spec roofline terms.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


from . import collectives as coll
from .cache import working_set_blend, working_set_blend_batch
from .hardware import BYTES_PER_ELEM, HardwareParams, TPU_V5E
from .workload import Row, TimeBreakdown, Workload, tb_from_row


def mxu_utilization(w: Workload, hw: HardwareParams) -> float:
    """MXU efficiency: precision factor x dimension-alignment factor.

    The MXU is a 128x128 systolic array; matmul dims not multiples of 128
    waste lanes (paper's S_mode / utilization analogue, re-derived for TPU).
    """
    eff = hw.precision_efficiency.get(w.precision, 1.0)
    if w.gemm is not None:
        for dim in (w.gemm.m, w.gemm.n, w.gemm.k):
            if dim % 128 != 0:
                pad = 128 * -(-dim // 128)
                eff *= dim / pad
    return eff


def dma_time(w: Workload, hw: HardwareParams) -> float:
    """HBM->VMEM DMA stage (TMA analogue): latency + bytes / B_eff(W)."""
    bw = working_set_blend(w.working_set_bytes or w.bytes, hw)
    t = hw.cycles_to_seconds(hw.tma_latency_cycles) + w.bytes / bw
    if w.irregular:
        t *= 4.0
    return t


def compute_time(w: Workload, hw: HardwareParams) -> float:
    """MXU + VPU stages. Matrix FLOPs ride the MXU; the rest ride the VPU."""
    if w.matrix:
        rate = hw.sustained_flops(w.precision, matrix=True)
        return w.flops / (rate * mxu_utilization(w, hw)
                          / hw.precision_efficiency.get(w.precision, 1.0))
    rate = hw.sustained_flops(w.precision, matrix=False)
    return w.flops / rate if w.flops > 0 else 0.0


def predict(w: Workload, hw: HardwareParams = TPU_V5E, *,
            mesh: Optional[coll.MeshSpec] = None,
            collective_ops: Sequence[Tuple[str, float, str]] = (),
            coll_overlap: Optional[float] = None) -> TimeBreakdown:
    """Stage-centric TPU prediction."""
    t_comp = compute_time(w, hw)
    t_dma = dma_time(w, hw)
    alpha = hw.pipeline_overlap_alpha
    t_sync = hw.cycles_to_seconds(hw.mbarrier_latency_cycles)
    t_io_eff = (1.0 - alpha) * t_dma + t_sync                    # Eq. 7

    t_coll = t_coll_exposed = 0.0
    if mesh is not None and collective_ops:
        a = alpha if coll_overlap is None else coll_overlap
        sched = coll.schedule_time(collective_ops, mesh, hw, overlap_alpha=a)
        t_coll, t_coll_exposed = sched["total"], sched["exposed"]

    t_step = max(t_comp, t_io_eff, t_coll_exposed) + t_sync      # Eq. 8
    total = hw.launch_latency_s + t_step
    total += (w.num_devices - 1) * 0.0  # SPMD: no per-device serial term;
    # straggler budget is reported separately (see straggler_budget()).
    return TimeBreakdown(
        total=total, compute=t_comp, memory=t_dma, io_effective=t_io_eff,
        sync=t_sync, launch=hw.launch_latency_s, collective=t_coll,
        detail={"t_coll_exposed": t_coll_exposed,
                "mxu_util": mxu_utilization(w, hw) if w.matrix else 0.0,
                "alpha": alpha},
    )


# ---------------------------------------------------------------------------
# Columnar (NumPy-vectorized) stage model — the WorkloadTable / SweepEngine
# hot path.  No mesh/collectives in batch mode (matching the scalar
# default); results are bit-identical to per-workload ``predict(w, hw)``.
# ---------------------------------------------------------------------------

def _mxu_utilization_batch(raw: np.ndarray, eff: np.ndarray) -> np.ndarray:
    from .workload import NV_GM, NV_GN, NV_GK, NV_HAS_GEMM
    has_gemm = raw[:, NV_HAS_GEMM] != 0
    util = eff
    for col in (NV_GM, NV_GN, NV_GK):
        dim = np.where(has_gemm, raw[:, col], 128.0)
        pad = 128 * -(-dim // 128)
        factor = np.where(dim % 128 != 0, dim / pad, 1.0)
        util = util * factor
    return util


def predict_table_cols(table, hw: HardwareParams = TPU_V5E):
    """Columnar ``predict`` over a WorkloadTable (no collectives — matching
    the scalar default).  Bit-identical per row to scalar ``predict``."""
    from .workload import NV_FLOPS, NV_BYTES, NV_WS_OR_BYTES, NV_MATRIX, \
        NV_IRREGULAR, TableCols
    raw = table.cols
    flops, nbytes, wsb = raw[:, NV_FLOPS], raw[:, NV_BYTES], \
        raw[:, NV_WS_OR_BYTES]
    is_mat = raw[:, NV_MATRIX] != 0

    rate = table.per_precision_matrix(
        lambda p, m: hw.sustained_flops(p, matrix=m))
    eff = table.per_precision(
        lambda p: hw.precision_efficiency.get(p, 1.0))

    util = _mxu_utilization_batch(raw, eff)
    with np.errstate(divide="ignore", invalid="ignore"):
        t_comp = np.where(
            is_mat,
            flops / (rate * util / eff),
            np.where(flops > 0, flops / rate, 0.0))

    bw = working_set_blend_batch(wsb, hw)
    t_dma = hw.cycles_to_seconds(hw.tma_latency_cycles) + nbytes / bw
    t_dma = np.where(raw[:, NV_IRREGULAR] != 0, t_dma * 4.0, t_dma)

    alpha = hw.pipeline_overlap_alpha
    t_sync = hw.cycles_to_seconds(hw.mbarrier_latency_cycles)
    t_io_eff = (1.0 - alpha) * t_dma + t_sync                    # Eq. 7
    t_step = np.maximum(np.maximum(t_comp, t_io_eff), 0.0) + t_sync
    total = hw.launch_latency_s + t_step  # (N-1)*0.0 device term: no-op

    return TableCols(
        len(table),
        (total, t_comp, t_dma, t_io_eff, t_sync, hw.launch_latency_s,
         0.0, 0.0, 0.0),
        ("t_coll_exposed", "mxu_util", "alpha"),
        (0.0, np.where(is_mat, util, 0.0), alpha))


def predict_rows(ws: Sequence[Workload],
                 hw: HardwareParams = TPU_V5E) -> List[Row]:
    """Vectorized ``predict`` over a workload batch, in row form (no
    collectives — matching the scalar default)."""
    from .workload import WorkloadTable
    return predict_table_cols(WorkloadTable.from_workloads(ws), hw).rows()


def predict_batch(ws: Sequence[Workload],
                  hw: HardwareParams = TPU_V5E) -> List[TimeBreakdown]:
    """Materialized form of ``predict_rows``."""
    return [tb_from_row(r) for r in predict_rows(ws, hw)]


def straggler_budget(num_workers: int, hw: HardwareParams = TPU_V5E) -> float:
    """Paper's (N-1)*tau interference term repurposed as a per-step
    straggler/jitter budget across workers (DESIGN.md §3)."""
    return (max(num_workers, 1) - 1) * hw.tau_interference_s / max(
        num_workers, 1)


# ---------------------------------------------------------------------------
# Dry-run artifact consumption (the §Roofline deliverable).
# ---------------------------------------------------------------------------

# Task-spec hardware constants for the roofline terms.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # per chip
ICI_LINK_BW = 50e9                # per link


@dataclass(frozen=True)
class RooflineReport:
    """Three-term roofline per (arch x shape x mesh) from a compiled
    dry-run artifact.  All terms in seconds (task-spec formulas)."""

    name: str
    num_chips: int
    hlo_flops: float              # whole-program FLOPs (all chips)
    hlo_bytes: float              # whole-program bytes accessed
    collective_bytes: float       # summed collective operand bytes
    model_flops: float            # 6*N*D (dense) / 6*N_active*D (MoE)

    @property
    def compute_term(self) -> float:
        return self.hlo_flops / (self.num_chips * PEAK_FLOPS_BF16)

    @property
    def memory_term(self) -> float:
        return self.hlo_bytes / (self.num_chips * HBM_BW)

    @property
    def collective_term(self) -> float:
        return self.collective_bytes / (self.num_chips * ICI_LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_term, "memory": self.memory_term,
                 "collective": self.collective_term}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.compute_term, self.memory_term, self.collective_term)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: fraction of compiled compute that is
        'useful' (catches remat/redundancy waste)."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute_term / bound_time: 1.0 == perfectly compute-bound at
        the spec roofline."""
        b = self.bound_time
        return self.compute_term / b if b > 0 else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "chips": self.num_chips,
            "compute_s": self.compute_term,
            "memory_s": self.memory_term,
            "collective_s": self.collective_term,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def report_from_artifacts(name: str, *, num_chips: int,
                          cost_analysis: Dict[str, float],
                          collective_bytes: float,
                          model_flops: float) -> RooflineReport:
    """Build a RooflineReport from compiled.cost_analysis() output + the
    HLO-parsed collective byte total (launch/hlo_analysis.py)."""
    flops = float(cost_analysis.get("flops", 0.0))
    nbytes = float(cost_analysis.get("bytes accessed", 0.0))
    return RooflineReport(name=name, num_chips=num_chips, hlo_flops=flops,
                          hlo_bytes=nbytes, collective_bytes=collective_bytes,
                          model_flops=model_flops)

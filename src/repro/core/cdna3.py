"""Wavefront-centric analytical model for AMD CDNA3 / MI300A (paper §IV-B).

Overlap is implicit and occupancy-driven; accumulators live in VGPRs:

    eta_overlap = min(1, (N_wf_active - 1) * T_compute / T_memory)   (Eq. 9)
    T_memory^eff: expected-latency walk over L1/L2/LLC/HBM           (Eq. 10)
    BW_eff = h_LLC * BW_LLC + (1 - h_LLC) * BW_HBM
    h_LLC(W): piecewise Infinity-Cache model                         (Tab. III)
    T_compute^MFMA = N_inst / (N_CU * Thr_MFMA * Util)               (Eq. 11)
    N_wf_active = min(32, floor(65536 / VGPR_per_wf))
    T_step = (T_memory^eff + T_compute) / (1 + eta_overlap)          (Eq. 12)
    T_kernel = T_launch + K_tiles*T_step + T_writeback
               + T_coherence + T_crossXCD                            (Eq. 13)
    occupancy/tile pipeline model                                    (Eq. 14)

Optional extensions implemented per §IV-B: MWP/CWP limits, multi-kernel
interference (N-1)*tau_interf, multi-GPU (N-1)*tau_gpu, adaptive tile
selection, kernel fusion with tau_fusion.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


from .cache import effective_bandwidth_llc, effective_bandwidth_llc_batch, \
    hierarchy_latency_walk, llc_hit_rate, llc_hit_rate_batch
from .hardware import BYTES_PER_ELEM, HardwareParams
from .workload import GemmShape, Row, TileConfig, TimeBreakdown, Workload, \
    row_from_tb, tb_from_row

MFMA_FLOPS_PER_INST = 512.0  # 32x32x8 fp64 MFMA ~= 2*32*32*8/128... canonical
                             # per-inst FLOP count used to convert FLOPs ->
                             # instruction counts (paper Eq. 11 N_MFMA_inst).


def vgpr_limited_occupancy(vgpr_per_workitem: int, hw: HardwareParams,
                           *, mwp: int = 0, cwp: int = 0) -> int:
    """N_wf_active = min(32, floor(65536 / VGPR_per_wf)); optionally capped
    by MWP/CWP limits (paper §IV-B: N_wf_eff = min(N_active, MWP, CWP))."""
    # VGPR_per_wf = per-workitem VGPRs x wavefront width; the 65536 budget
    # is the CU's VGPR file in workitem-register units (paper's formula).
    vgpr_per_wf = max(1, vgpr_per_workitem) * hw.warp_size
    n = min(hw.max_resident_warps, hw.vgpr_per_cu // max(vgpr_per_wf, 1))
    n = max(1, n)
    if mwp > 0:
        n = min(n, mwp)
    if cwp > 0:
        n = min(n, cwp)
    return int(n)


def overlap_factor(n_wf_active: int, t_compute: float,
                   t_memory: float) -> float:
    """Eq. 9. Returns eta in [0, 1]."""
    if t_memory <= 0:
        return 1.0
    eta = (max(n_wf_active, 1) - 1) * t_compute / t_memory
    return min(1.0, max(0.0, eta))


def memory_time(w: Workload, hw: HardwareParams) -> float:
    """T_memory^eff: Eq. 10 latency walk when per-load hit rates and
    N_loads are given, else bandwidth path bytes / BW_eff with h_LLC(W)."""
    if w.num_loads > 0 and w.hit_rates:
        return hierarchy_latency_walk(w.num_loads, w.hit_rates, hw)
    h = w.hit_rates.get("llc") if w.hit_rates else None
    bw = effective_bandwidth_llc(w.working_set_bytes or w.bytes, hw, h_llc=h)
    t = w.bytes / bw
    if w.irregular:
        t *= 4.0  # Obs. 2: irregular access degrades toward latency-bound
    return t


def mfma_compute_time(w: Workload, hw: HardwareParams) -> float:
    """Eq. 11: T = N_inst / (N_CU * Throughput_MFMA * Utilization).

    We convert FLOPs -> MFMA instructions and use the measured per-chip
    matrix throughput, so the equation reduces to
    flops / (chip_matrix_flops * utilization); the N_CU factorization is
    kept in the parameter file (throughput is per chip = per CU * N_CU).
    """
    eff = hw.precision_efficiency.get(w.precision, 1.0)
    if w.precision in hw.tensor_sustained_flops:
        # sustained throughput *is* peak*utilization as measured; applying
        # Util again would double-count.
        rate = hw.tensor_sustained_flops[w.precision] * eff
    else:
        # Eq. 11 literal form: peak * Utilization (Util 0.4-0.7, Table IV)
        rate = hw.peak_flops(w.precision, matrix=True) \
            * hw.mfma_utilization * eff
    return w.flops / rate


def vector_compute_time(w: Workload, hw: HardwareParams) -> float:
    rate = hw.sustained_flops(w.precision, matrix=False)
    return w.flops / rate if w.flops > 0 else 0.0


def step_time(t_memory: float, t_compute: float, eta: float) -> float:
    """Eq. 12: T_step = (T_mem + T_comp) / (1 + eta)."""
    return (t_memory + t_compute) / (1.0 + eta)


def predict(w: Workload, hw: HardwareParams, *,
            mwp: int = 0, cwp: int = 0,
            k_tiles_override: Optional[int] = None) -> TimeBreakdown:
    """Wavefront-centric MI300A prediction (Eq. 9-13).

    The base model (MWP=CWP=0) is what the paper's reported MAE uses.
    """
    if hw.model_family != "cdna":
        raise ValueError(f"cdna3 model mis-routed to {hw.name}")

    n_wf = vgpr_limited_occupancy(w.vgpr_per_workitem, hw, mwp=mwp, cwp=cwp)
    k_tiles = k_tiles_override if k_tiles_override is not None \
        else max(w.k_tiles, 1)

    # per-step slices of the kernel's totals
    t_mem_total = memory_time(w, hw)
    t_comp_total = (mfma_compute_time(w, hw) if w.matrix
                    else vector_compute_time(w, hw))
    t_mem = t_mem_total / k_tiles
    t_comp = t_comp_total / k_tiles

    eta = overlap_factor(n_wf, t_comp, t_mem)
    t_step = step_time(t_mem, t_comp, eta)

    t_writeback = 0.0
    if w.gemm is not None:
        out_b = w.gemm.m * w.gemm.n * BYTES_PER_ELEM[w.precision]
        t_writeback = out_b / effective_bandwidth_llc(
            w.working_set_bytes or w.bytes, hw)

    total = (hw.launch_latency_s + k_tiles * t_step + t_writeback
             + hw.coherence_latency_s + hw.cross_xcd_latency_s)   # Eq. 13
    # §IV-B multi-kernel / multi-GPU interference terms
    total += (w.concurrent_kernels - 1) * hw.tau_interference_s
    total += (w.num_devices - 1) * hw.tau_interference_gpu_s

    return TimeBreakdown(
        total=total,
        compute=t_comp_total,
        memory=t_mem_total,
        io_effective=t_mem_total,
        sync=hw.coherence_latency_s + hw.cross_xcd_latency_s,
        launch=hw.launch_latency_s,
        writeback=t_writeback,
        detail={
            "n_wf_active": float(n_wf), "eta_overlap": eta,
            "t_step": t_step,
            "h_llc": llc_hit_rate(w.working_set_bytes or w.bytes, hw),
        },
    )


# ---------------------------------------------------------------------------
# Columnar (NumPy-vectorized) wavefront model — the WorkloadTable /
# SweepEngine hot path.  Workloads carrying explicit hit rates or an Eq. 10
# latency walk (per-workload dicts) fall back to the scalar `predict`;
# everything else is vectorized bit-identically to the scalar expressions.
# ---------------------------------------------------------------------------

def _rate_fn(hw: HardwareParams):
    """Compute rate per (precision, matrix) mirroring mfma_compute_time /
    vector_compute_time rate selection."""
    def fn(p: str, matrix: bool) -> float:
        if matrix:
            eff = hw.precision_efficiency.get(p, 1.0)
            if p in hw.tensor_sustained_flops:
                return hw.tensor_sustained_flops[p] * eff
            return hw.peak_flops(p, matrix=True) * hw.mfma_utilization * eff
        return hw.sustained_flops(p, matrix=False)
    return fn


def _vectorized_cols(table, hw: HardwareParams):
    from .workload import NV_VGPR, NV_K_TILES, NV_BYTES, NV_WS_OR_BYTES, \
        NV_FLOPS, NV_IRREGULAR, NV_GMN, NV_HAS_GEMM, NV_MATRIX, \
        NV_CONCURRENT, NV_DEVICES, TableCols
    raw = table.cols
    vgpr_wf = np.maximum(1, raw[:, NV_VGPR].astype(np.int64)) * hw.warp_size
    n_wf = np.maximum(
        1, np.minimum(hw.max_resident_warps, hw.vgpr_per_cu // vgpr_wf))
    k_tiles = np.maximum(raw[:, NV_K_TILES].astype(np.int64), 1)

    nbytes, wsb, flops = raw[:, NV_BYTES], raw[:, NV_WS_OR_BYTES], \
        raw[:, NV_FLOPS]
    bw_eff = effective_bandwidth_llc_batch(wsb, hw)
    t_mem_total = nbytes / bw_eff
    t_mem_total = np.where(raw[:, NV_IRREGULAR] != 0, t_mem_total * 4.0,
                           t_mem_total)
    rate = table.per_precision_matrix(_rate_fn(hw))
    with np.errstate(divide="ignore", invalid="ignore"):
        t_comp_total = np.where((raw[:, NV_MATRIX] != 0) | (flops > 0),
                                flops / rate, 0.0)

    t_mem = t_mem_total / k_tiles
    t_comp = t_comp_total / k_tiles
    with np.errstate(divide="ignore", invalid="ignore"):
        eta_raw = (n_wf - 1) * t_comp / t_mem
        eta = np.where(t_mem <= 0, 1.0,
                       np.minimum(1.0, np.maximum(0.0, eta_raw)))
    t_step = (t_mem + t_comp) / (1.0 + eta)

    if raw[:, NV_HAS_GEMM].any():
        in_b = table.per_precision(lambda p: BYTES_PER_ELEM[p])
        out_b = raw[:, NV_GMN] * in_b
        t_writeback = np.where(raw[:, NV_HAS_GEMM] != 0,
                               out_b / bw_eff, 0.0)
    else:
        t_writeback = np.zeros(len(table))

    total = hw.launch_latency_s + k_tiles * t_step + t_writeback \
        + hw.coherence_latency_s + hw.cross_xcd_latency_s          # Eq. 13
    total = total + (raw[:, NV_CONCURRENT] - 1) * hw.tau_interference_s
    total = total + (raw[:, NV_DEVICES] - 1) * hw.tau_interference_gpu_s

    h_llc = llc_hit_rate_batch(wsb, hw)
    sync = hw.coherence_latency_s + hw.cross_xcd_latency_s
    return TableCols(
        len(table),
        (total, t_comp_total, t_mem_total, t_mem_total, sync,
         hw.launch_latency_s, t_writeback, 0.0, 0.0),
        ("n_wf_active", "eta_overlap", "t_step", "h_llc"),
        (n_wf.astype(np.float64), eta, t_step, h_llc))


def predict_table_cols(table, hw: HardwareParams):
    """Columnar ``predict`` over a WorkloadTable (base model, MWP=CWP=0).
    Bit-identical per row to scalar ``predict``; rows with explicit hit
    rates / Eq. 10 latency walks fall back to the scalar path per row."""
    from .workload import NV_NUM_LOADS, RowsCols, SegmentedCols
    if hw.model_family != "cdna":
        raise ValueError(f"cdna3 model mis-routed to {hw.name}")
    exotic = table.cols[:, NV_NUM_LOADS] > 0
    if table.hit_rates is not None:
        exotic = exotic | np.array([bool(h) for h in table.hit_rates])
    if not exotic.any():
        return _vectorized_cols(table, hw)
    idx_e = np.flatnonzero(exotic)
    idx_f = np.flatnonzero(~exotic)
    segments = [(idx_e, RowsCols(
        # repro: allow[SWEEP-LOOP] exotic rows (explicit hit rates /
        # Eq. 10 latency walks) are priced per row by design — the
        # columnar kernel has no path for them and bit-identity with
        # scalar predict() is the contract tests pin
        [row_from_tb(predict(table.workload(int(i)), hw))
         for i in idx_e]))]
    if len(idx_f):
        segments.append((idx_f, _vectorized_cols(table.take(idx_f), hw)))
    return SegmentedCols(len(table), segments)


def predict_rows(ws: Sequence[Workload], hw: HardwareParams) -> List[Row]:
    """Vectorized ``predict`` over a workload batch, in row form (base
    model, MWP=CWP=0).  Bit-identical to per-workload ``predict``;
    workloads with explicit hit rates / Eq. 10 latency walks fall back to
    the scalar path."""
    from .workload import WorkloadTable
    return predict_table_cols(WorkloadTable.from_workloads(ws), hw).rows()


def predict_batch(ws: Sequence[Workload],
                  hw: HardwareParams) -> List[TimeBreakdown]:
    """Materialized form of ``predict_rows``."""
    return [tb_from_row(r) for r in predict_rows(ws, hw)]


# ---------------------------------------------------------------------------
# Occupancy/tile pipeline model (Eq. 14): used for the 8x8 vs 16x16 study.
# ---------------------------------------------------------------------------

def occupancy_tile_predict(w: Workload, hw: HardwareParams, *,
                           tau_cta_s: float = 2e-7,
                           w_eff: Optional[float] = None) -> TimeBreakdown:
    """Eq. 14:
    T = T_launch + tau_cta*N_ctas + N_ctas*T_step_cta/(N_CU*W_eff)
        + T_writeback + T_coherence + T_crossXCD
    with T_step_cta = max(flops_per_cta/peak_cta, bytes_per_cta/BW_eff).
    """
    tile = w.tile or TileConfig()
    n_ctas = max(w.num_ctas, 1)
    flops_per_cta = w.flops / n_ctas
    bytes_per_cta = (w.bytes_per_cta * max(w.k_tiles, 1)
                     if w.bytes_per_cta > 0 else w.bytes / n_ctas)

    if w_eff is None:
        # effective wavefronts per CU: larger tiles need more VGPRs
        # (accumulator bM*bN/wavefront) -> lower occupancy, better reuse.
        accum_vgprs = tile.bm * tile.bn / hw.warp_size / 4  # 4B regs, /64 lanes
        vgpr_wi = max(32, int(accum_vgprs))
        w_eff = float(vgpr_limited_occupancy(vgpr_wi, hw))

    bw_eff = effective_bandwidth_llc(w.working_set_bytes or w.bytes, hw)
    peak_cta = (hw.sustained_flops(w.precision, matrix=w.matrix)
                / hw.num_sms)
    t_step_cta = max(flops_per_cta / peak_cta, bytes_per_cta / bw_eff)

    t_sched = tau_cta_s * n_ctas
    t_exec = n_ctas * t_step_cta / (hw.num_sms * max(w_eff, 1.0))
    out_b = (w.gemm.m * w.gemm.n * BYTES_PER_ELEM[w.precision]
             if w.gemm else 0.0)
    t_writeback = out_b / bw_eff
    total = (hw.launch_latency_s + t_sched + t_exec + t_writeback
             + hw.coherence_latency_s + hw.cross_xcd_latency_s)
    return TimeBreakdown(
        total=total, compute=n_ctas * flops_per_cta / peak_cta / hw.num_sms,
        memory=n_ctas * bytes_per_cta / bw_eff / hw.num_sms,
        launch=hw.launch_latency_s + t_sched, writeback=t_writeback,
        detail={"w_eff": w_eff, "t_step_cta": t_step_cta,
                "n_ctas": float(n_ctas)},
    )


def adaptive_tile_selection(
        base: Workload, hw: HardwareParams,
        candidate_tiles: Iterable[TileConfig],
        **kw) -> Tuple[TileConfig, Dict[str, float]]:
    """Paper §IV-B 'adaptive tile selection': evaluate candidate tiles via
    the model and return the minimum-time tile (+ the full cost map)."""
    costs: Dict[str, float] = {}
    best: Optional[TileConfig] = None
    best_t = math.inf
    for tile in candidate_tiles:
        w = _retile(base, tile)
        t = occupancy_tile_predict(w, hw, **kw).total
        costs[f"{tile.bm}x{tile.bn}x{tile.bk}"] = t
        if t < best_t:
            best_t, best = t, tile
    assert best is not None, "no candidate tiles given"
    return best, costs


def _retile(w: Workload, tile: TileConfig) -> Workload:
    if w.gemm is None:
        return w.replace(tile=tile)
    g = w.gemm
    num_ctas = -(-g.m // tile.bm) * -(-g.n // tile.bn)
    k_tiles = -(-g.k // tile.bk)
    in_b = BYTES_PER_ELEM[w.precision]
    bytes_per_cta = (tile.bm * tile.bk + tile.bk * tile.bn) * in_b
    return w.replace(tile=tile, num_ctas=num_ctas, k_tiles=k_tiles,
                     bytes_per_cta=bytes_per_cta)


def fused_predict(parts: List[Workload], hw: HardwareParams) -> TimeBreakdown:
    """Paper §IV-B kernel fusion: combined FLOPs/bytes + tau_fusion,
    minus the intermediate writeback/read traffic between the parts."""
    if not parts:
        raise ValueError("fusion of zero kernels")
    combined_flops = sum(p.flops for p in parts)
    # fusing removes the intermediate tensor round-trip between stages
    inter_bytes = sum(min(parts[i].bytes, parts[i + 1].bytes) * 0.5
                      for i in range(len(parts) - 1))
    combined_bytes = max(sum(p.bytes for p in parts) - inter_bytes, 0.0)
    fused = parts[0].replace(
        name="+".join(p.name for p in parts),
        flops=combined_flops, bytes=combined_bytes,
        working_set_bytes=max(p.working_set_bytes for p in parts),
    )
    out = predict(fused, hw)
    return TimeBreakdown(
        total=out.total + hw.tau_fusion_s,
        compute=out.compute, memory=out.memory,
        io_effective=out.io_effective, sync=out.sync, launch=out.launch,
        writeback=out.writeback,
        detail=dict(out.detail, tau_fusion=hw.tau_fusion_s),
    )

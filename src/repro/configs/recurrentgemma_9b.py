"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern.
38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427].  Local attention window 2048.

38 layers = 2 groups x 19-block pattern ((rglru, rglru, local_attn) x 6 +
one trailing rglru) — matches the published 2:1 mix with a recurrent tail.
Sub-quadratic: runs long_500k."""
from .base import ModelConfig

_PATTERN = ("rglru", "rglru", "local_attn") * 6 + ("rglru",)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    window=2048,
    pattern=_PATTERN,
    lru_width=4096,
    tie_embeddings=True,
    attn_logit_softcap=30.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="block",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="rg-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=128,
    window=8,
    pattern=("rglru", "rglru", "local_attn"),
    lru_width=64,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

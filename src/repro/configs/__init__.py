from .base import ModelConfig  # noqa: F401
from .registry import (ARCH_IDS, SHAPES, SUBQUADRATIC, all_cells,  # noqa
                       cell_applicable, get_config, memory_len)

"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4.
94L d_model=4096 64H d_ff=1536(expert) vocab=151936
[hf:Qwen/Qwen3-30B-A3B scaled family]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    pattern=("moe",),
    n_experts=128,
    top_k=8,
    n_shared_experts=0,
    d_expert=1536,
    capacity_factor=1.25,
    rope_theta=1000000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="full",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="qwen3moe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=48,
    d_expert=48,
    vocab=128,
    n_experts=8,
    top_k=2,
    capacity_factor=4.0,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

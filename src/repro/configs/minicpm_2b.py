"""minicpm-2b [dense] — WSD schedule, llama-like arch with depth/width
mup-style scaling.  40L d_model=2304 36H (kv=36 = MHA) d_ff=5760
vocab=122753 [arXiv:2404.06395].  Tied embeddings; residual scaled by
1.4/sqrt(L); logits scaled by 256/d_model.  The WSD (warmup-stable-decay)
schedule is wired in repro.optim.schedule and selected by the train
driver for this arch."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
    residual_scale=1.4 / 40 ** 0.5,
    logit_scale=256.0 / 2304.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="block",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="minicpm-smoke",
    n_layers=2,
    d_model=72,
    n_heads=6,
    n_kv_heads=6,
    d_ff=180,
    vocab=128,
    residual_scale=1.4 / 2 ** 0.5,
    logit_scale=256.0 / 72.0,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

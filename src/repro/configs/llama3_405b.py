"""llama3-405b [dense] — GQA, 128k vocab.
126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256
[arXiv:2407.21783]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope_theta=500000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="full",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="llama405b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=1,
    d_ff=416,
    vocab=128,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th block;
vision encoder is a STUB (input_specs provides precomputed patch
embeddings).  100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision family]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "cross_attn"),
    n_image_tokens=1601,        # one tile of 40x40 patches + cls (stub)
    rope_theta=500000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="full",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="vlm-smoke",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=128,
    n_image_tokens=8,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

"""Model configuration schema.

One dataclass covers all 10 assigned architecture families; family-specific
fields default to "unused".  Every ``src/repro/configs/<arch>.py`` exports
``CONFIG`` (the exact assigned full-scale config) and ``SMOKE`` (a reduced
same-family config for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm

    # --- core dims ----------------------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- attention variant --------------------------------------------------
    window: int = 0                # >0: sliding-window attention (SWA)
    attn_logit_softcap: float = 0.0

    # --- block pattern (hybrid / vlm) ----------------------------------------
    # sequence of block kinds repeated to fill n_layers, e.g.
    # ("rglru", "rglru", "local_attn") or ("attn",)*4 + ("cross_attn",)
    pattern: Tuple[str, ...] = ("attn",)

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0              # expert FFN width (d_ff used if 0)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    moe_every: int = 1             # MoE layer every k-th block (1 = all)
    first_dense: int = 0           # leading dense blocks before MoE starts

    # --- MLA (deepseek-v3) ---------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 -> no q compression
    rope_head_dim: int = 64

    # --- MTP (deepseek-v3 multi-token prediction) ----------------------------
    mtp_depth: int = 0

    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0             # N
    ssm_headdim: int = 64          # P
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_chunk: int = 128
    conv_width: int = 4

    # --- RG-LRU (recurrentgemma) ---------------------------------------------
    lru_width: int = 0             # 0 -> d_model

    # --- encoder-decoder (whisper) -------------------------------------------
    enc_layers: int = 0            # 0 -> decoder-only
    enc_seq_ratio: float = 1.0     # encoder len = ratio * seq_len

    # --- modality frontends (STUBS per assignment) ---------------------------
    n_image_tokens: int = 0        # vlm: stub patch-embedding count
    frontend_dim: int = 0          # stub embedding dim (0 -> d_model)

    # --- numerics / training -------------------------------------------------
    dtype: str = "float32"         # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "none"            # none | block | full
    scan_layers: bool = True
    residual_scale: float = 1.0    # minicpm-style depth scaling
    logit_scale: float = 1.0
    use_flash_kernel: bool = False  # Pallas path (TPU); CPU tests use XLA
    attn_chunk: int = 0            # >0: chunked (flash-in-XLA) attention
    attn_chunk_unroll: bool = False  # unroll the chunk loop (dry-run
                                     # accounting: while-bodies are counted
                                     # once by cost_analysis)
    # --- §Perf hillclimb switches (off = paper-faithful baseline) ---------
    ssd_shard_map: bool = False    # explicit shard_map SSD layer (kills the
                                   # GSPMD bwd all-reduces; EXPERIMENTS §Perf)
    ssd_tile_bf16: bool = False    # bf16 (L,L) SSD tiles, fp32 accumulation
    mtp_share_trunk: bool = False  # MTP head reuses the main forward's
                                   # hidden states instead of re-running it

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if (self.n_layers - self.first_dense) % len(self.pattern) != 0:
            raise ValueError(
                f"n_layers={self.n_layers} minus first_dense="
                f"{self.first_dense} not divisible by pattern "
                f"{self.pattern}")

    # --- derived -------------------------------------------------------------
    @property
    def n_groups(self) -> int:
        return (self.n_layers - self.first_dense) // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def expert_ff(self) -> int:
        return self.d_expert or self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- analytics (feeds MODEL_FLOPS = 6*N*D in §Roofline) -------------------
    def param_count(self) -> int:
        """Total parameters (embeddings included)."""
        return _count_params(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared only)."""
        return _count_params(self, active_only=True)


def _attn_params(cfg: ModelConfig, kind: str) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    if kind == "mla":
        qd = hd + cfg.rope_head_dim
        q = d * cfg.n_heads * qd if cfg.q_lora_rank == 0 else \
            d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qd
        kv = d * (cfg.kv_lora_rank + cfg.rope_head_dim) \
            + cfg.kv_lora_rank * cfg.n_heads * (hd + hd)
        o = cfg.n_heads * hd * d
        return q + kv + o
    q = d * cfg.n_heads * hd
    kv = 2 * d * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * d
    return q + kv + o


def _mlp_params(cfg: ModelConfig, width: int) -> int:
    return 3 * cfg.d_model * width          # SwiGLU: gate, up, down


def _moe_params(cfg: ModelConfig, active_only: bool) -> int:
    router = cfg.d_model * cfg.n_experts
    n_routed = cfg.top_k if active_only else cfg.n_experts
    routed = n_routed * _mlp_params(cfg, cfg.expert_ff)
    shared = cfg.n_shared_experts * _mlp_params(cfg, cfg.expert_ff)
    return router + routed + shared


def _ssm_params(cfg: ModelConfig) -> int:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    in_proj = d * (2 * di + 2 * n + h)      # z, x, B, C, dt
    conv = cfg.conv_width * conv_ch + conv_ch
    out_proj = di * d
    extras = 3 * h + di                     # A_log, dt_bias, D skip, norm
    return in_proj + conv + out_proj + extras


def _rglru_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    w = cfg.lru_width or d
    # wx, wy, w_out + conv(4w + w) + block-diag gates 2*(w^2/8) + b_gates 2w
    # + lambda w
    return 3 * d * w + 5 * w + 2 * w * w // 8 + 3 * w


def _block_params(cfg: ModelConfig, kind: str, active_only: bool) -> int:
    norms = 2 * cfg.d_model
    if kind in ("attn", "local_attn"):
        body = _attn_params(cfg, "gqa") + \
            (_mlp_params(cfg, cfg.d_ff) if cfg.d_ff else 0)
        if not cfg.d_ff:
            norms = cfg.d_model
    elif kind == "cross_attn":
        # self-attn + gated cross-attn + mlp, 3 norms + gate scalar
        body = 2 * _attn_params(cfg, "gqa") + _mlp_params(cfg, cfg.d_ff) + 1
        norms = 3 * cfg.d_model
    elif kind == "moe":
        body = _attn_params(cfg, "mla" if cfg.use_mla else "gqa") \
            + _moe_params(cfg, active_only)
    elif kind == "ssm":
        body = _ssm_params(cfg)
        norms = cfg.d_model
    elif kind == "rglru":
        body = _rglru_params(cfg) + \
            (_mlp_params(cfg, cfg.d_ff) if cfg.d_ff else 0)
    else:
        raise ValueError(kind)
    return body + norms


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab * cfg.d_model         # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab * cfg.d_model    # lm head
    total += cfg.d_model                    # final norm
    per_group = sum(_block_params(cfg, k, active_only) for k in cfg.pattern)
    total += cfg.n_groups * per_group
    total += cfg.first_dense * _block_params(cfg, "attn", active_only)
    if cfg.enc_layers:
        # encoder stack (attn blocks) + encoder final norm
        total += cfg.enc_layers * _block_params(cfg, "attn", active_only)
        total += cfg.d_model
    if cfg.mtp_depth > 0:
        total += 2 * cfg.d_model * cfg.d_model          # fusion proj
        total += _block_params(cfg, "attn", active_only)
        total += 2 * cfg.d_model                        # two norms
    return total

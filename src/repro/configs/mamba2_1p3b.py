"""mamba2-1.3b [ssm] — SSD (state-space duality), attn-free.
48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060].
d_inner = 2*d_model = 4096, headdim 64 -> 64 SSD heads.  Sub-quadratic:
runs the long_500k cell."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="block",
)

SMOKE = CONFIG.replace(
    name="mamba2-smoke",
    n_layers=2,
    d_model=64,
    vocab=128,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    dtype="float32",
    param_dtype="float32",
    remat="none",
)

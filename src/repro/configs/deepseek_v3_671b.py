"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
61L d_model=7168 128H d_ff=2048(expert) vocab=129280 [arXiv:2412.19437].
First 3 layers dense; MLA latent KV (kv_lora 512, rope head 64, q_lora
1536); multi-token-prediction head (depth 1)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # assignment: GQA kv=128 — realized via MLA
    d_ff=2048,
    vocab=129280,
    head_dim=128,
    pattern=("moe",),
    first_dense=3,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    d_expert=2048,
    capacity_factor=1.25,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    mtp_depth=1,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="full",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="dsv3-smoke",
    n_layers=3,
    first_dense=1,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=64,
    d_expert=64,
    vocab=128,
    n_experts=8,
    top_k=2,
    kv_lora_rank=32,
    q_lora_rank=0,
    rope_head_dim=16,
    capacity_factor=4.0,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

"""Architecture registry + assigned input shapes (the 10 x 4 = 40 cells).

Shapes (assignment):
    train_4k     seq 4,096   global_batch 256   lowers train_step
    prefill_32k  seq 32,768  global_batch 32    lowers prefill (fwd logits)
    decode_32k   seq 32,768  global_batch 128   lowers serve_step (1 token,
                                                KV cache of seq_len)
    long_500k    seq 524,288 global_batch 1     lowers serve_step; ONLY for
                                                sub-quadratic archs

Skip rules (DESIGN.md §4): long_500k runs for mamba2-1.3b, h2o-danube-1.8b,
recurrentgemma-9b (SSM / SWA / hybrid); skipped for pure full-attention
archs.  Nothing else is skipped.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .base import ModelConfig

_MODULES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "minicpm-2b": "minicpm_2b",
    "deepseek-67b": "deepseek_67b",
    "llama3-405b": "llama3_405b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)

# archs with sub-quadratic sequence mixing (may run long_500k)
SUBQUADRATIC = ("mamba2-1.3b", "h2o-danube-1.8b", "recurrentgemma-9b")


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


def cell_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch x shape) cell."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full attention: 500k KV/decode skipped (DESIGN.md §4)"
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """All 40 (arch, shape, runnable, reason) cells."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, why = cell_applicable(arch, shape)
            out.append((arch, shape, ok, why))
    return out


def memory_len(cfg: ModelConfig, seq_len: int) -> Optional[int]:
    """Stub-frontend memory length for one cell (audio frames / image
    patches); None for text-only archs."""
    if cfg.enc_layers > 0:
        return int(seq_len * cfg.enc_seq_ratio)
    if cfg.n_image_tokens > 0:
        return cfg.n_image_tokens
    return None

"""whisper-tiny [audio] — enc-dec transformer backbone; conv audio frontend
is a STUB (input_specs provides precomputed frame embeddings).
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356].

Shape mapping (DESIGN.md §4): encoder length = seq_len, decoder length =
seq_len (teacher forcing) for train; decode attends cross to the
seq_len-frame encoder output with a self KV cache."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                # decoder layers
    enc_layers=4,              # encoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    pattern=("cross_attn",),
    tie_embeddings=True,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="block",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="whisper-smoke",
    n_layers=2,
    enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=128,
    dtype="float32",
    param_dtype="float32",
    attn_chunk=0,
)

"""deepseek-67b [dense] — llama-arch, deep/narrow.
95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400
[arXiv:2401.02954]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    rope_theta=10000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="full",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="deepseek67b-smoke",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=1,
    d_ff=192,
    vocab=128,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818].
SWA window 4096 (mistral-style) -> sub-quadratic, runs long_500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    window=4096,
    pattern=("local_attn",),
    rope_theta=10000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
    remat="block",
    attn_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="danube-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=160,
    vocab=128,
    window=8,
    dtype="float32",
    param_dtype="float32",
    remat="none",
    attn_chunk=0,
)

from . import checkpoint, serve_step, train_step  # noqa: F401

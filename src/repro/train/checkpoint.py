"""Fault-tolerant sharded checkpointing.

Design (DESIGN.md §5):
  * each host writes its own shard files (.npz per leaf-group) plus a
    manifest with tree structure, shapes, dtypes and content hashes,
  * writes go to a temp dir, fsync'd, then atomically renamed — a crash
    mid-save never corrupts the latest checkpoint,
  * async save: a background thread serializes device arrays snapshotted
    at call time (training continues),
  * ELASTIC restore: the checkpoint stores the GLOBAL logical arrays;
    loading re-shards onto whatever mesh/sharding the new job provides —
    scale 8 -> 4 devices (or 256 -> 512) without conversion tools,
  * resume metadata (step, data seed) for exact deterministic continuation,
  * retention: keep_last N checkpoints garbage-collected.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def path_str(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)

    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[path_str(kp)] = leaf
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(path: str, tree, *, step: int = 0, extra: Optional[Dict] = None,
         keep_last: int = 3) -> str:
    """Synchronous atomic save.  Returns the final checkpoint dir."""
    flat = _flatten(tree)
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=parent)
    manifest = {"step": int(step), "extra": extra or {}, "leaves": {}}
    try:
        arrays = {}
        for name, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            key = hashlib.sha1(name.encode()).hexdigest()[:16]
            # store raw bytes: npz has no bfloat16/fp8; dtype lives in the
            # manifest and is restored via jnp.dtype
            arrays[key] = np.frombuffer(arr.tobytes(), dtype=np.uint8)
            manifest["leaves"][name] = {
                "file": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "hash": hashlib.sha256(arr.tobytes()).hexdigest()[:32],
            }
        np.savez(os.path.join(tmp, "shards.npz"), **arrays)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc_old(path, keep_last)
    return path


def _gc_old(path: str, keep_last: int):
    """Retention for step-suffixed siblings (ckpt_000010 style)."""
    parent = os.path.dirname(os.path.abspath(path))
    base = os.path.basename(path)
    prefix = base.rstrip("0123456789")
    if prefix == base:
        return
    sibs = sorted(d for d in os.listdir(parent)
                  if d.startswith(prefix)
                  and d[len(prefix):].isdigit()
                  and os.path.isdir(os.path.join(parent, d)))
    for d in sibs[:-keep_last]:
        shutil.rmtree(os.path.join(parent, d), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread saver: snapshot on the caller thread (cheap host
    copies), serialize/write off-thread.  wait() joins the in-flight save
    (call before exit or before starting a dependent restore)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, path: str, tree, **kw):
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                tree)

        def work():
            try:
                save(path, snapshot, **kw)
            except BaseException as e:   # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def load_manifest(path: str) -> Dict:
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)


def restore(path: str, like, *, shardings=None, verify: bool = True):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding — ELASTIC: any mesh works, jax.device_put reshards.
    Returns (tree, manifest)."""
    manifest = load_manifest(path)
    data = np.load(os.path.join(path, "shards.npz"))
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    restored = {}
    for name, spec in flat_like.items():
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        raw = data[meta["file"]]
        if verify:
            h = hashlib.sha256(raw.tobytes()).hexdigest()[:32]
            if h != meta["hash"]:
                raise IOError(f"checkpoint corruption in leaf {name!r}")
        stored_dtype = jax.numpy.dtype(meta["dtype"])
        arr = np.frombuffer(raw.tobytes(), dtype=stored_dtype).reshape(
            meta["shape"])
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(
                f"shape mismatch for {name!r}: ckpt {arr.shape} vs "
                f"model {spec.shape}")
        if arr.dtype != jax.numpy.dtype(spec.dtype):
            arr = arr.astype(jax.numpy.dtype(spec.dtype))
        sh = flat_shard.get(name)
        restored[name] = jax.device_put(arr, sh) if sh is not None \
            else jax.numpy.asarray(arr)

    # rebuild tree in `like`'s structure
    leaves_like, tdef = jax.tree_util.tree_flatten(like)
    names = list(_flatten(like).keys())
    ordered = [restored[n] for n in names]
    return jax.tree_util.tree_unflatten(tdef, ordered), manifest


def latest_step_dir(root: str, prefix: str = "ckpt_") -> Optional[str]:
    """Find the newest complete checkpoint under root (crash recovery:
    incomplete temp dirs are invisible because of the atomic rename)."""
    if not os.path.isdir(root):
        return None
    cands = sorted(d for d in os.listdir(root)
                   if d.startswith(prefix)
                   and os.path.exists(os.path.join(root, d, MANIFEST)))
    return os.path.join(root, cands[-1]) if cands else None

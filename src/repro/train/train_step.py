"""Training step: loss -> grads -> (optional int8 error-feedback
compression) -> AdamW, with gradient-accumulation microbatching.

The step is a pure function suitable for jax.jit with in_shardings from
distributed.sharding; XLA inserts the FSDP all-gathers / reduce-scatters
from the param shardings (DESIGN.md §5)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import LanguageModel
from ..optim import adamw_update, error_feedback_update
from ..optim.adamw import adamw_init

# Second-moment floor (optax-style eps_root, inside the sqrt) used by the
# train substrate: sqrt(1e-8) = 1e-4 bounds the first-step update's
# sensitivity to fp32 gradient noise, so grad-accumulated microbatch steps
# match full-batch steps instead of amplifying round-off through Adam's
# sign(g)-like cold-start update.
EPS_ROOT = 1e-8


class TrainState(dict):
    """params / opt / residuals / step as a plain dict pytree."""


def init_state(model: LanguageModel, key, *,
               moment_dtype: Optional[str] = None,
               compress_grads: bool = False) -> Dict:
    """moment_dtype: None/fp32, "bfloat16", or "int8" (block-quantized
    8-bit-Adam moments; optim.quantized_moments)."""
    params = model.init(key)
    if moment_dtype == "int8":
        # shape-preserving layout: moment shardings inherit the weights'
        from ..optim.quantized_moments import q8nd_init
        opt = q8nd_init(params)
    else:
        opt = adamw_init(params, moment_dtype=moment_dtype)
    state = {"params": params, "opt": opt}
    if compress_grads:
        state["residuals"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def make_train_step(model: LanguageModel, *, lr, microbatches: int = 1,
                    compress_grads: bool = False,
                    weight_decay: float = 0.1,
                    max_grad_norm: float = 1.0,
                    accum_dtype: str = "float32",
                    q8_moments: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    accum_dtype: gradient-accumulation buffer dtype (bf16 halves the
    accumulator HBM for the >=100B configs; DESIGN.md §5).
    q8_moments: block-quantized int8 Adam moments (state must come from
    init_state(moment_dtype="int8"))."""

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def split_micro(batch):
        def sp(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        return jax.tree.map(sp, batch)

    def train_step(state, batch):
        params = state["params"]
        if microbatches > 1:
            micro = split_micro(batch)

            adt = jnp.dtype(accum_dtype)

            def body(carry, mb):
                gsum, lsum, nsum = carry
                loss, _, grads = grads_of(params, mb)
                # weight each microbatch by its valid-token count: the model
                # loss is a mean over valid (label >= 0) tokens, so an
                # unweighted mean-of-means diverges from the full-batch
                # gradient whenever microbatches carry unequal valid counts.
                if "labels" in mb:
                    n = jnp.maximum(
                        jnp.sum(mb["labels"] >= 0), 1).astype(adt)
                else:
                    n = jnp.ones((), adt)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(adt) * n, gsum, grads)
                return (gsum, lsum + loss * n, nsum + n), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum, nsum), _ = jax.lax.scan(
                body, (gzero, jnp.zeros((), adt), jnp.zeros((), adt)), micro)
            grads = jax.tree.map(lambda g: g / nsum, gsum)
            loss = lsum / nsum
            metrics = {"xent": loss, "aux": jnp.zeros(())}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compress_grads:
            grads, new_res = error_feedback_update(grads,
                                                   state["residuals"])
        if q8_moments:
            from ..optim.quantized_moments import q8nd_adamw_update
            new_params, new_opt, opt_metrics = q8nd_adamw_update(
                params, grads, state["opt"], lr=lr,
                weight_decay=weight_decay, max_grad_norm=max_grad_norm)
        else:
            new_params, new_opt, opt_metrics = adamw_update(
                params, grads, state["opt"], lr=lr, eps_root=EPS_ROOT,
                weight_decay=weight_decay, max_grad_norm=max_grad_norm)
        new_state = dict(state, params=new_params, opt=new_opt)
        if compress_grads:
            new_state["residuals"] = new_res
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_state, metrics

    return train_step


def make_eval_step(model: LanguageModel) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return dict(metrics, loss=loss)
    return eval_step

"""Serving: prefill + batched one-token decode steps (the functions the
decode_32k / long_500k dry-run cells lower), plus a simple batched
request loop for the serving example."""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import LanguageModel


def make_prefill(model: LanguageModel) -> Callable:
    """prefill(params, tokens[, memory_embeds]) -> last-token logits.

    Lowered for the prefill_* cells: the dominant prefill compute is the
    full forward; per-layer cache population adds stores the roofline
    memory term already covers (DESIGN.md §4)."""

    def prefill(params, tokens, memory_embeds=None):
        logits, _ = model.forward(params, tokens,
                                  memory_embeds=memory_embeds)
        return logits[:, -1, :]

    return prefill


def make_serve_step(model: LanguageModel) -> Callable:
    """serve_step(params, cache, tokens (B,1), pos) -> (logits, cache).
    One new token against a KV cache of seq_len (decode cells)."""

    def serve_step(params, cache, tokens, pos, memory_embeds=None):
        return model.decode_step(params, cache, tokens, pos,
                                 memory_embeds=memory_embeds)

    return serve_step


def greedy_generate(model: LanguageModel, params, prompt, *, max_new: int,
                    max_len: Optional[int] = None, memory_embeds=None):
    """Batched greedy decoding driver (example/serving path)."""
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    cache = model.init_cache(b, max_len)
    # prefill fills the cache through position s-1 and returns the
    # last-token logits
    logits, cache = model.prefill(params, prompt, cache,
                                  memory_embeds=memory_embeds)
    step = jax.jit(model.decode_step)

    toks = []
    for i in range(max_new):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        toks.append(nxt)
        if i + 1 < max_new:
            logits, cache = step(params, cache, nxt, jnp.int32(s + i),
                                 memory_embeds=memory_embeds)
    return jnp.concatenate(toks, axis=1)

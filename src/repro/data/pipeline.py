"""Synthetic sharded data pipeline.

Deterministic, seekable token stream (Zipf-ish unigram + short-range
structure so tiny models can actually learn), with:
  * per-step deterministic batches (resume = skip to step, no state files),
  * host prefetch thread (double-buffering),
  * stub modality frontends (frame/patch embeddings) for audio/vlm archs,
  * global-batch sharding helpers for the (pod, data, model) mesh.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..configs.registry import memory_len


class SyntheticLMData:
    """Deterministic synthetic LM batches: batch(step) is a pure function
    of (seed, step), which makes checkpoint-resume trivial and exact."""

    def __init__(self, cfg: ModelConfig, *, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        # Zipf-ish unigram over a capped alphabet (keeps tiny models
        # learnable); structure: next token correlates with current.
        self.alphabet = min(cfg.vocab, 4096)
        ranks = np.arange(1, self.alphabet + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s = self.batch, self.seq_len
        toks = rng.choice(self.alphabet, size=(b, s + 1), p=self.unigram)
        # short-range structure: with p=0.5, t+1 = (t + 1) mod alphabet
        copy_mask = rng.random((b, s)) < 0.5
        nxt = (toks[:, :-1] + 1) % self.alphabet
        toks[:, 1:] = np.where(copy_mask, nxt, toks[:, 1:])
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        mlen = memory_len(self.cfg, s)
        if mlen is not None:
            out["memory_embeds"] = rng.standard_normal(
                (b, mlen, self.cfg.d_model)).astype(np.float32)
        return out

    def iter_batches(self, start_step: int = 0,
                     prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator (host thread double-buffers)."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_batch_specs(cfg: ModelConfig, *, batch: int, seq_len: int,
                     dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run use)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), dtype),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), dtype),
    }
    mlen = memory_len(cfg, seq_len)
    if mlen is not None:
        specs["memory_embeds"] = jax.ShapeDtypeStruct(
            (batch, mlen, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs

"""Static contract linter for the repo's standing invariants.

``python -m benchmarks.check_contracts`` is the gate; tier-1 runs
:func:`run_checks` on the checkout itself (``tests/test_contracts.py``).
See ``README.md`` in this package for the rule list and the suppression
syntax.
"""
from .core import (  # noqa: F401
    ERROR,
    WARNING,
    DEFAULT_PATHS,
    Finding,
    Module,
    Project,
    Report,
    Rule,
    RULES,
    register,
    repo_root,
    run_checks,
)

__all__ = [
    "ERROR", "WARNING", "DEFAULT_PATHS", "Finding", "Module", "Project",
    "Report", "Rule", "RULES", "register", "repo_root", "run_checks",
]

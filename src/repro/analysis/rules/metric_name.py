"""METRIC-NAME: metric families and labels come from closed sets.

The exposition contract (serve/README.md, enforced at runtime by
``tests/test_obs.py``): every family matches
``repro_{serve,client,sweep,pool}_*``, label keys come from a small
closed vocabulary, and the family inventory is append-only —
dashboards and scrapers bind to these names, so a silent rename is a
breaking API change that no unit test of the renamed code will catch.

Per file, the rule checks every ``counter(...)`` / ``gauge(...)`` /
``histogram(...)`` call:

* the family (first argument) must be a **string literal** — a computed
  name cannot be checked against the contract, and the registry's
  append-only test can't see it either;
* the literal must match ``repro_(serve|client|sweep|pool)_[a-z0-9_]+``;
* every label kwarg key must be in the closed label vocabulary, and a
  *literal* label value must be in that key's closed value set.

Cross-file (``finalize``): the set of literal families registered in
``src/repro`` is reconciled with ``EXPECTED_FAMILIES`` in
``tests/test_obs.py`` in both directions — a new family missing from
the list fails (append it), and a listed family with no remaining call
site fails (exposition is append-only; restore it).

``repro/obs/metrics.py`` itself is exempt: its module-level
``counter(name, ...)`` wrappers forward caller-supplied names.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Tuple

from ..astutil import attr_chain, const_value
from ..core import Finding, Module, Project, Rule, register

FAMILY_RE = re.compile(r"^repro_(serve|client|sweep|pool)_[a-z0-9_]+$")

#: closed label vocabulary: key -> allowed literal values
LABEL_VALUES: Dict[str, frozenset] = {
    "transport": frozenset({"http", "binary"}),
    "stage": frozenset({"parse", "queue_wait", "fuse", "evaluate",
                        "encode", "write"}),
    "reason": frozenset({"overload", "deadline"}),
    "cache": frozenset({"hit", "miss"}),
}

#: kwargs of the registration helpers that are not labels
_NON_LABEL_KWARGS = {"help", "buckets"}

_REGISTER_NAMES = {"counter", "gauge", "histogram"}

EXEMPT_PATHS = ("repro/obs/metrics.py",)

CONTRACT_TEST_REL = "tests/test_obs.py"


@register
class MetricNameRule(Rule):
    id = "METRIC-NAME"
    hint = ("metric families follow repro_{serve,client,sweep,pool}_* "
            "with label keys from the closed vocabulary "
            "(transport/stage/reason/cache); the family inventory is "
            "append-only — see tests/test_obs.py EXPECTED_FAMILIES")

    def __init__(self):
        #: family -> first registration site, for the finalize check
        self.declared: Dict[str, Tuple[str, int]] = {}

    def visit(self, module: Module) -> Iterable[Finding]:
        if any(e in module.rel for e in EXEMPT_PATHS):
            return ()
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if not chain or chain[-1] not in _REGISTER_NAMES:
                continue
            self._check_register(module, node, out)
        return out

    def _check_register(self, module: Module, call: ast.Call,
                        out: List[Finding]) -> None:
        if not call.args:
            return
        name_arg = call.args[0]
        if isinstance(name_arg, ast.Constant) \
                and isinstance(name_arg.value, str):
            family = name_arg.value
            if not FAMILY_RE.match(family):
                out.append(self.finding(
                    module.rel, call.lineno,
                    f"metric family {family!r} is outside the "
                    f"repro_{{serve,client,sweep,pool}}_* namespace"))
            else:
                self.declared.setdefault(
                    family, (module.rel, call.lineno))
        else:
            out.append(self.finding(
                module.rel, call.lineno,
                "metric family name is not a string literal — a computed "
                "name cannot be checked against the exposition contract"))
        for kw in call.keywords:
            if kw.arg is None or kw.arg in _NON_LABEL_KWARGS:
                continue
            if kw.arg not in LABEL_VALUES:
                out.append(self.finding(
                    module.rel, call.lineno,
                    f"label key {kw.arg!r} is outside the closed label "
                    f"vocabulary {sorted(LABEL_VALUES)}"))
            elif isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str) \
                    and kw.value.value not in LABEL_VALUES[kw.arg]:
                out.append(self.finding(
                    module.rel, call.lineno,
                    f"label {kw.arg}={kw.value.value!r} is outside the "
                    f"closed value set "
                    f"{sorted(LABEL_VALUES[kw.arg])}"))

    # -- cross-file: reconcile with the append-only contract list ----------
    def finalize(self, project: Project) -> Iterable[Finding]:
        tree = project.tree(CONTRACT_TEST_REL)
        if tree is None:
            return [self.finding(
                CONTRACT_TEST_REL, 1,
                "metric contract test is missing — EXPECTED_FAMILIES is "
                "the append-only family inventory", severity="warning")]
        expected: Dict[str, int] = {}
        list_line = 1
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "EXPECTED_FAMILIES"
                    for t in node.targets):
                list_line = node.lineno
                try:
                    for elt, value in zip(node.value.elts,
                                          const_value(node.value)):
                        expected[value] = elt.lineno
                except (ValueError, AttributeError):
                    pass
                break
        out: List[Finding] = []
        for family, (rel, line) in sorted(self.declared.items()):
            if family not in expected:
                out.append(self.finding(
                    rel, line,
                    f"metric family {family!r} is not in "
                    f"{CONTRACT_TEST_REL} EXPECTED_FAMILIES — append it "
                    f"(the inventory is append-only)"))
        for family, line in sorted(expected.items()):
            if family not in self.declared:
                out.append(self.finding(
                    CONTRACT_TEST_REL, line or list_line,
                    f"contract family {family!r} has no registration "
                    f"site left in src/repro — exposition is "
                    f"append-only; restore the family"))
        return out

"""WIRE-DRIFT: the binary wire format only changes additively.

Persisted tables and live clients both speak the ``RPRW`` codec and the
``RPB1`` frame; the v1->v2 transition (checksum section) set the
precedent — old payloads must keep decoding, so the format evolves by
*adding* message types / section tags / ops, never by renumbering,
removing, or repacking.

The rule statically extracts the wire surface from ``serve/codec.py``
and ``serve/framing.py`` — magic tags, ``WIRE_VERSION``, the ``MSG_*``
table, header/section struct formats, the 4-byte section-tag universe,
``REQUEST_OPS``/``CALIBRATE_MODES``, ``OP_*``/``FLAG_*`` and frame
limits — and diffs it against the committed
``src/repro/analysis/wire_schema.lock.json``:

* **breaking** drift (changed/removed constant, repacked struct) fails
  with a "bump the version" message: bump ``WIRE_VERSION``, keep the old
  decode path, then refresh the lock;
* **additive** drift (new message type, new tag) also fails — the lock
  must move with the code — but the fix is just
  ``python -m benchmarks.check_contracts --update-wire-lock`` plus a
  review of the new surface.

Both directions gate, so the committed lock is always the reviewed
source of truth for what's on the wire.
"""
from __future__ import annotations

import ast
import json
import os
from typing import Dict, Iterable, List, Optional, Tuple

from ..astutil import const_value, iter_module_scope
from ..core import Finding, Project, Rule, register

LOCK_REL = "src/repro/analysis/wire_schema.lock.json"
CODEC_REL = "src/repro/serve/codec.py"
FRAMING_REL = "src/repro/serve/framing.py"

#: lock sections whose *sets* may grow but never shrink or change
_ADDITIVE_MAPS = ("messages", "ops", "flags")
_ADDITIVE_LISTS = ("section_tags", "request_ops", "calibrate_modes")


def _assign_name(stmt: ast.stmt) -> Optional[str]:
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
            and isinstance(stmt.targets[0], ast.Name):
        return stmt.targets[0].id
    return None


def _struct_format(stmt: ast.Assign) -> Optional[str]:
    v = stmt.value
    if isinstance(v, ast.Call) and v.args \
            and isinstance(v.args[0], ast.Constant) \
            and isinstance(v.args[0].value, str):
        return v.args[0].value
    return None


def _bytes_str(value: bytes) -> str:
    return value.decode("latin-1")


def _try_const(node: ast.AST):
    """const_value or None — derived module constants (lookup dicts like
    framing.OP_NAMES) are not wire surface and are skipped."""
    try:
        return const_value(node)
    except ValueError:
        return None


def extract_schema(project: Project) -> Tuple[Dict, Dict[str, Tuple[str, int]]]:
    """(schema, locations): the wire surface as a JSON-able dict plus a
    ``dotted.key -> (rel, line)`` map for pointed findings."""
    schema: Dict = {"codec": {}, "framing": {}}
    where: Dict[str, Tuple[str, int]] = {}

    codec = project.tree(CODEC_REL)
    if codec is not None:
        c = schema["codec"]
        c["messages"] = {}
        for stmt in iter_module_scope(codec):
            name = _assign_name(stmt)
            if name is None:
                continue
            loc = (CODEC_REL, stmt.lineno)
            if name == "MAGIC":
                c["magic"] = _bytes_str(const_value(stmt.value))
                where["codec.magic"] = loc
            elif name == "WIRE_VERSION":
                c["wire_version"] = const_value(stmt.value)
                where["codec.wire_version"] = loc
            elif name.startswith("MSG_"):
                value = _try_const(stmt.value)
                if value is not None:
                    c["messages"][name] = value
                    where[f"codec.messages.{name}"] = loc
            elif name == "_MAX_SECTIONS":
                c["max_sections"] = const_value(stmt.value)
                where["codec.max_sections"] = loc
            elif name in ("REQUEST_OPS", "CALIBRATE_MODES"):
                key = name.lower()
                c[key] = list(const_value(stmt.value))
                where[f"codec.{key}"] = loc
            elif name == "_HEADER":
                fmt = _struct_format(stmt)
                if fmt:
                    c["header_format"] = fmt
                    where["codec.header_format"] = loc
            elif name == "_SECTION":
                fmt = _struct_format(stmt)
                if fmt:
                    c["section_format"] = fmt
                    where["codec.section_format"] = loc
        # the section-tag universe: every 4-byte bytes literal in the
        # codec except the magic itself (tags are used inline at the
        # _pack call sites, not declared as named constants)
        magic = c.get("magic", "").encode("latin-1")
        tags = {n.value for n in ast.walk(codec)
                if isinstance(n, ast.Constant)
                and isinstance(n.value, bytes)
                and len(n.value) == 4 and n.value != magic}
        c["section_tags"] = sorted(_bytes_str(t) for t in tags)
        where["codec.section_tags"] = (CODEC_REL, 1)

    framing = project.tree(FRAMING_REL)
    if framing is not None:
        f = schema["framing"]
        f["ops"] = {}
        f["flags"] = {}
        for stmt in iter_module_scope(framing):
            name = _assign_name(stmt)
            if name is None:
                continue
            loc = (FRAMING_REL, stmt.lineno)
            if name == "BIN_MAGIC":
                f["magic"] = _bytes_str(const_value(stmt.value))
                where["framing.magic"] = loc
            elif name == "MAX_FRAME_BYTES":
                f["max_frame_bytes"] = const_value(stmt.value)
                where["framing.max_frame_bytes"] = loc
            elif name == "HEADER":
                fmt = _struct_format(stmt)
                if fmt:
                    f["header_format"] = fmt
                    where["framing.header_format"] = loc
            elif name.startswith("OP_"):
                value = _try_const(stmt.value)
                if value is not None:
                    f["ops"][name] = value
                    where[f"framing.ops.{name}"] = loc
            elif name.startswith("FLAG_"):
                value = _try_const(stmt.value)
                if value is not None:
                    f["flags"][name] = value
                    where[f"framing.flags.{name}"] = loc
    return schema, where


def write_lock(project_root: str, schema: Dict) -> str:
    path = os.path.join(project_root, LOCK_REL)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(schema, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


_BREAKING_HINT = ("this is a breaking wire change — old payloads stop "
                  "decoding; bump WIRE_VERSION, keep the old decode path "
                  "(the v1->v2 checksum precedent), then refresh the lock "
                  "with --update-wire-lock")
_ADDITIVE_HINT = ("new wire surface — review it, then refresh the lock: "
                  "python -m benchmarks.check_contracts --update-wire-lock")


@register
class WireDriftRule(Rule):
    id = "WIRE-DRIFT"
    hint = _BREAKING_HINT

    def finalize(self, project: Project) -> Iterable[Finding]:
        schema, where = extract_schema(project)
        raw = project.read(LOCK_REL)
        if raw is None:
            return [self.finding(
                LOCK_REL, 1,
                "wire schema lock is missing — the wire surface has no "
                "reviewed source of truth", hint=_ADDITIVE_HINT)]
        try:
            lock = json.loads(raw)
        except ValueError as e:
            return [self.finding(
                LOCK_REL, 1, f"wire schema lock is not valid JSON: {e}",
                hint=_ADDITIVE_HINT)]
        out: List[Finding] = []
        for side in ("codec", "framing"):
            self._diff_side(side, schema.get(side, {}),
                            lock.get(side, {}), where, out)
        return out

    # -- diffing -----------------------------------------------------------
    def _loc(self, where: Dict, key: str, side: str) -> Tuple[str, int]:
        default = CODEC_REL if side == "codec" else FRAMING_REL
        return where.get(key, (default, 1))

    def _diff_side(self, side: str, cur: Dict, locked: Dict,
                   where: Dict, out: List[Finding]) -> None:
        for key in sorted(set(cur) | set(locked)):
            path = f"{side}.{key}"
            rel, line = self._loc(where, path, side)
            if key in _ADDITIVE_MAPS:
                self._diff_map(side, key, cur.get(key, {}),
                               locked.get(key, {}), where, out)
            elif key in _ADDITIVE_LISTS:
                self._diff_list(path, rel, line, cur.get(key, []),
                                locked.get(key, []), out)
            elif key not in locked:
                out.append(self.finding(
                    rel, line,
                    f"wire constant {path} = {cur[key]!r} is not in the "
                    f"committed lock", hint=_ADDITIVE_HINT))
            elif key not in cur:
                out.append(self.finding(
                    rel, line,
                    f"wire constant {path} (locked {locked[key]!r}) no "
                    f"longer exists in the source"))
            elif cur[key] != locked[key]:
                out.append(self.finding(
                    rel, line,
                    f"wire constant {path} changed: locked "
                    f"{locked[key]!r} -> source {cur[key]!r}"))

    def _diff_map(self, side: str, key: str, cur: Dict, locked: Dict,
                  where: Dict, out: List[Finding]) -> None:
        for name in sorted(set(cur) | set(locked)):
            path = f"{side}.{key}.{name}"
            rel, line = self._loc(where, path, side)
            if name not in locked:
                out.append(self.finding(
                    rel, line,
                    f"new wire constant {path} = {cur[name]!r} is not in "
                    f"the committed lock", hint=_ADDITIVE_HINT))
            elif name not in cur:
                out.append(self.finding(
                    rel, line,
                    f"wire constant {path} (locked {locked[name]!r}) was "
                    f"removed — decoders in the field still send it"))
            elif cur[name] != locked[name]:
                out.append(self.finding(
                    rel, line,
                    f"wire constant {path} was renumbered: locked "
                    f"{locked[name]!r} -> source {cur[name]!r}"))

    def _diff_list(self, path: str, rel: str, line: int,
                   cur: List, locked: List, out: List[Finding]) -> None:
        added = sorted(set(cur) - set(locked))
        removed = sorted(set(locked) - set(cur))
        if added:
            out.append(self.finding(
                rel, line,
                f"new entries in {path} not in the committed lock: "
                f"{added}", hint=_ADDITIVE_HINT))
        if removed:
            out.append(self.finding(
                rel, line,
                f"entries removed from {path}: {removed} — old payloads "
                f"referencing them stop decoding"))

"""The standing-contract rules.  Importing this package registers every
rule in :data:`repro.analysis.core.RULES`; ``run_checks`` does so
lazily.  To add a rule, create a module here and import it below."""
from . import (  # noqa: F401
    fork_lock,
    frozen_mut,
    loop_block,
    metric_name,
    sweep_loop,
    wire_drift,
)

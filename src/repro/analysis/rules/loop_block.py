"""LOOP-BLOCK: the binserver event loop must never block.

``serve/binserver.py`` runs ONE ``selectors`` thread for every binary
connection: accept, read, parse, write — nothing else.  A single
blocking call reachable from that thread stalls every pipelined client
at once (the transport's ~190x win over HTTP exists precisely because
nothing on the loop waits).  Real evaluation belongs in the coalescer
(``submit_async``) or the slow pool.

The rule builds an intra-module call graph from the configured entry
points (``_loop``) — ``self.method()`` and module-function edges — and
flags blocking primitives in any reachable function:

* ``time.sleep``, ``open()``, ``os.system``, ``subprocess.*``,
  ``socket.create_connection``;
* ``.sendall()`` / ``.makefile()`` (the loop buffers and uses
  nonblocking ``send``);
* ``.acquire()`` / ``.join()`` / ``.result()`` / ``.wait()`` without a
  timeout, and zero-argument ``.get()`` (queue-style indefinite wait).

Functions merely *defined* inside reachable code (completion callbacks
like ``on_done``) run on other threads and are not scanned.
``with lock:`` is deliberately allowed: bounded critical sections are
the stats-snapshot pattern; an *indefinite* ``acquire()`` is not.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Tuple

from ..astutil import call_name
from ..core import Finding, Module, Rule, register

#: event-loop modules (relative-path substring) -> entry-point function
#: names whose transitive intra-module callees must not block
EVENT_LOOP_FILES: Dict[str, Tuple[str, ...]] = {
    "repro/serve/binserver.py": ("_loop",),
}

#: (qualifier, name) calls that always block
_BLOCKING_CALLS = {
    ("time", "sleep"), ("os", "system"),
    ("socket", "create_connection"),
}
_BLOCKING_BARE = {"open", "input"}
_BLOCKING_QUALIFIER_PREFIX = ("subprocess",)
#: method names that block regardless of arguments
_BLOCKING_METHODS = {"sendall", "makefile"}
#: method names that block indefinitely unless given a timeout
_TIMEOUT_METHODS = {"acquire", "join", "result", "wait"}


def _has_timeout(call: ast.Call) -> bool:
    if call.args:                    # positional timeout (acquire/join)
        return True
    return any(kw.arg in ("timeout", "blocking", "block")
               for kw in call.keywords)


class _FnScanner(ast.NodeVisitor):
    """Calls made by one function body, not descending into nested
    function/lambda definitions (those run on other threads)."""

    def __init__(self):
        self.calls: List[ast.Call] = []

    def visit_FunctionDef(self, node):     # nested defs: skip bodies
        pass

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        pass

    def visit_Call(self, node: ast.Call):
        self.calls.append(node)
        self.generic_visit(node)


def _function_calls(fn: ast.AST) -> List[ast.Call]:
    scanner = _FnScanner()
    for stmt in fn.body:
        scanner.visit(stmt)
    return scanner.calls


@register
class LoopBlockRule(Rule):
    id = "LOOP-BLOCK"
    hint = ("the event loop must never wait: dispatch through the "
            "coalescer's submit_async or the slow pool, use nonblocking "
            "socket ops, or bound the call with a timeout")

    def visit(self, module: Module) -> Iterable[Finding]:
        entries = next(
            (names for sub, names in EVENT_LOOP_FILES.items()
             if sub in module.rel), None)
        if entries is None:
            return ()

        # name -> defs (methods of any class + module functions; an
        # intra-module approximation — self.x() resolves by method name)
        defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)

        # BFS over call edges, remembering one path for the report
        via: Dict[str, Tuple[str, ...]] = {}
        queue: List[str] = []
        for name in entries:
            if name in defs:
                via[name] = (name,)
                queue.append(name)
        out: List[Finding] = []
        while queue:
            name = queue.pop(0)
            for fn in defs[name]:
                for call in _function_calls(fn):
                    qual, callee = call_name(call)
                    self._check_blocking(module, call, qual, callee,
                                         via[name], out)
                    if callee in defs and callee not in via \
                            and (qual is None or qual == "self"):
                        via[callee] = via[name] + (callee,)
                        queue.append(callee)
        return out

    def _check_blocking(self, module: Module, call: ast.Call,
                        qual: Optional[str], name: str,
                        path: Tuple[str, ...],
                        out: List[Finding]) -> None:
        route = " -> ".join(path)
        blocked = None
        if (qual, name) in _BLOCKING_CALLS \
                or (qual is None and name in _BLOCKING_BARE) \
                or (qual or "").startswith(_BLOCKING_QUALIFIER_PREFIX):
            blocked = f"{qual + '.' if qual else ''}{name}()"
        elif qual is not None and name in _BLOCKING_METHODS:
            blocked = f".{name}() (use nonblocking send + output buffer)"
        elif qual is not None and name in _TIMEOUT_METHODS \
                and not _has_timeout(call):
            blocked = f".{name}() without a timeout"
        elif qual is not None and name == "get" and not call.args \
                and not call.keywords:
            blocked = ".get() with no arguments (indefinite queue wait)"
        if blocked:
            out.append(self.finding(
                module.rel, call.lineno,
                f"blocking call {blocked} reachable from the event loop "
                f"(via {route})"))

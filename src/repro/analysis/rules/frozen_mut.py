"""FROZEN-MUT: WorkloadTable columns and wire buffers stay frozen.

The engine's memo cache keys on ``content_token()`` computed from a
table's column bytes; zero-copy wire decode hands out read-only NumPy
views over the receive buffer.  Any in-place mutation of
``table.cols`` / ``table.precision_codes`` / ``table.wclass_codes`` —
or un-freezing a buffer with ``setflags(write=True)`` /
``.flags.writeable = True`` — can serve a *stale cached answer for
different data*, the exact bug PR 5's review rounds chased (writable
receive buffers staling the memo cache).

Flagged shapes:

* ``x.cols[...] = v`` / ``x.cols[...] += v`` — item store or augmented
  assign through a frozen column attribute (any depth of chaining);
* ``x.cols += v`` — augmented assign rebinding through the attribute;
* ``<chain containing .cols>.flags.writeable = True`` — un-freezing;
* ``anything.setflags(write=True)`` — un-freezing any array (wire
  decode views included), frozen attribute or not;
* ``x.cols.resize(...)`` — in-place reshape of a frozen column.

Freezing (``writeable = False``) and writes to *local* arrays still
being built (bare ``cols[...] = ...`` before the table is constructed)
are fine and not flagged.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import attr_chain
from ..core import Finding, Module, Rule, register

FROZEN_ATTRS = frozenset({"cols", "precision_codes", "wclass_codes"})


def _through_frozen(node: ast.AST) -> bool:
    """True when the expression dereferences one of the frozen column
    *attributes* (``table.cols``...), as opposed to a bare local name."""
    chain = attr_chain(node)
    return any(a in FROZEN_ATTRS for a in chain[1:])


@register
class FrozenMutRule(Rule):
    id = "FROZEN-MUT"
    hint = ("WorkloadTable columns are frozen — the memo cache keys on "
            "their content; build a new table (take/concat/from_workloads)"
            " instead of mutating, and never un-freeze a wire-decoded "
            "buffer")

    def visit(self, module: Module) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign):
                for target in node.targets:
                    self._check_store(module, node, target, out)
            elif isinstance(node, ast.AugAssign):
                self._check_store(module, node, node.target, out,
                                  augmented=True)
            elif isinstance(node, ast.Call):
                self._check_call(module, node, out)
        return out

    def _check_store(self, module: Module, stmt: ast.stmt,
                     target: ast.AST, out: List[Finding],
                     augmented: bool = False) -> None:
        if isinstance(target, ast.Subscript) \
                and _through_frozen(target.value):
            what = "augmented assign into" if augmented else "store into"
            out.append(self.finding(
                module.rel, stmt.lineno,
                f"in-place {what} a frozen WorkloadTable column "
                f"({'.'.join(attr_chain(target.value)[-2:])}[...])"))
        elif isinstance(target, ast.Attribute) \
                and target.attr == "writeable" \
                and _through_frozen(target) \
                and isinstance(stmt, ast.Assign) \
                and isinstance(stmt.value, ast.Constant) \
                and stmt.value.value is True:
            out.append(self.finding(
                module.rel, stmt.lineno,
                "un-freezing a WorkloadTable column "
                "(.flags.writeable = True)"))
        elif isinstance(target, ast.Attribute) \
                and target.attr in FROZEN_ATTRS and not augmented \
                and attr_chain(target)[:1] != ["self"]:
            # rebinding table.cols = ... wholesale replaces the frozen
            # array behind a possibly-interned content token (self.cols
            # assignments are constructors initializing their own table)
            out.append(self.finding(
                module.rel, stmt.lineno,
                f"rebinding .{target.attr} on a live table — the cached "
                f"content token no longer matches the data"))

    def _check_call(self, module: Module, call: ast.Call,
                    out: List[Finding]) -> None:
        if not isinstance(call.func, ast.Attribute):
            return
        attr = call.func.attr
        if attr == "setflags":
            write_true = any(
                kw.arg == "write" and isinstance(kw.value, ast.Constant)
                and kw.value.value for kw in call.keywords)
            if write_true:
                out.append(self.finding(
                    module.rel, call.lineno,
                    "setflags(write=True) un-freezes a buffer — decoded "
                    "wire views and table columns must stay read-only"))
        elif attr == "resize" and _through_frozen(call.func.value):
            out.append(self.finding(
                module.rel, call.lineno,
                "in-place resize of a frozen WorkloadTable column"))

"""FORK-LOCK: module-lifetime locks need an at-fork re-init hook.

``fork()`` clones exactly one thread.  A ``threading.Lock`` held by any
*other* thread at fork time is copied in the locked state and nobody in
the child will ever release it — the child deadlocks on first use.
The repo forks deliberately (``core/parallel.py`` worker pools prefer
fork for COW) so every lock that lives as long as the module must be
re-initialized in the child: ``os.register_at_fork(after_in_child=...)``
(the pattern ``core/sweep.py`` / ``obs/metrics.py`` / ``obs/trace.py``
established).

Flagged shapes, in any module without its own ``register_at_fork``
call:

* a module-scope ``threading.Lock()`` / ``RLock()`` assignment;
* a module-scope *singleton* of a class whose methods stash a lock on
  ``self`` (``REGISTRY = _LazyRegistry()`` with ``self._lock =
  threading.RLock()`` in ``__init__`` — the lock's lifetime is the
  module's even though the call site is a method).

Instance locks on short-lived objects (per-connection, per-pool) are
NOT flagged: ``core/parallel.py`` holds locks only on ``WorkerPool``
instances and refuses the fork start method outright once any helper
thread is alive (``_mp_context``/``allow_fork=False``), so its
fork-safety hook legitimately lives with the engine caches in
``core/sweep.py`` — audited for ISSUE 10, no module-lifetime lock
there.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from ..astutil import attr_chain, iter_module_scope
from ..core import Finding, Module, Rule, register

_LOCK_NAMES = {"Lock", "RLock"}


def _is_lock_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = attr_chain(node.func)
    return bool(chain) and chain[-1] in _LOCK_NAMES


def _has_fork_hook(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            if chain and chain[-1] == "register_at_fork":
                return True
    return False


def _lock_holding_classes(tree: ast.AST) -> Set[str]:
    """Class names whose methods assign a lock onto ``self``."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign) and _is_lock_call(sub.value) \
                    and any(isinstance(t, ast.Attribute)
                            and attr_chain(t)[:1] == ["self"]
                            for t in sub.targets):
                out.add(node.name)
                break
    return out


@register
class ForkLockRule(Rule):
    id = "FORK-LOCK"
    hint = ("re-initialize the lock in a fork hook: os.register_at_fork("
            "after_in_child=lambda: ...) in this module, mirroring "
            "core/sweep.py / obs/metrics.py")

    def visit(self, module: Module) -> Iterable[Finding]:
        tree = module.tree
        if _has_fork_hook(tree):
            return ()
        out: List[Finding] = []
        singletons = _lock_holding_classes(tree)
        for stmt in iter_module_scope(tree):
            if isinstance(stmt, ast.AnnAssign):        # X: T = Lock()
                if stmt.value is None:
                    continue
            elif not isinstance(stmt, ast.Assign):
                continue
            if _is_lock_call(stmt.value):
                out.append(self.finding(
                    module.rel, stmt.lineno,
                    "module-level threading lock in a module without an "
                    "os.register_at_fork re-init hook — a forked child "
                    "can inherit it locked"))
            elif isinstance(stmt.value, ast.Call):
                chain = attr_chain(stmt.value.func)
                if chain and chain[-1] in singletons:
                    out.append(self.finding(
                        module.rel, stmt.lineno,
                        f"module-scope singleton of lock-holding class "
                        f"{chain[-1]} in a module without an "
                        f"os.register_at_fork re-init hook — its lock "
                        f"lives as long as the module"))
        return out

"""SWEEP-LOOP: sweeps are WorkloadTables, never per-config loops.

The sweep-construction contract (ROADMAP "Standing contracts"): a sweep
is a ``WorkloadTable`` (or a lazy ``LatticeSpec``) priced through the
columnar ``predict_table``/``argmin_table``/``*_stream`` routes.
Constructing one ``Workload`` per configuration — or calling the scalar
``predict()`` once per configuration — inside a loop or comprehension
rebuilds the 21.6k-cfg/s scalar path the columnar engine replaced
(~1.4M cfg/s cold, PR 2) and bypasses the memo cache's content tokens.

Allow-listed files may loop: the suite inventories
(``core/suites/``) and the host microbenchmark harness
(``core/microbench.py``) build a handful of *named* kernels for
measurement — those are characterization lists, not sweeps.  Everything
else needs an inline justification, e.g. the CDNA3 scalar-fallback rows
(hit-rate / Eq. 10 walks) that are priced per row by design.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import attr_chain
from ..core import Finding, Module, Rule, register

#: files whose per-config loops are characterization inventories, not
#: sweeps (relative-path substrings)
ALLOWED_PATHS = (
    "repro/core/suites/",
    "repro/core/microbench.py",
)

_LOOPS = (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
          ast.DictComp, ast.GeneratorExp)


@register
class SweepLoopRule(Rule):
    id = "SWEEP-LOOP"
    hint = ("build the whole sweep as a WorkloadTable (tile_lattice / "
            "cartesian / from_workloads) or a LatticeSpec and price it "
            "via predict_table / argmin_table / *_stream; scalar "
            "predict() is for one-off questions only")

    def visit(self, module: Module) -> Iterable[Finding]:
        if any(a in module.rel for a in ALLOWED_PATHS):
            return ()
        out: List[Finding] = []
        self._scan(module, module.tree, 0, out)
        return out

    def _scan(self, module: Module, node: ast.AST, depth: int,
              out: List[Finding]) -> None:
        for child in ast.iter_child_nodes(node):
            d = depth + 1 if isinstance(child, _LOOPS) else depth
            if depth and isinstance(child, ast.Call):
                self._check_call(module, child, out)
            self._scan(module, child, d, out)

    def _check_call(self, module: Module, call: ast.Call,
                    out: List[Finding]) -> None:
        chain = attr_chain(call.func)
        if not chain:
            return
        name = chain[-1]
        if name == "Workload":
            out.append(self.finding(
                module.rel, call.lineno,
                "per-config Workload construction inside a loop/"
                "comprehension (the sweep-construction contract)"))
        elif name == "predict":
            out.append(self.finding(
                module.rel, call.lineno,
                "scalar predict() inside a loop/comprehension — this is "
                "the per-config path the columnar engine replaced"))

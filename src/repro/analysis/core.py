"""Contract-linter framework: AST rules, suppressions, repo driver.

The repo's standing contracts (ROADMAP "Standing contracts") are
runtime-enforced by tests and benchmark bit-identity flags — which fire
*after* a violation ships.  This package is the diff-time half: a
stdlib-only (``ast`` + ``tokenize``) static pass that recognizes the
contract-violating *shapes* in source code and fails the gate before
anything runs.  PPT-GPU's static pre-characterization pass (see
SNIPPETS.md) is the model: task structure is extractable from source
without executing it.

Pieces:

* :class:`Rule` — one contract, registered by subclassing with
  ``@register``.  ``visit(module)`` yields findings per file;
  ``finalize(project)`` runs once for cross-file contracts (metric
  family inventories, wire-schema locks).
* :class:`Finding` — ``file:line``, rule id, message, a one-line fix
  hint, and a per-rule severity (``error`` gates, ``warning`` reports).
* Suppressions — ``# repro: allow[RULE-ID] <justification>`` on the
  offending line (or a standalone comment directly above it).  The
  justification is REQUIRED: a bare allow is itself an error finding
  (``SUPPRESS``), and the underlying finding still gates.  Unused
  suppressions are warnings (``SUPPRESS-UNUSED``) so stale allows rot
  visibly.
* :func:`run_checks` — the driver ``python -m benchmarks.
  check_contracts`` and the tier-1 test both call.

Adding a rule: subclass :class:`Rule` in ``rules/``, decorate with
``@register``, import the module from ``rules/__init__``.  See
``README.md`` in this package.
"""
from __future__ import annotations

import ast
import io
import json
import os
import re
import tokenize
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "ERROR", "WARNING", "Finding", "Module", "Project", "Report", "Rule",
    "RULES", "DEFAULT_PATHS", "register", "repo_root", "run_checks",
]

ERROR = "error"
WARNING = "warning"

#: what the gate lints by default, relative to the repo root
DEFAULT_PATHS: Tuple[str, ...] = ("src/repro",)

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow\[([A-Za-z0-9_-]+)\]\s*(.*?)\s*$")


@dataclass(frozen=True)
class Finding:
    """One contract violation (or meta finding) at ``path:line``."""

    rule: str
    path: str            # repo-relative, posix separators
    line: int
    message: str
    hint: str = ""
    severity: str = ERROR
    suppressed: bool = False
    justification: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> Dict:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "message": self.message, "hint": self.hint,
            "severity": self.severity, "suppressed": self.suppressed,
            "justification": self.justification,
        }

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        out = (f"{self.location}: [{self.rule}] {self.severity}{tag}: "
               f"{self.message}")
        if self.hint and not self.suppressed:
            out += f"\n    hint: {self.hint}"
        return out


@dataclass
class Suppression:
    """One ``# repro: allow[ID] why`` comment."""

    line: int            # line the comment sits on
    target: int          # code line it suppresses
    rule: str
    justification: str
    used: bool = False


class Module:
    """One parsed source file plus its suppression comments."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree: Optional[ast.AST] = ast.parse(source, filename=rel)
        except SyntaxError as e:
            self.tree = None
            self.parse_error = e
        self.suppressions: List[Suppression] = self._scan_suppressions()

    # -- suppressions ------------------------------------------------------
    def _scan_suppressions(self) -> List[Suppression]:
        out: List[Suppression] = []
        try:
            tokens = tokenize.generate_tokens(
                io.StringIO(self.source).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _SUPPRESS_RE.search(tok.string)
                if not m:
                    continue
                line = tok.start[0]
                standalone = not self.lines[line - 1][:tok.start[1]].strip()
                out.append(Suppression(
                    line=line,
                    target=self._next_code_line(line) if standalone
                    else line,
                    rule=m.group(1), justification=m.group(2)))
        except tokenize.TokenError:
            pass                     # the PARSE finding covers broken files
        return out

    def _next_code_line(self, after: int) -> int:
        """First line past ``after`` holding code (a standalone allow
        comment suppresses the statement it stands above)."""
        for i in range(after, len(self.lines)):
            text = self.lines[i].strip()
            if text and not text.startswith("#"):
                return i + 1
        return after


class Project:
    """The file set one run lints, plus read access to the whole repo
    (cross-file rules read committed artifacts like lock files and the
    metric-contract test even when those are outside the linted paths)."""

    def __init__(self, root: str, modules: Sequence[Module]):
        self.root = root
        self.modules = list(modules)
        self._by_rel = {m.rel: m for m in self.modules}

    def module(self, rel: str) -> Optional[Module]:
        return self._by_rel.get(rel.replace(os.sep, "/"))

    def read(self, rel: str) -> Optional[str]:
        """Source of any repo file (linted or not); None if absent."""
        m = self.module(rel)
        if m is not None:
            return m.source
        path = os.path.join(self.root, rel)
        if not os.path.isfile(path):
            return None
        with open(path, encoding="utf-8") as f:
            return f.read()

    def tree(self, rel: str) -> Optional[ast.AST]:
        m = self.module(rel)
        if m is not None:
            return m.tree
        src = self.read(rel)
        if src is None:
            return None
        try:
            return ast.parse(src, filename=rel)
        except SyntaxError:
            return None


class Rule:
    """One standing contract.  Subclass, set ``id``/``hint``/``severity``,
    implement ``visit`` (per file) and/or ``finalize`` (once, cross-file),
    and decorate with :func:`register`."""

    id: str = ""
    severity: str = ERROR
    hint: str = ""

    def visit(self, module: Module) -> Iterable[Finding]:
        return ()

    def finalize(self, project: Project) -> Iterable[Finding]:
        return ()

    # -- helpers -----------------------------------------------------------
    def finding(self, rel: str, line: int, message: str, *,
                hint: Optional[str] = None,
                severity: Optional[str] = None) -> Finding:
        return Finding(
            rule=self.id, path=rel, line=int(line), message=message,
            hint=self.hint if hint is None else hint,
            severity=self.severity if severity is None else severity)


#: rule id -> rule class; populated by ``@register`` at import of
#: ``repro.analysis.rules``
RULES: Dict[str, type] = {}


def register(cls):
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in RULES and RULES[cls.id] is not cls:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    RULES[cls.id] = cls
    return cls


def repo_root() -> str:
    """The checkout this installed package belongs to
    (``src/repro/analysis/core.py`` -> four levels up)."""
    here = os.path.abspath(__file__)
    root = here
    for _ in range(4):
        root = os.path.dirname(root)
    return root


def collect_modules(root: str, paths: Sequence[str]) -> List[Module]:
    files: List[str] = []
    for p in paths:
        full = os.path.join(root, p)
        if os.path.isfile(full):
            files.append(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__"
                                 and not d.startswith("."))
            files.extend(os.path.join(dirpath, fn)
                         for fn in sorted(filenames) if fn.endswith(".py"))
    modules = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as f:
            source = f.read()
        modules.append(Module(path, rel, source))
    return modules


@dataclass
class Report:
    """Every finding of one run, suppressions already resolved."""

    findings: List[Finding] = field(default_factory=list)

    def unsuppressed(self, severity: Optional[str] = None) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed
                and (severity is None or f.severity == severity)]

    @property
    def errors(self) -> List[Finding]:
        return self.unsuppressed(ERROR)

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_json(self) -> Dict:
        return {
            "ok": self.ok,
            "counts": {
                "errors": len(self.errors),
                "warnings": len(self.unsuppressed(WARNING)),
                "suppressed": sum(1 for f in self.findings
                                  if f.suppressed),
            },
            "findings": [f.to_dict() for f in self.findings],
        }

    def render(self, verbose: bool = True) -> str:
        shown = self.findings if verbose else self.unsuppressed()
        return "\n".join(f.render() for f in shown)


def _load_baseline(path: Optional[str]) -> List[Dict]:
    if not path:
        return []
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    entries = doc.get("findings", doc) if isinstance(doc, dict) else doc
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path}: expected a findings list")
    return entries


def _apply_suppressions(findings: List[Finding],
                        modules: Sequence[Module],
                        baseline: List[Dict]) -> List[Finding]:
    by_rel: Dict[str, List[Suppression]] = {}
    meta: List[Finding] = []
    for m in modules:
        live = []
        for s in m.suppressions:
            if not s.justification:
                meta.append(Finding(
                    rule="SUPPRESS", path=m.rel, line=s.line,
                    message=(f"suppression of {s.rule} carries no "
                             f"justification — the allow is inert"),
                    hint=("write '# repro: allow[{0}] <why this is "
                          "safe>'".format(s.rule))))
                continue
            live.append(s)
        by_rel[m.rel] = live

    base_keys = {(e.get("rule"), e.get("path"), int(e.get("line", 0)))
                 for e in baseline}
    out: List[Finding] = []
    for f in findings:
        supp = next(
            (s for s in by_rel.get(f.path, ())
             if s.rule == f.rule and s.target == f.line), None)
        if supp is not None:
            supp.used = True
            out.append(replace(f, suppressed=True,
                               justification=supp.justification))
        elif (f.rule, f.path, f.line) in base_keys:
            out.append(replace(f, suppressed=True,
                               justification="grandfathered by baseline"))
        else:
            out.append(f)

    for m in modules:
        for s in by_rel.get(m.rel, ()):
            if not s.used:
                meta.append(Finding(
                    rule="SUPPRESS-UNUSED", path=m.rel, line=s.line,
                    severity=WARNING,
                    message=(f"suppression of {s.rule} matches no "
                             f"finding — delete the stale allow")))
    return out + meta


def run_checks(root: Optional[str] = None,
               paths: Optional[Sequence[str]] = None,
               rules: Optional[Sequence[str]] = None,
               baseline: Optional[str] = None) -> Report:
    """Lint ``paths`` (default ``src/repro``) under ``root`` (default:
    this checkout) with ``rules`` (default: all registered).  Returns a
    :class:`Report`; the run gates on ``report.errors``."""
    from . import rules as _rules_pkg                      # noqa: F401
    root = os.path.abspath(root or repo_root())
    modules = collect_modules(root, paths or DEFAULT_PATHS)
    project = Project(root, modules)
    active = [RULES[r]() for r in rules] if rules is not None \
        else [cls() for _, cls in sorted(RULES.items())]

    findings: List[Finding] = []
    for m in modules:
        if m.parse_error is not None:
            findings.append(Finding(
                rule="PARSE", path=m.rel,
                line=m.parse_error.lineno or 1,
                message=f"file does not parse: {m.parse_error.msg}"))
            continue
        for rule in active:
            findings.extend(rule.visit(m))
    for rule in active:
        findings.extend(rule.finalize(project))

    findings = _apply_suppressions(
        findings, modules, _load_baseline(baseline))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(findings)

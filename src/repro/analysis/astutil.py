"""Small shared AST helpers for the contract rules (stdlib only)."""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

__all__ = ["attr_chain", "call_name", "const_value", "iter_module_scope"]


def attr_chain(node: ast.AST) -> List[str]:
    """Attribute/name path of an expression, outermost last.

    ``table.cols[i].flags.writeable`` -> ``["table", "cols", "flags",
    "writeable"]`` (subscripts and calls are transparent).  Unresolvable
    roots (calls of calls, literals) contribute nothing.
    """
    parts: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, (ast.Subscript, ast.Call)):
            node = node.func if isinstance(node, ast.Call) else node.value
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            break
        else:
            break
    parts.reverse()
    return parts


def call_name(call: ast.Call) -> Tuple[Optional[str], str]:
    """(qualifier, name) of a call: ``time.sleep(...)`` ->
    ``("time", "sleep")``, ``open(...)`` -> ``(None, "open")``,
    ``self._flush(...)`` -> ``("self", "_flush")``.  The qualifier is the
    full dotted prefix."""
    chain = attr_chain(call.func)
    if not chain:
        return None, ""
    if len(chain) == 1:
        return None, chain[0]
    return ".".join(chain[:-1]), chain[-1]


def const_value(node: ast.AST):
    """Fold a constant expression (literals, tuples/lists of constants,
    +-*//<< on folded values, unary minus).  Raises ValueError when the
    expression is not statically constant."""
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, (ast.Tuple, ast.List)):
        return [const_value(e) for e in node.elts]
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -const_value(node.operand)
    if isinstance(node, ast.BinOp):
        left, right = const_value(node.left), const_value(node.right)
        op = type(node.op)
        folds = {ast.Add: lambda a, b: a + b,
                 ast.Sub: lambda a, b: a - b,
                 ast.Mult: lambda a, b: a * b,
                 ast.Pow: lambda a, b: a ** b,
                 ast.LShift: lambda a, b: a << b,
                 ast.RShift: lambda a, b: a >> b,
                 ast.BitOr: lambda a, b: a | b,
                 ast.FloorDiv: lambda a, b: a // b}
        if op in folds:
            return folds[op](left, right)
    raise ValueError(f"not a static constant: {ast.dump(node)}")


def iter_module_scope(tree: ast.Module) -> Iterator[ast.stmt]:
    """Statements executed at import time at module scope — walks into
    module-level ``if``/``try``/``with`` blocks but never into function
    or class bodies."""
    stack: List[ast.stmt] = list(tree.body)
    while stack:
        stmt = stack.pop(0)
        yield stmt
        if isinstance(stmt, (ast.If, ast.Try, ast.With, ast.For,
                             ast.While)):
            for name in ("body", "orelse", "finalbody", "handlers"):
                for child in getattr(stmt, name, ()):
                    if isinstance(child, ast.ExceptHandler):
                        stack.extend(child.body)
                    else:
                        stack.append(child)

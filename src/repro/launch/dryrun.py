import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first init).  Everything else follows.

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
from typing import Dict, Optional, Tuple   # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                    # noqa: E402

from ..configs import SHAPES, all_cells, cell_applicable, get_config, \
    memory_len                              # noqa: E402
from ..configs.base import ModelConfig      # noqa: E402
from ..core import tpu as tpu_model          # noqa: E402
from ..data import make_batch_specs          # noqa: E402
from ..distributed import sharding           # noqa: E402
from ..models import build                   # noqa: E402
from ..optim.schedule import for_arch        # noqa: E402
from ..train.serve_step import make_prefill, make_serve_step  # noqa: E402
from ..train.train_step import init_state, make_train_step    # noqa: E402
from . import hlo_analysis                   # noqa: E402
from .mesh import make_production_mesh       # noqa: E402

# ---------------------------------------------------------------------------
# Per-cell execution plans (baseline).  §Perf hillclimbing edits these.
# ---------------------------------------------------------------------------

BIG = ("deepseek-67b", "llama3-405b", "deepseek-v3-671b",
       "qwen3-moe-235b-a22b", "llama-3.2-vision-90b")


def plan_for(arch: str, shape: str, cfg: ModelConfig) -> Dict:
    """Baseline execution plan: sharding-rule overrides + microbatches +
    optimizer dtypes, chosen to fit HBM (DESIGN.md §5)."""
    plan: Dict = {"rules": {}, "microbatches": 1,
                  "moment_dtype": None, "accum_dtype": "float32",
                  "remat": None}
    if cfg.d_model >= 7168:
        # shard the residual stream's hidden dim over "model" so scanned
        # layer-carry residuals stay O(D/16) per chip
        plan["rules"]["embed"] = "model"
    if arch in BIG:
        plan["moment_dtype"] = "bfloat16"
        plan["accum_dtype"] = "bfloat16"
    if shape == "train_4k":
        # global batch 256: grad-accumulate in 8 microbatches.  Dominant
        # temp buffers (fp32 logits chain + per-layer scan carries) scale
        # with live tokens; 1M tokens at once blows the 16 GB HBM.
        plan["microbatches"] = 8
    if shape == "long_500k":
        plan["rules"]["batch"] = None     # batch 1: DP axes idle
    return plan


def model_flops_for(cfg: ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), N excluding
    embeddings; D = tokens processed by the lowered step."""
    shape = SHAPES[shape_name]
    n_embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n = cfg.active_param_count() - n_embed
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: 1 token per sequence


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

ACCOUNTING_ATTN_CHUNK = 4096   # same flop/byte totals, fewer bigger HLO ops


def _accounting_cfg(cfg: ModelConfig, groups: int) -> ModelConfig:
    """Reduced-depth UNROLLED config for cost accounting.

    XLA cost_analysis counts while-loop bodies once, so the deployed
    scanned lowering under-reports by the trip count.  We instead lower
    unrolled 1-group and 2-group variants; all depth-dependent costs are
    linear in the group count, so  total(G) = f1 + (G-1)*(f2-f1)  is exact
    for flops/bytes/collectives (embed/head/optimizer-on-prefix terms live
    in the intercept)."""
    plen = len(cfg.pattern)
    kw = dict(
        n_layers=cfg.first_dense + groups * plen,
        scan_layers=False,
        attn_chunk_unroll=True,
    )
    if cfg.attn_chunk > 0:
        kw["attn_chunk"] = ACCOUNTING_ATTN_CHUNK
    return cfg.replace(**kw)


def _lower_for(model, cfg, shape, mesh, plan, arch):
    if shape.kind == "train":
        return _lower_train(model, cfg, shape, mesh, plan, arch)
    if shape.kind == "prefill":
        return _lower_prefill(model, cfg, shape, mesh, plan)
    return _lower_decode(model, cfg, shape, mesh, plan)


def _cost_of(lowered, num_chips: int) -> Tuple[float, float, float, object]:
    """GLOBAL flop/byte/collective totals of one lowering.

    XLA cost_analysis on an SPMD executable reports PER-PARTITION numbers
    (verified empirically: an 8-way-sharded matmul reports 1/8 of the
    global flops), and HLO shard shapes are per-device — so scale by the
    chip count to match the task-spec global-form roofline terms."""
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost = cost or {}
    stats = hlo_analysis.analyze(compiled.as_text(),
                                 default_while_multiplier=1.0)
    nbytes = max(float(cost.get("bytes accessed", 0.0))
                 - stats.dus_overcount_bytes, 0.0)
    return (float(cost.get("flops", 0.0)) * num_chips,
            nbytes * num_chips,
            stats.total_bytes * num_chips, stats)


def account_cell(cfg, shape, mesh, plan, arch) -> Dict[str, float]:
    """Two-point group extrapolation of flops / bytes / collective bytes."""
    vals = []
    stats2 = None
    for g in (1, 2):
        cfg_g = _accounting_cfg(cfg, g)
        model_g = build(cfg_g)
        plan_g = dict(plan, microbatches=1)
        with sharding.use_mesh(mesh, plan["rules"]):
            art = _lower_for(model_g, cfg_g, shape, mesh, plan_g, arch)
        f, b, c, stats = _cost_of(art["lowered"], mesh.size)
        vals.append((f, b, c))
        stats2 = stats
    g_full = cfg.n_groups
    out = {}
    for key, (v1, v2) in zip(("flops", "bytes", "collective_bytes"),
                             zip(*vals)):
        out[key] = v1 + (g_full - 1) * (v2 - v1)
        out[f"{key}_g1"] = v1
        out[f"{key}_g2"] = v2
    out["per_op_collectives_g2"] = dict(stats2.totals) if stats2 else {}
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               plan_override: Optional[Dict] = None,
               accounting: bool = True):
    """Lower + compile one (arch x shape x mesh) cell.

    Two lowerings per cell:
      1. the DEPLOYED plan (scan + remat + microbatches) -> compile gate +
         memory_analysis ("proves it fits"),
      2. unrolled 1-/2-group accounting lowers -> exact flop/byte/
         collective totals via linear extrapolation (see _accounting_cfg).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    plan = plan_for(arch, shape_name, cfg)
    if plan_override:
        plan["rules"].update(plan_override.pop("rules", {}))
        plan.update(plan_override)
    if plan.get("remat"):
        cfg = cfg.replace(remat=plan["remat"])
    if plan.get("cfg_overrides"):
        cfg = cfg.replace(**plan["cfg_overrides"])

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    t0 = time.time()

    with sharding.use_mesh(mesh, plan["rules"]):
        artifacts = _lower_for(model, cfg, shape, mesh, plan, arch)

    lowered = artifacts["lowered"]
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost = cost or {}
    stats = hlo_analysis.analyze(
        compiled.as_text(),
        default_while_multiplier=max(cfg.n_groups, 1))

    if accounting:
        acct = account_cell(cfg, shape, mesh, plan, arch)
        eff_cost = {"flops": acct["flops"],
                    "bytes accessed": acct["bytes"]}
        coll_bytes = acct["collective_bytes"]
    else:
        acct = {}
        eff_cost = {k: float(v) * mesh.size for k, v in cost.items()
                    if isinstance(v, (int, float))}
        coll_bytes = stats.total_bytes * mesh.size

    report = tpu_model.report_from_artifacts(
        f"{arch}/{shape_name}/{'2x16x16' if multi_pod else '16x16'}",
        num_chips=mesh.size,
        cost_analysis=eff_cost,
        collective_bytes=coll_bytes,
        model_flops=model_flops_for(cfg, shape_name),
    )
    return {
        "compiled": compiled,
        "cost": cost,
        "accounting": acct,
        "memory_analysis": mem,
        "collectives": stats,
        "report": report,
        "compile_seconds": t_compile,
        "plan": plan,
        "mesh": mesh,
    }


def _batch_shardings(mesh, specs):
    pspecs = sharding.batch_specs_tree(specs, mesh=mesh)
    return sharding.tree_shardings(mesh, pspecs)


def _lower_train(model, cfg, shape, mesh, plan, arch):
    state_specs = jax.eval_shape(
        lambda k: init_state(model, k, moment_dtype=plan["moment_dtype"]),
        jax.random.PRNGKey(0))
    state_sh = sharding.tree_shardings(
        mesh, sharding.param_specs(state_specs, mesh=mesh))
    # per-device batch: global batch over DP axes
    batch_specs = make_batch_specs(cfg, batch=shape.global_batch,
                                   seq_len=shape.seq_len)
    batch_sh = _batch_shardings(mesh, batch_specs)

    lr = for_arch(arch, 3e-4, 2000, 100000)
    step = make_train_step(model, lr=lr,
                           microbatches=plan["microbatches"],
                           accum_dtype=plan.get("accum_dtype", "float32"),
                           q8_moments=plan["moment_dtype"] == "int8")
    lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                      donate_argnums=(0,)).lower(state_specs, batch_specs)
    return {"lowered": lowered}


def _lower_prefill(model, cfg, shape, mesh, plan):
    params_specs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = sharding.tree_shardings(
        mesh, sharding.param_specs(params_specs, mesh=mesh))
    batch_specs = make_batch_specs(cfg, batch=shape.global_batch,
                                   seq_len=shape.seq_len)
    batch_specs.pop("labels")
    batch_sh = _batch_shardings(mesh, batch_specs)

    prefill = make_prefill(model)
    kwargs = {}
    if "memory_embeds" in batch_specs:
        lowered = jax.jit(
            prefill, in_shardings=(params_sh, batch_sh["tokens"],
                                   batch_sh["memory_embeds"])).lower(
            params_specs, batch_specs["tokens"],
            batch_specs["memory_embeds"])
    else:
        lowered = jax.jit(
            prefill, in_shardings=(params_sh, batch_sh["tokens"])).lower(
            params_specs, batch_specs["tokens"])
    return {"lowered": lowered}


def _lower_decode(model, cfg, shape, mesh, plan):
    b = shape.global_batch
    params_specs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = sharding.tree_shardings(
        mesh, sharding.param_specs(params_specs, mesh=mesh))
    cache_specs = model.init_cache(b, shape.seq_len, abstract=True)
    cache_sh = sharding.tree_shardings(
        mesh, sharding.cache_specs_tree(cache_specs, mesh=mesh))
    tok_spec = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_sh = sharding.tree_shardings(
        mesh, sharding.batch_specs_tree(tok_spec, mesh=mesh))
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = sharding.tree_shardings(
        mesh, sharding.batch_specs_tree(pos_spec, mesh=mesh))

    serve = make_serve_step(model)
    args = [params_specs, cache_specs, tok_spec, pos_spec]
    shs = [params_sh, cache_sh, tok_sh, pos_sh]
    mlen = memory_len(cfg, shape.seq_len)
    if mlen is not None:
        mem_spec = jax.ShapeDtypeStruct((b, mlen, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        mem_sh = sharding.tree_shardings(
            mesh, sharding.batch_specs_tree(mem_spec, mesh=mesh))
        args.append(mem_spec)
        shs.append(mem_sh)
    lowered = jax.jit(serve, in_shardings=tuple(shs),
                      donate_argnums=(1,)).lower(*args)
    return {"lowered": lowered}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             json_out: Optional[str] = None, quiet: bool = False) -> Dict:
    ok, why = cell_applicable(arch, shape_name)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if not ok:
        row = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "skipped", "reason": why}
        if not quiet:
            print(f"[dryrun] SKIP {arch} x {shape_name} x {mesh_tag}: {why}")
        if json_out:
            with open(json_out, "a") as f:
                f.write(json.dumps(row) + "\n")
        return row

    if not quiet:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_tag} ...",
              flush=True)
    art = lower_cell(arch, shape_name, multi_pod=multi_pod)
    rep = art["report"]
    mem = art["memory_analysis"]
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "status": "ok",
        "chips": rep.num_chips,
        "hlo_flops": rep.hlo_flops,
        "hlo_bytes": rep.hlo_bytes,
        "collective_bytes": rep.collective_bytes,
        "model_flops": rep.model_flops,
        "compute_term_s": rep.compute_term,
        "memory_term_s": rep.memory_term,
        "collective_term_s": rep.collective_term,
        "dominant": rep.dominant,
        "useful_flops_ratio": rep.useful_flops_ratio,
        "roofline_fraction": rep.roofline_fraction,
        "compile_seconds": art["compile_seconds"],
        "collective_totals": dict(art["collectives"].totals),
        "plan": {k: v for k, v in art["plan"].items()},
    }
    # memory analysis: "proves it fits"
    try:
        row["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception:                                    # pragma: no cover
        row["memory"] = {"repr": repr(mem)}
    if not quiet:
        print(f"  compile {art['compile_seconds']:.1f}s | "
              f"flops {rep.hlo_flops:.3e} bytes {rep.hlo_bytes:.3e} "
              f"coll {rep.collective_bytes:.3e}")
        print(f"  terms: compute {rep.compute_term:.4e}s "
              f"memory {rep.memory_term:.4e}s "
              f"collective {rep.collective_term:.4e}s "
              f"-> {rep.dominant}-bound | useful {rep.useful_flops_ratio:.3f}")
        print(f"  memory_analysis: {row['memory']}")
    if json_out:
        with open(json_out, "a") as f:
            f.write(json.dumps(row) + "\n")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--json", default=None, help="append JSONL rows here")
    args = ap.parse_args(argv)

    cells: list
    if args.all:
        cells = [(a, s) for a, s, _, _ in all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, json_out=args.json)
            except Exception as e:                       # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} x {shape} "
                      f"(multi_pod={mp}): {e}", file=sys.stderr)
    if failures:
        print(f"[dryrun] {len(failures)} failures", file=sys.stderr)
        sys.exit(1)
    print("[dryrun] all requested cells compiled")


if __name__ == "__main__":
    main()

"""Serving driver: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --smoke \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, memory_len
from ..models import build
from ..train.serve_step import greedy_generate


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, max_new: int = 16, seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    mem = None
    mlen = memory_len(cfg, prompt_len)
    if mlen is not None:
        mem = jax.random.normal(key, (batch, max(mlen, 4), cfg.d_model),
                                jnp.float32)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, max_new=max_new,
                          memory_embeds=mem)
    dt = time.time() - t0
    toks = batch * max_new
    print(f"[serve] {arch}: generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. prefill)")
    return out


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    # BooleanOptionalAction so --no-smoke actually reaches the full-size
    # configs (action="store_true" with default=True made every invocation
    # smoke mode, flag or not)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    serve(args.arch, smoke=args.smoke, batch=args.batch,
          prompt_len=args.prompt_len, max_new=args.max_new)


if __name__ == "__main__":
    main()

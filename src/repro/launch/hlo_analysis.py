"""HLO text analysis: collective-byte accounting for the roofline's third
term (task spec: "parse lowered.as_text() / compiled.as_text() and sum
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute").

Collectives inside scan (while) bodies execute trip_count times; we parse
``known_trip_count={n}`` annotations where XLA provides them and propagate
multipliers through nested while computations.  When no annotation exists
the caller can supply a default multiplier for while-bodies (the dry-run
passes the model's layer-group count).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\([^)]*\))?\s*->.*{")
_WHILE_RE = re.compile(r"body=%?([\w.\-]+)")
# accepts the text form known_trip_count={n=7} and the backend_config
# JSON form "known_trip_count":{"n":"7"}
_TRIP_RE = re.compile(
    r'known_trip_count"?\s*[:=]\s*\{"?n"?\s*[:=]\s*"?(\d+)"?\}')
_CALL_RE = re.compile(r"(?:to_apply|called_computations)=\{?%?([\w.\-]+)")


def shape_bytes(shape_str: str) -> float:
    """Bytes of one HLO shape string; tuples sum their elements."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    """Per-op-type byte totals + schedule rows (op, bytes, computation).

    dus_overcount_bytes: XLA's cost model charges dynamic-update-slice at
    full-operand size; real (in-place) traffic is the updated slice.  The
    dry-run subtracts this from 'bytes accessed' (decode KV-cache writes
    otherwise inflate the memory term ~35x)."""

    totals: Dict[str, float] = field(default_factory=dict)
    schedule: List[Tuple[str, float, str, float]] = field(
        default_factory=list)
    dus_overcount_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return sum(self.totals.values())


def analyze(hlo_text: str, *,
            default_while_multiplier: float = 1.0) -> CollectiveStats:
    """Sum collective bytes over the module, weighting while-body
    computations by trip count."""
    # pass 1: instruction shapes, per-computation collectives, while edges
    comp = "<module>"
    shapes: Dict[str, str] = {}
    comp_collectives: Dict[str, List[Tuple[str, str, str]]] = {}
    comp_dus: Dict[str, List[float]] = {}   # per-comp DUS overcounts
    while_edges: List[Tuple[str, str, Optional[int]]] = []  # (parent, body, trip)
    comp_calls: List[Tuple[str, str]] = []

    # join continuation lines (attrs like backend_config may wrap)
    joined: List[str] = []
    for raw in hlo_text.splitlines():
        if joined and not _INSTR_RE.match(raw) and not _COMP_RE.match(raw) \
                and raw.strip() and not raw.strip().startswith(("}", "//")):
            joined[-1] += " " + raw.strip()
        else:
            joined.append(raw)

    for line in joined:
        mcomp = _COMP_RE.match(line)
        if mcomp and "=" not in line.split("{")[0]:
            comp = mcomp.group(1)
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op = m.groups()
        shapes[name] = shape_str
        base_op = op
        if base_op.endswith("-start"):
            base_op = base_op[:-6]
        if base_op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        if base_op in COLLECTIVE_OPS:
            comp_collectives.setdefault(comp, []).append(
                (base_op, shape_str, line))
        if op == "dynamic-update-slice" or "dynamic-update-slice(" in line:
            opnds = re.findall(r"%([\w.\-]+)", line.split("(", 1)[-1])
            full = shape_bytes(shape_str)
            upd = shape_bytes(shapes.get(opnds[1], "")) if len(opnds) > 1 \
                else 0.0
            if full > 4 * max(upd, 1.0):    # only correct real cache writes
                comp_dus.setdefault(comp, []).append(2.0 * (full - upd))
        if op == "while":
            mb = _WHILE_RE.search(line)
            if mb:
                mt = _TRIP_RE.search(line)
                trip = int(mt.group(1)) if mt else None
                while_edges.append((comp, mb.group(1), trip))
        else:
            mc = _CALL_RE.search(line)
            if mc:
                comp_calls.append((comp, mc.group(1)))

    # pass 2: propagate multipliers (fixpoint over nesting)
    mult: Dict[str, float] = {}

    def multiplier_of(c: str, depth=0) -> float:
        if c in mult:
            return mult[c]
        if depth > 32:
            return 1.0
        m = 1.0
        for parent, body, trip in while_edges:
            if body == c:
                t = trip if trip is not None else default_while_multiplier
                m = multiplier_of(parent, depth + 1) * t
                break
        else:
            for parent, callee in comp_calls:
                if callee == c:
                    m = multiplier_of(parent, depth + 1)
                    break
        mult[c] = m
        return m

    stats = CollectiveStats()
    for c, vals in comp_dus.items():
        stats.dus_overcount_bytes += multiplier_of(c) * sum(vals)
    for c, items in comp_collectives.items():
        weight = multiplier_of(c)
        for base_op, shape_str, line in items:
            # operand bytes: prefer summing named operand shapes; fall back
            # to the result shape (equal for all-reduce, lower bound else)
            opnds = re.findall(r"%([\w.\-]+)", line.split("(", 1)[-1])
            b = sum(shape_bytes(shapes.get(o, "")) for o in opnds
                    if o in shapes)
            if b == 0.0:
                b = shape_bytes(shape_str)
            stats.totals[base_op] = stats.totals.get(base_op, 0.0) \
                + b * weight
            stats.schedule.append((base_op, b, c, weight))
    return stats


def summarize(stats: CollectiveStats) -> str:
    lines = [f"collective bytes total: {stats.total_bytes:.3e}"]
    for op, b in sorted(stats.totals.items()):
        lines.append(f"  {op:20s} {b:.3e}")
    return "\n".join(lines)

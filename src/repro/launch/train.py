"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On the real cluster this runs under SPMD with the production mesh; in this
container it runs single-host (smoke configs) with the same code path:
deterministic data, WSD/cosine schedule per arch, gradient clipping,
async checkpointing every N steps, exact resume, preemption-safe saves.
"""
from __future__ import annotations

import argparse
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data import SyntheticLMData
from ..models import build
from ..optim.schedule import for_arch
from ..train import checkpoint as ckpt
from ..train.train_step import init_state, make_train_step


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          batch: int = 8, seq: int = 128, lr: float = 3e-4,
          microbatches: int = 1, compress_grads: bool = False,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 25,
          log_every: int = 10, seed: int = 0,
          resume: bool = True) -> dict:
    cfg = get_config(arch, smoke=smoke)
    model = build(cfg)
    data = SyntheticLMData(cfg, batch=batch, seq_len=seq, seed=seed)
    schedule = for_arch(arch, lr, max(steps // 20, 5), steps)
    step_fn = jax.jit(make_train_step(
        model, lr=schedule, microbatches=microbatches,
        compress_grads=compress_grads))

    start_step = 0
    state = init_state(model, jax.random.PRNGKey(seed),
                       compress_grads=compress_grads)
    if ckpt_dir and resume:
        latest = ckpt.latest_step_dir(ckpt_dir)
        if latest:
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, manifest = ckpt.restore(latest, like)
            start_step = manifest["step"]
            print(f"[train] resumed from {latest} at step {start_step}")

    saver = ckpt.AsyncCheckpointer()
    interrupted = {"flag": False}

    def _on_signal(signum, frame):     # preemption-safe emergency save
        interrupted["flag"] = True
    old = signal.signal(signal.SIGTERM, _on_signal)

    losses = []
    t0 = time.time()
    try:
        for step in range(start_step, steps):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            state, metrics = step_fn(state, b)
            losses.append(float(metrics["loss"]))
            if log_every and (step + 1) % log_every == 0:
                rate = (step + 1 - start_step) / (time.time() - t0)
                print(f"[train] step {step + 1}/{steps} "
                      f"loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({rate:.2f} it/s)")
            if ckpt_dir and ((step + 1) % ckpt_every == 0
                             or interrupted["flag"]):
                saver.save(f"{ckpt_dir}/ckpt_{step + 1:06d}", state,
                           step=step + 1)
            if interrupted["flag"]:
                print("[train] SIGTERM: emergency checkpoint written")
                break
    finally:
        saver.wait()
        signal.signal(signal.SIGTERM, old)
    if ckpt_dir:
        saver.save(f"{ckpt_dir}/ckpt_{steps:06d}", state, step=steps)
        saver.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "state": state}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args(argv)
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, lr=args.lr,
                microbatches=args.microbatches,
                compress_grads=args.compress_grads,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"[train] done; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()

"""Production mesh construction.

A function (not module-level constant) so importing this module never
touches jax device state.  Production target: TPU v5e pods of 256 chips
(16x16 ICI torus); multi-pod adds a leading DCI-connected "pod" axis.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devs)} — run "
            f"via repro.launch.dryrun (sets "
            f"xla_force_host_platform_device_count=512)")
    # dry-run container: 512 placeholder devices; single-pod uses 256
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(*, devices: Optional[int] = None, model: int = 2,
                   pod: int = 1):
    """Small mesh for CPU subprocess tests (8 host devices)."""
    n = devices or len(jax.devices())
    data = n // (model * pod)
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_spec_of(mesh) -> "object":
    """core.collectives.MeshSpec view of a jax Mesh (for the analytical
    collective model)."""
    from ..core.collectives import MeshSpec
    return MeshSpec(axes=tuple(
        (name, int(mesh.shape[name])) for name in mesh.axis_names))

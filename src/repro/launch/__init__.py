# launch: mesh construction, multi-pod dry-run, HLO analysis, drivers.
# NOTE: dryrun.py must be executed as a MAIN module (python -m
# repro.launch.dryrun) so its XLA_FLAGS lines run before jax initializes;
# do not import it from here.
from . import mesh  # noqa: F401

"""Prediction-serving launcher (the sweep-pricing counterpart of
``launch.serve``'s token-generation driver).

    # start the server (ephemeral port prints on stdout)
    PYTHONPATH=src python -m repro.launch.predict_serve serve --port 8707

    # also open the framed persistent-socket transport (binary framing
    # v1; --binary-port 0 picks an ephemeral port, printed as a second
    # banner) and cap the coalescer's adaptive fused-row budget
    PYTHONPATH=src python -m repro.launch.predict_serve serve \
        --port 8707 --binary-port 8708 --max-fused-rows 65536

    # query it from another shell / machine (--transport binary pins the
    # framed socket; the default auto-negotiates via /v1/health)
    PYTHONPATH=src python -m repro.launch.predict_serve query health
    PYTHONPATH=src python -m repro.launch.predict_serve query argmin-demo \
        --hw b200 --gemm 8192,8192,8192

Thin wrapper: ``serve`` is ``repro.serve.server.main`` and ``query`` is
``repro.serve.client.main`` — both accept the same flags here as when
run as modules directly.
"""
from __future__ import annotations

import sys


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return
    cmd, rest = argv[0], argv[1:]
    if cmd == "serve":
        from ..serve.server import main as serve_main
        serve_main(rest)
    elif cmd == "query":
        from ..serve.client import main as query_main
        query_main(rest)
    else:
        raise SystemExit(
            f"unknown command {cmd!r}: expected 'serve' or 'query'")


if __name__ == "__main__":
    main()

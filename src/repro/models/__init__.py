from .model import LanguageModel, build  # noqa: F401

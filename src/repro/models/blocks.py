"""Block registry: per-kind (init, apply, cache_init/spec, decode).

Every block owns its norms and residual adds.  Kinds:
  attn        full causal GQA attention + SwiGLU MLP
  local_attn  sliding-window GQA attention + MLP
  moe         (MLA or GQA) attention + MoE FFN (returns aux loss)
  ssm         Mamba2 mixer (no MLP; the block IS the mixer)
  rglru       RG-LRU recurrent mixer + MLP
  cross_attn  self-attn + cross-attn(memory) + MLP (whisper dec / vlm)
  enc_attn    bidirectional attention + MLP (whisper encoder)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import dtype_of, mlp_apply, mlp_init, rmsnorm

ZERO = jnp.zeros((), jnp.float32)


def _norm_init(cfg):
    return jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype))


# --------------------------------------------------------------------------
# attn / local_attn
# --------------------------------------------------------------------------

def _attn_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {"ln1": _norm_init(cfg), "attn": attn_mod.attn_init(k1, cfg)}
    if cfg.d_ff > 0:
        p["ln2"] = _norm_init(cfg)
        p["mlp"] = mlp_init(k2, cfg)
    return p


def _attn_block_apply(p, x, cfg: ModelConfig, *, window=0, causal=True,
                      memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attn_mod.attn_apply(p["attn"], h, cfg, causal=causal,
                                window=window)
    if "mlp" in p:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg)
    return x, ZERO


def _attn_cache(cfg, batch, max_len, *, window=0):
    return {"kv": attn_mod.init_kv_cache(cfg, batch, max_len, window=window)}


def _attn_decode(p, x, cache, pos, cfg: ModelConfig, *, window=0,
                 memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, kv = attn_mod.decode_attn_apply(p["attn"], h, cache["kv"], pos, cfg,
                                       window=window)
    x = x + o
    if "mlp" in p:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg)
    return x, {"kv": kv}


# --------------------------------------------------------------------------
# moe (attention = MLA or GQA, FFN = MoE)
# --------------------------------------------------------------------------

def _moe_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    attn_p = (mla_mod.mla_init(k1, cfg) if cfg.use_mla
              else attn_mod.attn_init(k1, cfg))
    return {"ln1": _norm_init(cfg), "attn": attn_p,
            "ln2": _norm_init(cfg), "moe": moe_mod.moe_init(k2, cfg)}


def _moe_block_apply(p, x, cfg: ModelConfig, *, memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        x = x + mla_mod.mla_apply(p["attn"], h, cfg)
    else:
        x = x + attn_mod.attn_apply(p["attn"], h, cfg, causal=True)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    y, aux = moe_mod.moe_apply(p["moe"], h, cfg)
    return x + y, aux


def _moe_cache(cfg, batch, max_len, **kw):
    if cfg.use_mla:
        return {"mla": mla_mod.init_mla_cache(cfg, batch, max_len)}
    return {"kv": attn_mod.init_kv_cache(cfg, batch, max_len)}


def _moe_decode(p, x, cache, pos, cfg: ModelConfig, *, memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        o, new = mla_mod.mla_decode(p["attn"], h, cache["mla"], pos, cfg)
        new_cache = {"mla": new}
    else:
        o, new = attn_mod.decode_attn_apply(p["attn"], h, cache["kv"],
                                            pos, cfg)
        new_cache = {"kv": new}
    x = x + o
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    y, _ = moe_mod.moe_apply(p["moe"], h, cfg)
    return x + y, new_cache


# --------------------------------------------------------------------------
# ssm
# --------------------------------------------------------------------------

def _ssm_block_init(key, cfg: ModelConfig):
    return {"ln1": _norm_init(cfg), "ssm": ssm_mod.ssm_init(key, cfg)}


def _ssm_block_apply(p, x, cfg: ModelConfig, *, memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    return x + ssm_mod.ssm_apply(p["ssm"], h, cfg), ZERO


def _ssm_cache(cfg, batch, max_len, **kw):
    return {"ssm": ssm_mod.init_ssm_cache(cfg, batch)}


def _ssm_decode(p, x, cache, pos, cfg: ModelConfig, *, memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, new = ssm_mod.ssm_decode(p["ssm"], h, cache["ssm"], pos, cfg)
    return x + o, {"ssm": new}


# --------------------------------------------------------------------------
# rglru
# --------------------------------------------------------------------------

def _rglru_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {"ln1": _norm_init(cfg), "lru": rglru_mod.rglru_init(k1, cfg)}
    if cfg.d_ff > 0:
        p["ln2"] = _norm_init(cfg)
        p["mlp"] = mlp_init(k2, cfg)
    return p


def _rglru_block_apply(p, x, cfg: ModelConfig, *, memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + rglru_mod.rglru_apply(p["lru"], h, cfg)
    if "mlp" in p:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg)
    return x, ZERO


def _rglru_cache(cfg, batch, max_len, **kw):
    return {"lru": rglru_mod.init_rglru_cache(cfg, batch)}


def _rglru_decode(p, x, cache, pos, cfg: ModelConfig, *, memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, new = rglru_mod.rglru_decode(p["lru"], h, cache["lru"], pos, cfg)
    x = x + o
    if "mlp" in p:
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, cfg)
    return x, {"lru": new}


# --------------------------------------------------------------------------
# cross_attn (self + cross + mlp) and enc_attn (bidirectional + mlp)
# --------------------------------------------------------------------------

def _cross_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _norm_init(cfg), "attn": attn_mod.attn_init(k1, cfg),
            "lnx": _norm_init(cfg), "xattn": attn_mod.attn_init(k2, cfg),
            "ln2": _norm_init(cfg), "mlp": mlp_init(k3, cfg),
            "xgate": jnp.zeros((), jnp.dtype(cfg.param_dtype))}


def _cross_block_apply(p, x, cfg: ModelConfig, *, memory=None):
    assert memory is not None, "cross_attn block needs memory"
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + attn_mod.attn_apply(p["attn"], h, cfg, causal=True)
    h = rmsnorm(x, p["lnx"], cfg.norm_eps)
    xo = attn_mod.attn_apply(p["xattn"], h, cfg, causal=False,
                             kv_override=memory)
    x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * xo
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h, cfg), ZERO


def _cross_cache(cfg, batch, max_len, **kw):
    return {"kv": attn_mod.init_kv_cache(cfg, batch, max_len)}


def _cross_decode(p, x, cache, pos, cfg: ModelConfig, *, memory=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, kv = attn_mod.decode_attn_apply(p["attn"], h, cache["kv"], pos, cfg)
    x = x + o
    h = rmsnorm(x, p["lnx"], cfg.norm_eps)
    xo = attn_mod.attn_apply(p["xattn"], h, cfg, causal=False,
                             kv_override=memory)
    x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * xo
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h, cfg), {"kv": kv}


def _enc_block_apply(p, x, cfg: ModelConfig, *, memory=None):
    return _attn_block_apply(p, x, cfg, causal=False)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

class BlockDef:
    def __init__(self, init, apply, cache, decode):
        self.init = init
        self.apply = apply
        self.cache = cache
        self.decode = decode


REGISTRY: Dict[str, BlockDef] = {
    "attn": BlockDef(
        _attn_block_init,
        lambda p, x, cfg, **kw: _attn_block_apply(p, x, cfg, window=0, **kw),
        lambda cfg, b, m, **kw: _attn_cache(cfg, b, m, window=0),
        lambda p, x, c, pos, cfg, **kw: _attn_decode(p, x, c, pos, cfg,
                                                     window=0, **kw)),
    "local_attn": BlockDef(
        _attn_block_init,
        lambda p, x, cfg, **kw: _attn_block_apply(
            p, x, cfg, window=cfg.window, **kw),
        lambda cfg, b, m, **kw: _attn_cache(cfg, b, m, window=cfg.window),
        lambda p, x, c, pos, cfg, **kw: _attn_decode(
            p, x, c, pos, cfg, window=cfg.window, **kw)),
    "moe": BlockDef(_moe_block_init, _moe_block_apply, _moe_cache,
                    _moe_decode),
    "ssm": BlockDef(_ssm_block_init, _ssm_block_apply, _ssm_cache,
                    _ssm_decode),
    "rglru": BlockDef(_rglru_block_init, _rglru_block_apply, _rglru_cache,
                      _rglru_decode),
    "cross_attn": BlockDef(_cross_block_init, _cross_block_apply,
                           _cross_cache, _cross_decode),
    "enc_attn": BlockDef(_attn_block_init, _enc_block_apply,
                         lambda cfg, b, m, **kw: {},
                         None),
}

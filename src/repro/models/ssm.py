"""Mamba2 block: split in-projections -> causal depthwise convs -> SSD scan
-> gated RMSNorm -> out-proj.

Projections are SEPARATE weights per stream (z, x, B, C, dt) rather than one
fused matmul: fused output slicing would cut across "model"-axis shards and
force XLA to re-gather the whole activation (found in the dry-run: 3e14
collective bytes on train_4k).  B/C are small (2N per token) and computed
replicated; z/x/dt shard cleanly on heads/channels.

Uses the Pallas SSD kernel (TPU target) or the chunked-jnp path with
head-block processing (XLA fallback; see kernels/ssd/ref.py)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .layers import dense_init, dtype_of, pdtype_of, rmsnorm


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * n
    return di, n, h, conv_ch


def ssm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    pd = pdtype_of(cfg)
    di, n, h, conv_ch = _dims(cfg)
    return {
        # separate stream projections (shard-aligned; see module docstring)
        "w_z": dense_init(ks[0], cfg.d_model, di, pd),
        "w_xs": dense_init(ks[1], cfg.d_model, di, pd),
        "w_b": dense_init(ks[2], cfg.d_model, n, pd),
        "w_c": dense_init(ks[3], cfg.d_model, n, pd),
        "w_dtp": dense_init(ks[4], cfg.d_model, h, pd),
        "conv_w": (jax.random.normal(ks[5], (cfg.conv_width, conv_ch))
                   * 0.1).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), pd),
        "w_out": dense_init(ks[7], di, cfg.d_model, pd,
                            scale=cfg.residual_scale),
    }


def _conv_split(p, cfg: ModelConfig):
    """Per-stream views of the depthwise conv parameters."""
    di, n, _, _ = _dims(cfg)
    w, b = p["conv_w"], p["conv_b"]
    return ((w[:, :di], b[:di]),
            (w[:, di:di + n], b[di:di + n]),
            (w[:, di + n:], b[di + n:]))


def _causal_conv(x, w, b, *, width: int):
    """Depthwise causal conv over seq: x (B, S, C)."""
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    s = x.shape[1]
    out = sum(pad[:, j:j + s, :] * w[j][None, None, :] for j in range(width))
    return jax.nn.silu(out + b[None, None, :])


def ssm_apply(p, x, cfg: ModelConfig):
    dt_ = dtype_of(cfg)
    di, n, h, _ = _dims(cfg)
    b, s, _ = x.shape
    z = x @ p["w_z"].astype(dt_)
    xs = x @ p["w_xs"].astype(dt_)
    bmat = x @ p["w_b"].astype(dt_)
    cmat = x @ p["w_c"].astype(dt_)
    dt_raw = x @ p["w_dtp"].astype(dt_)

    (wx, bx), (wb, bb), (wc, bc) = _conv_split(p, cfg)
    xs = _causal_conv(xs, wx.astype(dt_), bx.astype(dt_),
                      width=cfg.conv_width)
    bmat = _causal_conv(bmat, wb.astype(dt_), bb.astype(dt_),
                        width=cfg.conv_width)
    cmat = _causal_conv(cmat, wc.astype(dt_), bc.astype(dt_),
                        width=cfg.conv_width)

    xh = xs.reshape(b, s, h, cfg.ssm_headdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    xh = constrain(xh, ("batch", "seq", "heads", None))

    from ..distributed import sharding as shd
    from ..distributed.sharding import axis_size
    from ..kernels.ssd import ssd_scan
    mesh = shd._ACTIVE_MESH.get()
    if cfg.ssd_shard_map and mesh is not None and axis_size("model") > 1:
        rules = shd.current_rules() or {}
        dp = rules.get("batch")
        dp_axes = (dp,) if isinstance(dp, str) else (dp or ())
        y = ssd_apply_shard_map(
            xh.astype(jnp.float32), dt, p["a_log"],
            bmat.astype(jnp.float32), cmat.astype(jnp.float32), cfg,
            mesh=mesh, dp_axes=dp_axes)
    else:
        # head blocks: keep the "model"-sharded head slice vectorized, loop
        # the rest (memory ~ per-chip heads x (nc, L, L); kernels/ssd/ref.py)
        hb = max(1, h // max(axis_size("model"), 1))
        y = ssd_scan(xh.astype(jnp.float32), dt, p["a_log"],
                     bmat.astype(jnp.float32), cmat.astype(jnp.float32),
                     chunk=cfg.ssm_chunk,
                     use_kernel=cfg.use_flash_kernel,
                     unroll_heads=cfg.attn_chunk_unroll,
                     head_blocks=hb)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = y @ p["w_out"].astype(dt_)
    return constrain(out, ("batch", "seq", "embed"))


def init_ssm_cache(cfg: ModelConfig, batch: int) -> Dict:
    di, n, h, conv_ch = _dims(cfg)
    dt_ = dtype_of(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dt_),
        "ssm": jnp.zeros((batch, h, n, cfg.ssm_headdim), jnp.float32),
    }


def ssm_decode(p, x, cache: Dict, pos, cfg: ModelConfig
               ) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, D) single-token step."""
    dt_ = dtype_of(cfg)
    di, n, h, conv_ch = _dims(cfg)
    b = x.shape[0]
    x0 = x[:, 0, :]
    z = x0 @ p["w_z"].astype(dt_)
    new = jnp.concatenate([x0 @ p["w_xs"].astype(dt_),
                           x0 @ p["w_b"].astype(dt_),
                           x0 @ p["w_c"].astype(dt_)], axis=-1)
    dt_raw = x0 @ p["w_dtp"].astype(dt_)

    hist = jnp.concatenate([cache["conv"], new[:, None, :]], axis=1)
    w = p["conv_w"].astype(dt_)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(dt_)
    xbc = jax.nn.silu(conv_out)

    xs = xbc[:, :di].reshape(b, h, cfg.ssm_headdim).astype(jnp.float32)
    bmat = xbc[:, di:di + n].astype(jnp.float32)
    cmat = xbc[:, di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])                                  # (H,)
    da = jnp.exp(dt * a[None, :])                             # (B, H)
    inc = dt[:, :, None, None] * bmat[:, None, :, None] * xs[:, :, None, :]
    ssm = da[:, :, None, None] * cache["ssm"] + inc           # (B,H,N,P)
    y = jnp.einsum("bn,bhnp->bhp", cmat, ssm)
    y = y + xs * p["d_skip"][None, :, None]
    y = y.reshape(b, di).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = (y @ p["w_out"].astype(dt_))[:, None, :]
    return out, {"conv": hist[:, 1:, :], "ssm": ssm}


# ---------------------------------------------------------------------------
# shard_map SSD path (§Perf hillclimb; cfg.ssd_shard_map).
#
# Everything the SSD needs is already per-shard local: x-heads shard over
# "model", batch over the DP axes, B/C replicated over "model".  Running the
# chunked scan inside shard_map means autodiff inserts exactly ONE psum per
# replicated input's gradient (dB, dC, dA) per layer — instead of GSPMD's
# per-head-block (B,nc,L,L)-sized backward all-reduces (measured 6.8e13
# collective bytes on mamba2 train_4k; see EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

def _ssd_local_body(xh, dt, a_log, bmat, cmat, *, chunk: int,
                    unroll_heads: bool, tile_dtype=None):
    from ..distributed.sharding import manual_region
    from ..kernels.ssd.ref import ssd_chunked_jnp
    # per-shard: all local heads vectorized in one block (no inner loop)
    with manual_region():
        return ssd_chunked_jnp(xh, dt, a_log, bmat, cmat, chunk=chunk,
                               unroll_heads=unroll_heads, head_blocks=1,
                               tile_dtype=tile_dtype)


def ssd_apply_shard_map(xh, dt, a_log, bmat, cmat, cfg: ModelConfig, *,
                        mesh, dp_axes, model_axis: str = "model"):
    """xh: (B,S,H,P) head-sharded; dt: (B,S,H); bmat/cmat: (B,S,N)."""
    import functools
    from jax.sharding import PartitionSpec as P

    from ..distributed.sharding import shard_map

    dp = tuple(dp_axes) if dp_axes else None
    body = functools.partial(
        _ssd_local_body, chunk=cfg.ssm_chunk,
        unroll_heads=cfg.attn_chunk_unroll,
        tile_dtype=jnp.bfloat16 if cfg.ssd_tile_bf16 else None)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp, None, model_axis, None),   # x heads sharded
                  P(dp, None, model_axis),          # dt heads sharded
                  P(model_axis,),                   # A_log per local head
                  P(dp, None, None),                # B replicated over model
                  P(dp, None, None)),               # C replicated over model
        out_specs=P(dp, None, model_axis, None),
        check_vma=False,
    )(xh, dt, a_log, bmat, cmat)

"""Shared layers: init helpers, RMSNorm, rotary embeddings, SwiGLU MLP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale * (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6, *, use_kernel: bool = False):
    if use_kernel:
        from ..kernels.rmsnorm import rmsnorm as k_rmsnorm
        return k_rmsnorm(x, scale, eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D_even); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, width: Optional[int] = None):
    width = width or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    pd = pdtype_of(cfg)
    return {
        "wg": dense_init(k1, cfg.d_model, width, pd),
        "wu": dense_init(k2, cfg.d_model, width, pd),
        "wd": dense_init(k3, width, cfg.d_model, pd,
                         scale=cfg.residual_scale),
    }


def mlp_apply(p, x, cfg: ModelConfig):
    dt = dtype_of(cfg)
    h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wu"].astype(dt))
    h = constrain(h, ("batch", "seq", "ffn"))
    out = h @ p["wd"].astype(dt)
    return constrain(out, ("batch", "seq", "embed"))


def softcap(x, cap: float):
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)

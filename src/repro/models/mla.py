"""Multi-head Latent Attention (DeepSeek-V3): KV compressed into a small
latent; cache stores (latent, shared rope-key) instead of full K/V."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .attention import NEG_INF
from .layers import apply_rope, dense_init, dtype_of, pdtype_of


def mla_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    pd = pdtype_of(cfg)
    hd, rd = cfg.head_dim, cfg.rope_head_dim
    p = {
        "w_dkv": dense_init(ks[0], cfg.d_model, cfg.kv_lora_rank + rd, pd),
        "w_uk": dense_init(ks[1], cfg.kv_lora_rank, cfg.n_heads * hd, pd),
        "w_uv": dense_init(ks[2], cfg.kv_lora_rank, cfg.n_heads * hd, pd),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, pd,
                         scale=cfg.residual_scale),
    }
    if cfg.q_lora_rank > 0:
        p["w_qa"] = dense_init(ks[4], cfg.d_model, cfg.q_lora_rank, pd)
        p["w_qb"] = dense_init(ks[5], cfg.q_lora_rank,
                               cfg.n_heads * (hd + rd), pd)
    else:
        p["wq"] = dense_init(ks[4], cfg.d_model, cfg.n_heads * (hd + rd), pd)
    return p


def _queries(p, x, cfg: ModelConfig, positions):
    dt = dtype_of(cfg)
    b, s, _ = x.shape
    hd, rd = cfg.head_dim, cfg.rope_head_dim
    if "w_qa" in p:
        q = (x @ p["w_qa"].astype(dt)) @ p["w_qb"].astype(dt)
    else:
        q = x @ p["wq"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(p, x, cfg: ModelConfig, positions):
    dt = dtype_of(cfg)
    ckv = x @ p["w_dkv"].astype(dt)
    latent, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return latent, k_rope


def _attend(q_nope, q_rope, latent, k_rope, p, cfg: ModelConfig, *,
            causal: bool, q_offset: int = 0, valid=None):
    dt = dtype_of(cfg)
    b, sq = q_nope.shape[:2]
    skv = latent.shape[1]
    hd = cfg.head_dim
    k = (latent @ p["w_uk"].astype(dt)).reshape(b, skv, cfg.n_heads, hd)
    v = (latent @ p["w_uv"].astype(dt)).reshape(b, skv, cfg.n_heads, hd)
    scale = (hd + cfg.rope_head_dim) ** -0.5
    s = (jnp.einsum("bqhd,bshd->bhqs", q_nope.astype(jnp.float32),
                    k.astype(jnp.float32))
         + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    if causal:
        q_ids = q_offset + jnp.arange(sq)[:, None]
        k_ids = jnp.arange(skv)[None, :]
        s = jnp.where((k_ids <= q_ids)[None, None], s, NEG_INF)
    if valid is not None:
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pbar = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", pbar, v.astype(jnp.float32))
    return o.reshape(b, sq, cfg.n_heads * hd).astype(dt)


def mla_apply(p, x, cfg: ModelConfig, *, positions=None):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope = _queries(p, x, cfg, positions)
    latent, k_rope = _latent_kv(p, x, cfg, positions)
    latent = constrain(latent, ("batch", "seq", None))
    o = _attend(q_nope, q_rope, latent, k_rope, p, cfg, causal=True)
    return o @ p["wo"].astype(dtype_of(cfg))


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    dt = dtype_of(cfg)
    return {
        "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dt),
    }


def mla_decode(p, x, cache: Dict, pos, cfg: ModelConfig
               ) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    posv = jnp.full((b, 1), pos)
    q_nope, q_rope = _queries(p, x, cfg, posv)
    lat_new, kr_new = _latent_kv(p, x, cfg, posv)
    latent = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], lat_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new, pos, axis=1)
    valid = jnp.arange(latent.shape[1]) <= pos
    o = _attend(q_nope, q_rope, latent, k_rope, p, cfg, causal=False,
                valid=valid)
    out = o @ p["wo"].astype(dtype_of(cfg))
    return out, {"latent": latent, "k_rope": k_rope}

"""Unified language model: pattern-scanned decoder (+ optional encoder /
modality memory), with train forward, loss, prefill and one-token decode.

Layers are stored STACKED (leading dim = n_groups) and executed with
jax.lax.scan so compile time is independent of depth; remat policy wraps
the per-group apply.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .blocks import REGISTRY
from .layers import dtype_of, embed_init, pdtype_of, rmsnorm


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def _group_init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, len(cfg.pattern))
        return {f"b{i}": REGISTRY[kind].init(keys[i], cfg)
                for i, kind in enumerate(cfg.pattern)}

    def init(self, key) -> Dict:
        cfg = self.cfg
        k_embed, k_groups, k_head, k_enc, k_mtp, k_pre = \
            jax.random.split(key, 6)
        pd = pdtype_of(cfg)
        params: Dict[str, Any] = {
            "tok_embed": embed_init(k_embed, cfg.vocab, cfg.d_model, pd),
            "final_norm": jnp.ones((cfg.d_model,), pd),
        }
        group_keys = jax.random.split(k_groups, cfg.n_groups)
        params["groups"] = jax.vmap(self._group_init)(group_keys)
        if cfg.first_dense > 0:
            pre_keys = jax.random.split(k_pre, cfg.first_dense)
            params["prefix"] = jax.vmap(
                lambda k: REGISTRY["attn"].init(k, cfg.replace(
                    pattern=("attn",))))(pre_keys)
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(k_head, cfg.d_model,
                                           cfg.vocab, pd).T.copy() \
                if False else (jax.random.normal(
                    k_head, (cfg.d_model, cfg.vocab)) * 0.02).astype(pd)
        if cfg.enc_layers > 0:
            enc_keys = jax.random.split(k_enc, cfg.enc_layers)
            params["encoder"] = {
                "blocks": jax.vmap(
                    lambda k: REGISTRY["enc_attn"].init(k, cfg))(enc_keys),
                "final_norm": jnp.ones((cfg.d_model,), pd),
            }
        if cfg.mtp_depth > 0:
            km1, km2 = jax.random.split(k_mtp)
            params["mtp"] = {
                "proj": (jax.random.normal(km1, (2 * cfg.d_model,
                                                 cfg.d_model))
                         * (2 * cfg.d_model) ** -0.5).astype(pd),
                "block": REGISTRY["attn"].init(
                    km2, cfg.replace(pattern=("attn",))),
                "norm_h": jnp.ones((cfg.d_model,), pd),
                "norm_e": jnp.ones((cfg.d_model,), pd),
            }
        return params

    # -------------------------------------------------------------- forward
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["tok_embed"], tokens, axis=0).astype(
            dtype_of(cfg))
        return constrain(x, ("batch", "seq", "embed"))

    def _logits(self, params, x):
        cfg = self.cfg
        head = (params["tok_embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(dtype_of(cfg))
        logits = (x @ head) * cfg.logit_scale
        return constrain(logits, ("batch", "seq", "vocab"))

    def _group_apply(self, gparams, x, memory):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.pattern):
            x, a = REGISTRY[kind].apply(gparams[f"b{i}"], x, cfg,
                                        memory=memory)
            aux = aux + a
        return x, aux

    def _run_groups(self, params, x, memory):
        cfg = self.cfg
        apply = self._group_apply
        if cfg.remat != "none":
            policy = (jax.checkpoint_policies.nothing_saveable
                      if cfg.remat == "full"
                      else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            apply = jax.checkpoint(apply, policy=policy,
                                   static_argnums=())
        if cfg.scan_layers:
            def body(carry, gparams):
                h, aux = carry
                h, a = apply(gparams, h, memory)
                return (h, aux + a), None
            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       params["groups"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for g in range(cfg.n_groups):
                gp = jax.tree.map(lambda a: a[g], params["groups"])
                x, a = apply(gp, x, memory)
                aux = aux + a
        return x, aux

    def _run_prefix(self, params, x):
        cfg = self.cfg
        if cfg.first_dense == 0:
            return x
        dense_cfg = cfg.replace(pattern=("attn",))

        if cfg.scan_layers:
            def body(h, bparams):
                h, _ = REGISTRY["attn"].apply(bparams, h, dense_cfg)
                return h, None
            x, _ = jax.lax.scan(body, x, params["prefix"])
        else:
            for i in range(cfg.first_dense):
                bp = jax.tree.map(lambda a: a[i], params["prefix"])
                x, _ = REGISTRY["attn"].apply(bp, x, dense_cfg)
        return x

    def _encode(self, params, frame_embeds):
        cfg = self.cfg
        x = frame_embeds.astype(dtype_of(cfg))

        if cfg.scan_layers:
            def body(h, bparams):
                h, _ = REGISTRY["enc_attn"].apply(bparams, h, cfg)
                return h, None
            x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        else:
            for i in range(cfg.enc_layers):
                bp = jax.tree.map(lambda a: a[i],
                                  params["encoder"]["blocks"])
                x, _ = REGISTRY["enc_attn"].apply(bp, x, cfg)
        return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    def forward(self, params, tokens, *, memory_embeds=None
                ) -> Tuple[jax.Array, jax.Array]:
        """tokens: (B, S) -> (logits (B, S, V), aux_loss scalar).

        memory_embeds: (B, M, D) stub frontend output (audio frames /
        image patches) for audio/vlm families; encoder runs here for
        enc-dec models.
        """
        cfg = self.cfg
        memory = None
        if cfg.enc_layers > 0:
            assert memory_embeds is not None, "enc-dec model needs frames"
            memory = self._encode(params, memory_embeds)
        elif memory_embeds is not None:
            memory = memory_embeds.astype(dtype_of(cfg))

        x = self._embed(params, tokens)
        x = self._run_prefix(params, x)
        x, aux = self._run_groups(params, x, memory)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x), aux

    # ----------------------------------------------------------------- loss
    def loss_fn(self, params, batch: Dict) -> Tuple[jax.Array, Dict]:
        """batch: tokens (B,S), labels (B,S) (-100 = ignore), optional
        memory_embeds."""
        cfg = self.cfg
        trunk = None
        if cfg.mtp_depth > 0 and cfg.mtp_share_trunk:
            # §Perf: compute the trunk ONCE; head + MTP both read it
            memory = None
            if batch.get("memory_embeds") is not None:
                memory = batch["memory_embeds"].astype(dtype_of(cfg))
            x = self._embed(params, batch["tokens"])
            x = self._run_prefix(params, x)
            trunk, aux = self._run_groups(params, x, memory)
            logits = self._logits(
                params, rmsnorm(trunk, params["final_norm"], cfg.norm_eps))
        else:
            logits, aux = self.forward(
                params, batch["tokens"],
                memory_embeds=batch.get("memory_embeds"))
        labels = batch["labels"]
        valid = labels >= 0
        safe = jnp.where(valid, labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(valid), 1)
        xent = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
        metrics = {"xent": xent, "aux": aux}
        loss = xent + aux

        if cfg.mtp_depth > 0:
            loss = loss + 0.3 * self._mtp_loss(params, batch, metrics,
                                               trunk=trunk)
        return loss, metrics

    def _mtp_loss(self, params, batch, metrics, trunk=None) -> jax.Array:
        """DeepSeek-V3 multi-token prediction: predict t+2 from a fused
        (h_t, emb_{t+1}) stream through one extra block."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        if trunk is None:
            # hidden states (pre-head) for the main stream
            x = self._embed(params, tokens)
            x = self._run_prefix(params, x)
            x, _ = self._run_groups(params, x, None)
        else:
            x = trunk
        h = rmsnorm(x, params["mtp"]["norm_h"], cfg.norm_eps)
        e_next = rmsnorm(self._embed(params, jnp.roll(tokens, -1, axis=1)),
                         params["mtp"]["norm_e"], cfg.norm_eps)
        fused = jnp.concatenate([h, e_next], axis=-1) \
            @ params["mtp"]["proj"].astype(dtype_of(cfg))
        fused, _ = REGISTRY["attn"].apply(params["mtp"]["block"], fused,
                                          cfg.replace(pattern=("attn",)))
        logits = self._logits(params, fused)
        mtp_labels = jnp.roll(labels, -1, axis=1)
        valid = mtp_labels >= 0
        valid = valid.at[:, -2:].set(False)
        safe = jnp.where(valid, mtp_labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        mtp = jnp.sum(jnp.where(valid, nll, 0.0)) \
            / jnp.maximum(jnp.sum(valid), 1)
        metrics["mtp"] = mtp
        return mtp

    # --------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int, *, abstract: bool = False
                   ) -> Dict:
        cfg = self.cfg

        def group_cache():
            return {f"b{i}": REGISTRY[kind].cache(cfg, batch, max_len)
                    for i, kind in enumerate(cfg.pattern)}

        proto = jax.eval_shape(group_cache)
        stack = (lambda a: jax.ShapeDtypeStruct((cfg.n_groups,) + a.shape,
                                                a.dtype)) if abstract else \
                (lambda a: jnp.zeros((cfg.n_groups,) + a.shape, a.dtype))
        cache: Dict[str, Any] = {"groups": jax.tree.map(stack, proto)}
        if cfg.first_dense > 0:
            pre = jax.eval_shape(
                lambda: REGISTRY["attn"].cache(cfg, batch, max_len))
            stack_p = (lambda a: jax.ShapeDtypeStruct(
                (cfg.first_dense,) + a.shape, a.dtype)) if abstract else \
                (lambda a: jnp.zeros((cfg.first_dense,) + a.shape, a.dtype))
            cache["prefix"] = jax.tree.map(stack_p, pre)
        return cache

    def decode_step(self, params, cache: Dict, tokens, pos, *,
                    memory_embeds=None) -> Tuple[jax.Array, Dict]:
        """tokens: (B, 1); pos: scalar int32 -> (logits (B, V), new cache)."""
        cfg = self.cfg
        memory = None
        if cfg.enc_layers > 0:
            assert memory_embeds is not None
            memory = self._encode(params, memory_embeds)
        elif memory_embeds is not None:
            memory = memory_embeds.astype(dtype_of(cfg))

        x = self._embed(params, tokens)
        new_cache: Dict[str, Any] = {}

        if cfg.first_dense > 0:
            dense_cfg = cfg.replace(pattern=("attn",))

            def pre_body(h, inp):
                bp, bc = inp
                h, nc = REGISTRY["attn"].decode(bp, h, bc, pos, dense_cfg)
                return h, nc

            if cfg.scan_layers:
                x, new_cache["prefix"] = jax.lax.scan(
                    pre_body, x, (params["prefix"], cache["prefix"]))
            else:
                ncs = []
                for i in range(cfg.first_dense):
                    inp = jax.tree.map(lambda a: a[i],
                                       (params["prefix"], cache["prefix"]))
                    x, nc = pre_body(x, inp)
                    ncs.append(nc)
                new_cache["prefix"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *ncs)

        def body(h, inp):
            gp, gc = inp
            ncs = {}
            for i, kind in enumerate(cfg.pattern):
                h, nc = REGISTRY[kind].decode(gp[f"b{i}"], h, gc[f"b{i}"],
                                              pos, cfg, memory=memory)
                ncs[f"b{i}"] = nc
            return h, ncs

        if cfg.scan_layers:
            x, new_cache["groups"] = jax.lax.scan(
                body, x, (params["groups"], cache["groups"]))
        else:
            ncs = []
            for g in range(cfg.n_groups):
                inp = jax.tree.map(lambda a: a[g],
                                   (params["groups"], cache["groups"]))
                x, nc = body(x, inp)
                ncs.append(nc)
            new_cache["groups"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *ncs)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)[:, 0, :]
        return logits, new_cache

    def prefill(self, params, tokens, cache: Dict, *, memory_embeds=None):
        """Sequential prefill through decode_step (exactness over speed;
        the dry-run lowers ``forward`` for prefill compute instead)."""
        s = tokens.shape[1]

        def body(carry, t):
            cache, last = carry
            logits, cache = self.decode_step(
                params, cache, tokens[:, t][:, None], t,
                memory_embeds=memory_embeds)
            return (cache, logits), None

        (cache, logits), _ = jax.lax.scan(
            body, (cache, jnp.zeros((tokens.shape[0], self.cfg.vocab),
                                    jnp.float32)),
            jnp.arange(s))
        return logits, cache

    # ----------------------------------------------------------- analytics
    def param_count(self, params) -> int:
        return sum(int(x.size) for x in jax.tree.leaves(params))


def build(cfg: ModelConfig) -> LanguageModel:
    return LanguageModel(cfg)

"""Attention variants: GQA (full / sliding-window / chunked), decode-with-
cache, and cross-attention.  Sharding-friendly einsum formulation with
optional Pallas flash kernel."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .layers import apply_rope, dense_init, dtype_of, pdtype_of, softcap

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pd = pdtype_of(cfg)
    hd = cfg.head_dim
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, pd),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, pd),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, pd),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, pd,
                         scale=cfg.residual_scale),
    }


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd)


def _mask(sq: int, skv: int, *, causal: bool, window: int,
          q_offset: int = 0):
    q_ids = q_offset + jnp.arange(sq)[:, None]
    k_ids = jnp.arange(skv)[None, :]
    m = jnp.ones((sq, skv), dtype=bool)
    if causal:
        m &= k_ids <= q_ids
    if window > 0:
        m &= k_ids >= q_ids - window
    return m


def _sdpa(q, k, v, *, scale: float, causal: bool, window: int,
          logit_cap: float, q_offset: int = 0):
    """q: (B,Sq,H,hd); k,v: (B,Skv,Hkv,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = softcap(s, logit_cap)
    mask = _mask(sq, k.shape[1], causal=causal, window=window,
                 q_offset=q_offset)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def _sdpa_chunked(q, k, v, *, scale: float, causal: bool, window: int,
                  logit_cap: float, chunk: int, unroll: bool = False):
    """Flash-in-XLA: scan over query chunks; never materializes (Sq, Skv)
    for all queries at once.  Memory per step: (B,H,chunk,Skv).

    unroll=True inlines the chunk loop (dry-run accounting: XLA
    cost_analysis counts while-loop bodies once)."""
    b, sq, h, hd = q.shape
    assert sq % chunk == 0, (sq, chunk)
    nq = sq // chunk
    qc = q.reshape(b, nq, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    if unroll:
        outs = [
            _sdpa(qc[i], k, v, scale=scale, causal=causal, window=window,
                  logit_cap=logit_cap, q_offset=i * chunk)
            for i in range(nq)
        ]
        out = jnp.stack(outs, axis=0)
        return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)

    def step(carry, inp):
        i, qi = inp
        o = _sdpa(qi, k, v, scale=scale, causal=causal, window=window,
                  logit_cap=logit_cap, q_offset=i * chunk)
        return carry, o

    _, outs = jax.lax.scan(step, 0, (jnp.arange(nq), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attn_apply(p, x, cfg: ModelConfig, *, positions=None,
               causal: bool = True, window: int = 0,
               kv_override: Optional[Tuple] = None):
    """Training/prefill attention.  kv_override supplies encoder KV for
    cross-attention (k_in, v_in already projected inputs)."""
    dt = dtype_of(cfg)
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = _split_heads(x @ p["wq"].astype(dt), cfg.n_heads, hd)
    if kv_override is None:
        k = _split_heads(x @ p["wk"].astype(dt), cfg.n_kv_heads, hd)
        v = _split_heads(x @ p["wv"].astype(dt), cfg.n_kv_heads, hd)
    else:
        src = kv_override
        k = _split_heads(src @ p["wk"].astype(dt), cfg.n_kv_heads, hd)
        v = _split_heads(src @ p["wv"].astype(dt), cfg.n_kv_heads, hd)
    if positions is None:
        positions = jnp.arange(s)
    if kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if k.shape[1] == s
                       else jnp.arange(k.shape[1]), cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "heads", None))
    scale = hd ** -0.5

    if cfg.use_flash_kernel and kv_override is None and s % 128 == 0:
        from ..kernels.flash_attention import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), sm_scale=scale,
                            causal=causal, window=window)
        o = o.transpose(0, 2, 1, 3)
    elif cfg.attn_chunk > 0 and s > cfg.attn_chunk and s % cfg.attn_chunk == 0:
        o = _sdpa_chunked(q, k, v, scale=scale, causal=causal, window=window,
                          logit_cap=cfg.attn_logit_softcap,
                          chunk=cfg.attn_chunk,
                          unroll=cfg.attn_chunk_unroll)
    else:
        o = _sdpa(q, k, v, scale=scale, causal=causal, window=window,
                  logit_cap=cfg.attn_logit_softcap)
    o = constrain(o, ("batch", "seq", "heads", None))
    out = o.reshape(b, s, cfg.n_heads * hd) @ p["wo"].astype(dt)
    return constrain(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  *, window: int = 0) -> Dict:
    """Linear cache for full attention; ring cache of size `window + 1` for
    SWA (the mask k >= q - window keeps window+1 keys including the current
    token; keeps long_500k SWA decode memory at O(window))."""
    size = min(window + 1, max_len) if window > 0 else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    dt = dtype_of(cfg)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_attn_apply(p, x, cache: Dict, pos, cfg: ModelConfig, *,
                      window: int = 0):
    """One-token decode.  x: (B, 1, D); pos: scalar int32 (same for the
    whole batch); returns (out, new_cache)."""
    dt = dtype_of(cfg)
    b = x.shape[0]
    hd = cfg.head_dim
    q = _split_heads(x @ p["wq"].astype(dt), cfg.n_heads, hd)
    k_new = _split_heads(x @ p["wk"].astype(dt), cfg.n_kv_heads, hd)
    v_new = _split_heads(x @ p["wv"].astype(dt), cfg.n_kv_heads, hd)
    posv = jnp.full((b, 1), pos)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = pos % size if window > 0 else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    hkv = cfg.n_kv_heads
    group = cfg.n_heads // hkv
    qg = q.reshape(b, hkv, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    s = softcap(s, cfg.attn_logit_softcap)

    slots = jnp.arange(size)
    if window > 0:
        # ring buffer: slot holds absolute position p iff p = pos - ((slot_now
        # - slot) mod size); valid iff p >= 0 and p > pos - window... all ring
        # entries are within the window by construction once warm.
        age = (slot - slots) % size
        abs_pos = pos - age
        valid = (abs_pos >= 0) & (age < size)
    else:
        valid = slots <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pbar = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", pbar, v.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads * hd).astype(dt)
    out = o @ p["wo"].astype(dt)
    return out, {"k": k, "v": v}

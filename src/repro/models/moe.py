"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Dispatch avoids the (T, E, C) one-hot tensors of GShard-style einsum MoE:
  1. router top-k per token,
  2. rank within each expert via cumsum over the token dim (exclusive),
  3. capacity-clipped scatter into an (E*C, D) buffer,
  4. batched per-expert SwiGLU einsum (experts dim shards over "model"),
  5. gather-back weighted by normalized gates (dropped tokens contribute 0
     and fall through on the residual path).

Aux load-balancing loss per Switch/GShard: E * sum_e f_e * p_e.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed import sharding as shd
from ..distributed.sharding import constrain
from .layers import dense_init, dtype_of, pdtype_of


def moe_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    pd = pdtype_of(cfg)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_ff
    std = d ** -0.5
    p = {
        "w_router": dense_init(ks[0], d, e, jnp.float32),
        "we_g": (jax.random.normal(ks[1], (e, d, f)) * std).astype(pd),
        "we_u": (jax.random.normal(ks[2], (e, d, f)) * std).astype(pd),
        "we_d": (jax.random.normal(ks[3], (e, f, d)) * std
               * cfg.residual_scale).astype(pd),
    }
    if cfg.n_shared_experts > 0:
        width = cfg.expert_ff * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": dense_init(kk[0], d, width, pd),
            "wu": dense_init(kk[1], d, width, pd),
            "wd": dense_init(kk[2], width, d, pd, scale=cfg.residual_scale),
        }
    return p


def moe_apply(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).  Routes to the shard_map
    expert-parallel path when a mesh with a >1 "model" axis is active."""
    mesh = shd._ACTIVE_MESH.get()
    if mesh is not None and shd.axis_size("model") > 1:
        rules = shd.current_rules() or {}
        dp = rules.get("batch")
        dp_axes = (dp,) if isinstance(dp, str) else (dp or ())
        return moe_apply_sharded(p, x, cfg, mesh=mesh, dp_axes=dp_axes)
    return _moe_apply_gspmd(p, x, cfg)


def _moe_apply_gspmd(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    dt = dtype_of(cfg)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    # capacity exists for load-balance memory bounds at scale; for small
    # token counts (decode steps, smoke tests) drops would be an artifact,
    # so floor at 8 slots (or the no-drop bound t*k when even smaller).
    cap = min(t * k, max(int(cfg.capacity_factor * t * k / e), 8))

    xt = x.reshape(t, d)
    xt = constrain(xt, ("batch", None))
    logits = constrain(xt.astype(jnp.float32) @ p["w_router"],
                       ("batch", None))                      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux loss: fraction routed vs mean prob, per expert
    onehot_all = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (T,k,E)
    f_e = jnp.mean(jnp.sum(onehot_all, axis=1), axis=0)      # (E,)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(f_e * p_e)

    # rank within expert via stable sort (the (T*k, E) one-hot cumsum
    # alternative costs O(T*k*E) memory traffic and lowers to a serial
    # reduce-window; sort is O(n log n) and shards cleanly)
    flat_e = expert_ids.reshape(-1)                          # (T*k,)
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e))  # (E,)
    rank_sorted = jnp.arange(t * k) - group_start[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = rank < cap

    # shard expert compute over BOTH axes: experts (EP) on "model", token
    # slots on the DP axes — otherwise data-ranks within a model group
    # redundantly compute the same expert block (16x wasted flops, found
    # via the dry-run useful-flops ratio).  The capacity buffer is sharded
    # FROM CREATION; over-capacity assignments fall off via mode="drop".
    ebuf0 = constrain(jnp.zeros((e, cap, d), dt),
                      ("experts", "batch", None))
    ebuf = ebuf0.at[flat_e, rank].set(xt[flat_tok].astype(dt),
                                      mode="drop")
    ebuf = constrain(ebuf, ("experts", "batch", None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, p["we_g"].astype(dt))) \
        * jnp.einsum("ecd,edf->ecf", ebuf, p["we_u"].astype(dt))
    h = constrain(h, ("experts", "batch", None))
    y = jnp.einsum("ecf,efd->ecd", h, p["we_d"].astype(dt))
    y = constrain(y, ("experts", "batch", None))

    contrib = jnp.where(
        keep[:, None],
        y[flat_e, jnp.minimum(rank, cap - 1)] * flat_g[:, None].astype(dt),
        0.0)
    contrib = constrain(contrib, ("batch", None))
    out0 = constrain(jnp.zeros((t, d), dt), ("batch", None))
    out = out0.at[flat_tok].add(contrib)

    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["wg"].astype(dt)) * (xt @ sp["wu"].astype(dt))
        out = out + hs @ sp["wd"].astype(dt)
    return out.reshape(b, s, d), aux


def moe_apply_reference(p, x, cfg: ModelConfig) -> jax.Array:
    """Dense loop-over-experts oracle (no capacity drops) for tests."""
    dt = dtype_of(cfg)
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["w_router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for ei in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["we_g"][ei].astype(dt)) \
            * (xt @ p["we_u"][ei].astype(dt))
        ye = h @ p["we_d"][ei].astype(dt)
        w = jnp.sum(jnp.where(expert_ids == ei, gate_vals, 0.0), axis=-1)
        out = out + ye * w[:, None].astype(dt)
    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["wg"].astype(dt)) * (xt @ sp["wu"].astype(dt))
        out = out + hs @ sp["wd"].astype(dt)
    return out.reshape(b, s, d)


# ---------------------------------------------------------------------------
# shard_map expert-parallel path (DESIGN.md §5).
#
# On a (pod, data, model) mesh, activations are replicated across "model",
# so MoE dispatch needs NO token all-to-all: each model rank extracts the
# tokens routed to ITS experts (local gather + capacity scatter), runs the
# expert FFN locally, and the per-rank partial outputs are psum'd over
# "model".  Communication per layer = one (T_local, D) all-reduce — GSPMD's
# auto-partitioned scatter for the same computation replicated the capacity
# buffers instead (354 GB/chip temp, 7.5e16 collective bytes; see
# EXPERIMENTS.md §Dry-run).
# ---------------------------------------------------------------------------

def _moe_dispatch_local(xt, gate_vals, expert_ids, we_g, we_u, we_d, *,
                        cap_local: int, model_axis: str, dt):
    """Per-shard body. xt: (T_loc, D); we_*: (E_loc, D, F)."""
    t_loc, d = xt.shape
    e_loc = we_g.shape[0]
    k = expert_ids.shape[-1]
    rank_id = jax.lax.axis_index(model_axis)
    my_lo = rank_id * e_loc

    local_ids = expert_ids.reshape(-1) - my_lo               # (T_loc*k,)
    mine = (local_ids >= 0) & (local_ids < e_loc)
    flat_e = jnp.where(mine, local_ids, e_loc)               # sentinel last
    flat_g = jnp.where(mine, gate_vals.reshape(-1), 0.0)
    flat_tok = jnp.repeat(jnp.arange(t_loc), k)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1))
    rank_sorted = jnp.arange(t_loc * k) - group_start[sorted_e]
    rank = jnp.zeros((t_loc * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = mine & (rank < cap_local)

    ebuf = jnp.zeros((e_loc, cap_local, d), dt).at[
        jnp.where(keep, flat_e, e_loc),           # OOB expert -> dropped
        rank].set(xt[flat_tok].astype(dt), mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, we_g.astype(dt))) \
        * jnp.einsum("ecd,edf->ecf", ebuf, we_u.astype(dt))
    y = jnp.einsum("ecf,efd->ecd", h, we_d.astype(dt))

    contrib = jnp.where(
        keep[:, None],
        y[jnp.minimum(flat_e, e_loc - 1), jnp.minimum(rank, cap_local - 1)]
        * flat_g[:, None].astype(dt),
        0.0)
    out = jnp.zeros((t_loc, d), dt).at[flat_tok].add(contrib)
    return jax.lax.psum(out, model_axis)


def moe_apply_sharded(p, x, cfg: ModelConfig, *, mesh, dp_axes,
                      model_axis: str = "model"):
    """Expert-parallel MoE via shard_map (router/aux stay GSPMD-global)."""
    from jax.sharding import PartitionSpec as P

    dt = dtype_of(cfg)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    model_size = int(dict(mesh.shape).get(model_axis, 1))
    dp_size = 1
    for a in (dp_axes or ()):
        dp_size *= int(dict(mesh.shape).get(a, 1))
    t_loc = t // max(dp_size, 1)
    cap_local = min(t_loc * k,
                    max(int(cfg.capacity_factor * t_loc * k / e), 8))

    xt = constrain(x.reshape(t, d), ("batch", None))
    logits = constrain(xt.astype(jnp.float32) @ p["w_router"],
                       ("batch", None))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    onehot_all = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)
    f_e = jnp.mean(jnp.sum(onehot_all, axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(f_e * p_e)

    dp = tuple(dp_axes) if dp_axes else None
    body = functools.partial(_moe_dispatch_local, cap_local=cap_local,
                             model_axis=model_axis, dt=dt)
    from ..distributed.sharding import shard_map
    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=P(dp, None),
    )(xt, gate_vals, expert_ids, p["we_g"], p["we_u"], p["we_d"])

    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["wg"].astype(dt)) * (xt @ sp["wu"].astype(dt))
        out = out + hs @ sp["wd"].astype(dt)
    return out.reshape(b, s, d), aux

"""RG-LRU recurrent block (RecurrentGemma): conv1d + real-gated linear
recurrent unit, with associative-scan training path and O(1) decode.

    r_t = sigmoid(blockdiag(W_r) x_t)          recurrence gate
    i_t = sigmoid(blockdiag(W_i) x_t)          input gate
    a_t = exp(-c * softplus(Lambda) * r_t)     per-channel decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .layers import dense_init, dtype_of, pdtype_of

RGLRU_C = 8.0
N_GATE_BLOCKS = 8


def _width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def rglru_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    pd = pdtype_of(cfg)
    w = _width(cfg)
    bs = w // N_GATE_BLOCKS
    # Lambda init so decay a ~ U(0.9, 0.999) at r=0.5
    lam = jax.random.uniform(ks[4], (w,), minval=2.0, maxval=6.0)
    return {
        "wx": dense_init(ks[0], cfg.d_model, w, pd),      # x branch
        "wy": dense_init(ks[1], cfg.d_model, w, pd),      # gate branch
        "conv_w": (jax.random.normal(ks[5], (4, w)) * 0.1).astype(pd),
        "conv_b": jnp.zeros((w,), pd),
        "w_gates": (jax.random.normal(ks[2], (2, N_GATE_BLOCKS, bs, bs))
                    * bs ** -0.5).astype(pd),
        "b_gates": jnp.zeros((2, w), pd),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(ks[3], w, cfg.d_model, pd,
                            scale=cfg.residual_scale),
    }


def _gates(p, x):
    """x: (..., W) -> (r, i) each (..., W) via block-diagonal projections."""
    shp = x.shape
    w = shp[-1]
    bs = w // N_GATE_BLOCKS
    xb = x.reshape(*shp[:-1], N_GATE_BLOCKS, bs)
    g = jnp.einsum("...nb,gnbc->g...nc", xb.astype(jnp.float32),
                   p["w_gates"].astype(jnp.float32))
    g = g.reshape(2, *shp[:-1], w) + p["b_gates"].astype(
        jnp.float32).reshape(2, *([1] * (len(shp) - 1)), w)
    r, i = jax.nn.sigmoid(g[0]), jax.nn.sigmoid(g[1])
    return r, i


def _decay(p, r):
    return jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"]) * r)


def _conv(x, w, b):
    pad = jnp.pad(x, ((0, 0), (w.shape[0] - 1, 0), (0, 0)))
    s = x.shape[1]
    out = sum(pad[:, j:j + s, :] * w[j][None, None, :]
              for j in range(w.shape[0]))
    return out + b[None, None, :]


def rglru_apply(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D)."""
    dt = dtype_of(cfg)
    xb = x @ p["wx"].astype(dt)                    # (B, S, W)
    gate = jax.nn.gelu(x @ p["wy"].astype(dt))
    xb = _conv(xb, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    r, i = _gates(p, xb)
    a = _decay(p, r)                               # (B, S, W)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    u = beta * i * xb.astype(jnp.float32)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    h = constrain(h.astype(dt), ("batch", "seq", "ffn"))
    out = (h * gate) @ p["w_out"].astype(dt)
    return constrain(out, ("batch", "seq", "embed"))


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict:
    w = _width(cfg)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), dtype_of(cfg)),
    }


def rglru_decode(p, x, cache: Dict, pos, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    dt = dtype_of(cfg)
    xb = (x @ p["wx"].astype(dt))[:, 0, :]         # (B, W)
    gate = jax.nn.gelu(x @ p["wy"].astype(dt))[:, 0, :]
    hist = jnp.concatenate([cache["conv"], xb[:, None, :]], axis=1)
    conv = jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(dt)) \
        + p["conv_b"].astype(dt)
    r, i = _gates(p, conv)
    a = _decay(p, r)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    h = a * cache["h"] + beta * i * conv.astype(jnp.float32)
    out = ((h.astype(dt) * gate) @ p["w_out"].astype(dt))[:, None, :]
    return out, {"h": h, "conv": hist[:, 1:, :]}

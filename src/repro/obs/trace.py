"""Trace ids, span records, and the slow-request log.

A trace id is 16 lowercase hex characters (64 random bits), minted
once per logical client request and carried:

* over HTTP in the ``X-Repro-Trace`` header (:data:`TRACE_HEADER`);
* over both transports in the codec request meta as an *additive*
  ``trace_id`` field (binary framing v1 is untouched; v1 payloads
  without the field still decode).

Spans are lightweight completed-interval records (monotonic start,
duration, small attribute dict) kept in a bounded process-global ring
so tests and the demo can ask "which spans did trace X produce?"
without an external collector.  Recording honours the metrics kill
switch (``metrics.set_enabled(False)`` silences spans too).
"""
from __future__ import annotations

import json
import os
import random
import re
import sys
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Callable, Dict, List, NamedTuple, Optional

from . import metrics

__all__ = [
    "TRACE_HEADER", "new_trace_id", "is_trace_id", "coerce_trace_id",
    "Span", "record_span", "recent_spans", "clear_spans", "span",
    "slow_log",
]

TRACE_HEADER = "X-Repro-Trace"

_TRACE_RE = re.compile(r"^[0-9a-f]{16}$")
_RNG = random.Random(int.from_bytes(os.urandom(8), "big"))
_RNG_LOCK = threading.Lock()


def new_trace_id() -> str:
    """A fresh 16-hex trace id (64 random bits)."""
    with _RNG_LOCK:
        return "%016x" % _RNG.getrandbits(64)


def is_trace_id(s) -> bool:
    return isinstance(s, str) and bool(_TRACE_RE.match(s))


def coerce_trace_id(value) -> Optional[str]:
    """A valid trace id or None — never raises on hostile input."""
    if isinstance(value, str):
        v = value.strip().lower()
        if _TRACE_RE.match(v):
            return v
    return None


class Span(NamedTuple):
    """One completed interval attributed to a trace."""

    name: str
    trace_id: str
    start_s: float          # time.monotonic() at entry
    duration_s: float
    attrs: Dict


_SPANS_MAX = 4096
#: deque appends are thread-safe and maxlen evicts in C — the record
#: path takes no lock; readers snapshot with a retry loop because
#: list(deque) raises RuntimeError if it races a concurrent append
_SPANS: deque = deque(maxlen=_SPANS_MAX)


def record_span(name: str, trace_id: Optional[str], duration_s: float,
                start_s: Optional[float] = None, **attrs) -> Optional[Span]:
    """Append a completed span to the ring; no-op without a trace id."""
    if not trace_id or not metrics.REGISTRY.enabled:
        return None
    if start_s is None:
        start_s = time.monotonic() - duration_s
    sp = Span(name, trace_id, start_s, duration_s, attrs)
    _SPANS.append(sp)
    return sp


def recent_spans(trace_id: Optional[str] = None,
                 name: Optional[str] = None) -> List[Span]:
    while True:
        try:
            out = list(_SPANS)
            break
        except RuntimeError:        # lost a race with an append
            continue
    if trace_id is not None:
        out = [s for s in out if s.trace_id == trace_id]
    if name is not None:
        out = [s for s in out if s.name == name]
    return out


def clear_spans() -> None:
    _SPANS.clear()


@contextmanager
def span(name: str, trace_id: Optional[str], **attrs):
    """``with span("client.attempt", tid): ...`` records on exit."""
    t0 = time.monotonic()
    try:
        yield
    finally:
        record_span(name, trace_id, time.monotonic() - t0,
                    start_s=t0, **attrs)


def slow_log(record: Dict,
             sink: Optional[Callable[[str], None]] = None) -> str:
    """Emit one structured slow-request line (JSON, sorted keys).

    The default sink writes to stderr.  Returns the serialized line so
    callers/tests can capture it without a sink.
    """
    line = json.dumps(record, sort_keys=True, separators=(",", ":"),
                      default=str)
    if sink is not None:
        sink(line)
    else:
        print(line, file=sys.stderr, flush=True)
    return line


def _reinit_after_fork_in_child() -> None:
    global _RNG_LOCK, _RNG
    _RNG_LOCK = threading.Lock()
    # re-seed so forked children don't mint identical trace ids
    _RNG = random.Random(int.from_bytes(os.urandom(8), "big"))


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reinit_after_fork_in_child)

"""Dependency-free observability substrate for the repro stack.

Two modules, stdlib only:

* :mod:`repro.obs.metrics` — thread-safe Counter / Gauge / Histogram
  primitives behind a process-global named registry, rendered with
  :func:`repro.obs.metrics.render_prometheus` in Prometheus text
  exposition format (served as ``GET /v1/metrics`` and the binary
  ``OP_METRICS`` frame by the serve stack).
* :mod:`repro.obs.trace` — 16-hex trace ids, bounded in-process span
  records, and the propagation contract (``X-Repro-Trace`` HTTP header
  plus the additive ``trace_id`` field in the codec request meta).

Everything is near-free and can be disabled process-wide with
``metrics.set_enabled(False)`` (the server's ``--metrics off`` switch).
"""
from . import metrics, trace

__all__ = ["metrics", "trace"]

"""Thread-safe metrics primitives with a process-global named registry.

Design constraints (see serve/README.md "Observability"):

* stdlib only — no prometheus_client, no numpy;
* near-free when disabled: every mutation starts with one attribute
  load and a branch on ``Registry.enabled``, so ``--metrics off`` costs
  a handful of nanoseconds per call site;
* histograms use *fixed* bucket ladders (log-spaced) so series never
  change shape at runtime and the exposition is a stable contract;
* metric names are append-only once shipped — renaming or deleting a
  family is a breaking change for scrapers.

Series identity is ``(name, sorted(labels))``.  ``counter()`` /
``gauge()`` / ``histogram()`` are get-or-create and idempotent, so call
sites can simply re-ask the registry at construction time; registering
the same name with a different metric kind raises ``ValueError``.
"""
from __future__ import annotations

import os
import re
import threading
from bisect import bisect_left
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "LATENCY_BUCKETS_S", "COUNT_BUCKETS",
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "counter", "gauge", "histogram",
    "render_prometheus", "snapshot", "set_enabled", "enabled",
]

# Fixed log-spaced ladders.  Latency: 1 us .. 50 s, three buckets per
# decade (1 / 2.5 / 5).  Sizes: powers of two, 1 .. ~1M rows.
LATENCY_BUCKETS_S: Tuple[float, ...] = tuple(
    float("%se%d" % (m, e)) for e in range(-6, 2) for m in ("1", "2.5", "5"))
COUNT_BUCKETS: Tuple[float, ...] = tuple(float(2 ** k) for k in range(21))

#: recent exemplars kept per histogram series (a fused batch can land
#: several trace-carrying observations back-to-back; one slot would
#: keep only the last request's id)
EXEMPLAR_RING = 8

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _fmt(v: float) -> str:
    """Prometheus sample value: integers without a trailing ``.0``."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Series:
    """Base: one (name, labels) time series owned by a registry."""

    kind = "untyped"
    __slots__ = ("name", "labels", "_reg", "_lock")

    def __init__(self, reg: "Registry", name: str,
                 labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self._reg = reg
        self._lock = threading.Lock()

    def _label_str(self, extra: str = "") -> str:
        parts = [f'{k}="{_escape(v)}"' for k, v in self.labels]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""


class Counter(_Series):
    """Monotonically increasing float counter."""

    kind = "counter"
    __slots__ = ("_value",)

    def __init__(self, reg, name, labels):
        super().__init__(reg, name, labels)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _render(self) -> List[str]:
        return [f"{self.name}{self._label_str()} {_fmt(self.value)}"]

    def _snapshot(self) -> Dict:
        return {"labels": dict(self.labels), "value": self.value}


class Gauge(_Series):
    """Instantaneous value that can move both ways."""

    kind = "gauge"
    __slots__ = ("_value",)

    def __init__(self, reg, name, labels):
        super().__init__(reg, name, labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        # a single attribute store is atomic under the GIL; the lock is
        # only needed for read-modify-write (inc/dec), so the hot-path
        # set (queue depth, inflight — twice per request) skips it
        self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    _render = Counter._render
    _snapshot = Counter._snapshot


class Histogram(_Series):
    """Fixed-bucket histogram with a small ring of recent exemplars.

    ``observe(v, trace_id=...)`` attaches the trace id of the
    observation as an exemplar; the last :data:`EXEMPLAR_RING` of them
    are kept per series, which is how a request id stays findable from
    the metrics side even when a fused batch lands several observations
    back-to-back (the text exposition stays plain Prometheus; exemplars
    live in :meth:`Registry.snapshot`).
    """

    kind = "histogram"
    __slots__ = ("buckets", "_counts", "_sum", "_count", "_exemplars")

    def __init__(self, reg, name, labels,
                 buckets: Iterable[float] = LATENCY_BUCKETS_S):
        super().__init__(reg, name, labels)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self._counts = [0] * (len(self.buckets) + 1)   # last = +Inf
        self._sum = 0.0
        self._count = 0
        self._exemplars: deque = deque(maxlen=EXEMPLAR_RING)

    def observe(self, v: float, trace_id: Optional[str] = None) -> None:
        # hot path: several observes per served request — no float()
        # coercion (callers pass time deltas / row counts), bucket
        # search outside the lock, plain acquire/release
        if not self._reg.enabled:
            return
        i = bisect_left(self.buckets, v)
        lock = self._lock
        lock.acquire()
        try:
            self._counts[i] += 1
            self._sum += v
            self._count += 1
            if trace_id is not None:
                self._exemplars.append((trace_id, v))
        finally:
            lock.release()

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def exemplar(self) -> Optional[Tuple[str, float]]:
        """The most recent exemplar, or None."""
        with self._lock:
            return self._exemplars[-1] if self._exemplars else None

    @property
    def exemplars(self) -> List[Tuple[str, float]]:
        """The recent-exemplar ring, oldest first."""
        with self._lock:
            return list(self._exemplars)

    def _render(self) -> List[str]:
        with self._lock:
            counts, total, count = list(self._counts), self._sum, self._count
        out, cum = [], 0
        for bound, c in zip(self.buckets, counts):
            cum += c
            le = 'le="%s"' % _fmt(bound)
            out.append(f"{self.name}_bucket{self._label_str(le)} {cum}")
        inf = 'le="+Inf"'
        out.append(f"{self.name}_bucket{self._label_str(inf)} {count}")
        out.append(f"{self.name}_sum{self._label_str()} {_fmt(total)}")
        out.append(f"{self.name}_count{self._label_str()} {count}")
        return out

    def _snapshot(self) -> Dict:
        with self._lock:
            counts = list(self._counts)
            snap = {
                "labels": dict(self.labels),
                "sum": self._sum,
                "count": self._count,
                "buckets": [[b, c] for b, c in zip(self.buckets, counts)],
                "inf": counts[-1],
            }
            if self._exemplars:
                last = self._exemplars[-1]
                snap["exemplar"] = {"trace_id": last[0], "value": last[1]}
                snap["exemplars"] = [{"trace_id": t, "value": v}
                                     for t, v in self._exemplars]
        return snap


class Registry:
    """Named collection of series; one process-global instance below."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._families: Dict[str, Tuple[str, str]] = {}   # name -> kind, help
        self._series: Dict[Tuple[str, Tuple], _Series] = {}

    # -- get-or-create ------------------------------------------------
    def _get(self, cls, name: str, help: str, labels: Dict[str, str],
             **kw) -> _Series:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name: {name!r}")
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"bad label name: {k!r}")
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                self._families[name] = (cls.kind, help)
            elif fam[0] != cls.kind:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{fam[0]}, not {cls.kind}")
            s = self._series.get(key)
            if s is None:
                s = cls(self, name, key[1], **kw)
                self._series[key] = s
            return s

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = LATENCY_BUCKETS_S,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    # -- export -------------------------------------------------------
    def _ordered(self) -> List[Tuple[str, str, str, List[_Series]]]:
        with self._lock:
            fams = dict(self._families)
            series = list(self._series.items())
        by_name: Dict[str, List[Tuple[Tuple, _Series]]] = {}
        for (name, lbls), s in series:
            by_name.setdefault(name, []).append((lbls, s))
        out = []
        for name in sorted(by_name):
            kind, help = fams[name]
            out.append((name, kind, help,
                        [s for _, s in sorted(by_name[name],
                                              key=lambda p: p[0])]))
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        for name, kind, help, series in self._ordered():
            if help:
                lines.append(f"# HELP {name} {_escape(help)}")
            lines.append(f"# TYPE {name} {kind}")
            for s in series:
                lines.extend(s._render())
        return "\n".join(lines) + "\n" if lines else ""

    def snapshot(self) -> Dict:
        """Structured dict view (includes histogram exemplars)."""
        out: Dict = {}
        for name, kind, help, series in self._ordered():
            out[name] = {"kind": kind, "help": help,
                         "series": [s._snapshot() for s in series]}
        return out

    def family_names(self) -> List[str]:
        with self._lock:
            return sorted(self._families)

    def reset(self) -> None:
        """Drop every family and series (tests only)."""
        with self._lock:
            self._families.clear()
            self._series.clear()


REGISTRY = Registry()


def counter(name: str, help: str = "", **labels) -> Counter:
    return REGISTRY.counter(name, help, **labels)


def gauge(name: str, help: str = "", **labels) -> Gauge:
    return REGISTRY.gauge(name, help, **labels)


def histogram(name: str, help: str = "",
              buckets: Iterable[float] = LATENCY_BUCKETS_S,
              **labels) -> Histogram:
    return REGISTRY.histogram(name, help, buckets=buckets, **labels)


def render_prometheus() -> str:
    return REGISTRY.render_prometheus()


def snapshot() -> Dict:
    return REGISTRY.snapshot()


def set_enabled(on: bool) -> None:
    """Process-wide kill switch (the server's ``--metrics off|on``)."""
    REGISTRY.enabled = bool(on)


def enabled() -> bool:
    return REGISTRY.enabled


def _reinit_after_fork_in_child() -> None:
    # A forked worker must not inherit possibly-held locks; series
    # values are fine to keep (the child's registry is its own copy).
    REGISTRY._lock = threading.Lock()
    for s in REGISTRY._series.values():
        s._lock = threading.Lock()


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reinit_after_fork_in_child)

"""HTTP prediction server: one ``SweepEngine``, micro-batched requests.

Stdlib only (``http.server``): the server owns one memoizing
``SweepEngine`` (so repeated sweeps hit the whole-table content-token
cache across requests and clients), one optional ``core.parallel``
``WorkerPool`` (reused across streamed-lattice requests instead of paying
pool startup per query), and one request coalescer.

Endpoints (wire bodies are ``repro.serve.codec`` messages):

    GET  /v1/health        liveness + wire version + known hardware
    GET  /v1/metrics       Prometheus text exposition (no auth, read-only)
    GET  /v1/cache_stats   engine cache counters + coalescer counters
    GET  /v1/hardware      JSON directory of the hardware library
    GET  /v1/hardware/<n>  one entry as a HARDWARE message
    POST /v1/hardware      HARDWARE -> register a new entry (?overwrite=1)
    POST /v1/calibrate     CALREQ(suite) -> CALIBRATION (fit w/ holdout)
    POST /v1/predict_table REQUEST(table|spec) -> TOTALS
    POST /v1/argmin        REQUEST(table|spec) -> WINNERS (list of one)
    POST /v1/topk          REQUEST(table|spec) -> WINNERS
    POST /v1/pareto        REQUEST(table|spec) -> WINNERS
    POST /v1/predict       REQUEST, op taken from the request meta
    POST /v1/clear_cache   admin: drop every engine cache tier

Calibration-as-data: ``/v1/calibrate`` accepts a measured microbench
suite, fits per-case/per-class multipliers against this server's own
predictions with the paper's train/holdout discipline, and returns the
fitted ``Calibration`` with its full §IV-D disclosure.  ``register_as``
stores it server-side; sweep requests that name it
(``calibration=<name>``) price with its multipliers applied (and group
separately in the coalescer — calibrated and raw answers never fuse).
Registering a calibration or hardware entry is idempotent (same payload
-> same state), preserving the client's retry contract.

Micro-batching contract: concurrent **table** requests that share
(hardware, model route) and did not opt out (``coalesce=False``) are
fused — their tables concatenate into one columnar evaluation and each
request's answer reduces over its own row window
(``sweep.*_from_result``).  The model backends are row-elementwise, so
fused answers are bit-identical to evaluating each request alone; the
fused table prices with the memo cache bypassed so transient
concatenations never churn the table LRU.  Single-request groups take the
normal cached path, which is what makes identical replayed sweeps a
content-token hit.  **Spec** (streamed-lattice) requests are never
coalesced — each one already streams O(chunk) and may shard across the
worker pool.

Failures decode-side (bad magic, truncation, unknown hardware, wrong op)
return HTTP 400 with an ERROR message body; unexpected server faults
return 500.  The serving loop itself never dies on a bad request.

Fault tolerance (the full status-code contract lives in ``README.md``
and ``errors.py``): the coalescer queue is depth-bounded — past
``max_queue_depth`` the server sheds load with 503 + ``Retry-After``
instead of piling up handler threads; requests carrying a deadline
budget (``X-Repro-Deadline-S``) are shed once the budget is spent; the
mutating endpoints (``POST /v1/hardware``, ``DELETE /v1/hardware/<n>``,
``POST /v1/calibrate``, ``POST /v1/clear_cache``) can be gated behind a
shared-secret token (401) and a token-bucket rate limit (429); one
poisoned request inside a fused batch fails alone with 400 while its
batchmates answer normally; and SIGTERM triggers a graceful drain —
stop accepting, 503 new work, finish in-flight batches, snapshot
``--state-dir`` calibrations, reap the pool.
"""
from __future__ import annotations

import argparse
import hmac
import json
import os
import sys
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import hardware, sweep
from ..core.workload import LatticeSpec, WorkloadTable
from ..obs import metrics, trace
from . import codec, errors

#: refuse request bodies beyond this (a 2^31-row table is a streamed
#: lattice, not an upload)
MAX_BODY_BYTES = 1 << 30

#: coalescer admission bound: submissions beyond this many parked
#: requests are shed with 503 + Retry-After (load shedding instead of an
#: unbounded handler-thread pile-up)
DEFAULT_MAX_QUEUE_DEPTH = 1024

#: Retry-After hint (seconds) sent with drain/overload 503s
SHED_RETRY_AFTER_S = 0.05
DRAIN_RETRY_AFTER_S = 1.0

#: extra seconds the coalescer holds a batch open for companions.  The
#: default is 0: batching happens naturally — requests that arrive while
#: an evaluation is in flight pile up and drain as one batch — so a lone
#: sequential request never pays artificial latency.  Raise it to force
#: deterministic fusion (tests) or on high-RTT links.
DEFAULT_COALESCE_WINDOW_S = 0.0

#: fused evaluations stop growing past this estimated row-cost budget —
#: a coalesced batch should stay LLC-friendly, not become an accidental
#: materialization.  The budget is in *vectorized-row units*: a plain row
#: costs 1 unit, a scalar-fallback row costs ``SCALAR_ROW_COST`` (so a
#: batch of expensive rows fuses ~50x fewer rows and stays inside the
#: same latency envelope as a vectorized one)
MAX_FUSED_ROWS = 262_144

#: estimated cost of one scalar-fallback row (explicit hit-rate rows take
#: the wavefront model's per-row latency walk, ~10us vs ~0.2us
#: vectorized) relative to a vectorized row
SCALAR_ROW_COST = 50

CONTENT_TYPE = "application/x-repro-wire"
METRICS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_STAGE_HELP = ("Per-stage request latency "
               "(parse/queue_wait/fuse/evaluate/encode/write)")


_STAGE_HISTS: dict = {}


def _stage_hist(stage: str) -> metrics.Histogram:
    # memoized: the registry's get-or-create takes its lock and
    # re-validates names (~2.4us) — too much for twice per request
    h = _STAGE_HISTS.get(stage)
    if h is None:
        h = _STAGE_HISTS[stage] = metrics.histogram(
            "repro_serve_stage_seconds", _STAGE_HELP, stage=stage)
    return h


class _Pending:
    """One in-flight table request parked in the coalescer."""

    __slots__ = ("op", "table", "k", "objectives", "event", "result",
                 "error", "deadline", "max_rows", "on_done", "trace_id",
                 "t_submit")

    def __init__(self, op: str, table: WorkloadTable, k: Optional[int],
                 objectives: Optional[Tuple[str, ...]],
                 deadline: Optional[float] = None,
                 max_rows: Optional[int] = None,
                 on_done=None,
                 trace_id: Optional[str] = None):
        self.op = op
        self.table = table
        self.k = k
        self.objectives = objectives
        self.deadline = deadline          # time.monotonic() cutoff or None
        #: per-request fused-batch budget hint (clamped to the server's
        #: bound — a hint tightens, never raises)
        self.max_rows = max_rows
        #: completion callback for event-loop callers (invoked on the
        #: coalescer thread after result/error is set)
        self.on_done = on_done
        #: client trace id (16-hex) riding the request through fusion,
        #: dedup, and poison-isolation solo re-runs
        self.trace_id = trace_id
        self.t_submit = time.monotonic()
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None


class TokenBucket:
    """Thread-safe token bucket: ``rate_per_s`` refill, ``burst`` cap.

    ``try_acquire()`` returns 0.0 on admit, else the seconds until a
    token will exist (the 429 ``Retry-After`` hint)."""

    def __init__(self, rate_per_s: float, burst: int):
        if rate_per_s <= 0 or burst < 1:
            raise ValueError(f"need rate > 0 and burst >= 1, got "
                             f"rate={rate_per_s} burst={burst}")
        self.rate = float(rate_per_s)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._stamp = time.monotonic()
        self._lock = threading.Lock()

    def try_acquire(self) -> float:
        with self._lock:
            now = time.monotonic()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._stamp) * self.rate)
            self._stamp = now
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                return 0.0
            return (1.0 - self._tokens) / self.rate


class _NamedCalibration:
    """A registered calibration: the object plus its registry name (the
    name is the coalescer group key — two requests naming the same
    registered calibration may fuse; raw and calibrated never do)."""

    __slots__ = ("name", "cal")

    def __init__(self, name: str, cal):
        self.name = name
        self.cal = cal


class Coalescer:
    """Fuses concurrent small table requests into one columnar evaluation.

    Handler threads ``submit()`` and block; one worker thread drains the
    queue (optionally holding each batch open ``window_s`` for
    companions), groups by (hardware token, model route), prices each
    group once, and answers every request from its own row window.
    """

    def __init__(self, engine: sweep.SweepEngine,
                 window_s: float = DEFAULT_COALESCE_WINDOW_S,
                 max_fused_rows: int = MAX_FUSED_ROWS,
                 max_queue_depth: int = DEFAULT_MAX_QUEUE_DEPTH):
        self.engine = engine
        self.window_s = window_s
        self.max_fused_rows = max_fused_rows
        #: admission bound: submissions finding this many requests already
        #: parked are shed with ``ServerOverloaded`` (-> 503) instead of
        #: blocking another handler thread behind an unbounded queue
        self.max_queue_depth = max_queue_depth
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._closed = False
        self.stats = {"requests": 0, "batches": 0, "fused_evaluations": 0,
                      "coalesced_requests": 0, "fused_rows": 0,
                      "deduped_requests": 0, "dedup_rows_saved": 0,
                      "shed_overload": 0, "shed_deadline": 0,
                      "isolated_failures": 0}
        #: one lock covers every stats mutation AND the snapshot read, so
        #: ``/v1/cache_stats`` can never observe a torn combination (e.g.
        #: ``deduped_requests`` updated by the worker thread while
        #: ``requests`` still shows the pre-submit value)
        self._stats_lock = threading.Lock()
        # metric series (get-or-create against the process registry)
        self._m_queue_wait = _stage_hist("queue_wait")
        self._m_fuse = _stage_hist("fuse")
        self._m_evaluate = _stage_hist("evaluate")
        self._m_batch_reqs = metrics.histogram(
            "repro_serve_fused_batch_requests",
            "Requests answered per fused evaluation",
            buckets=metrics.COUNT_BUCKETS)
        self._m_batch_rows = metrics.histogram(
            "repro_serve_fused_batch_rows",
            "Rows in each fused columnar evaluation",
            buckets=metrics.COUNT_BUCKETS)
        self._m_batch_cost = metrics.histogram(
            "repro_serve_fused_batch_cost",
            "Estimated row-cost units of each fused evaluation",
            buckets=metrics.COUNT_BUCKETS)
        self._m_dedup = metrics.counter(
            "repro_serve_deduped_requests_total",
            "Requests answered from another request's evaluation")
        self._m_dedup_rows = metrics.counter(
            "repro_serve_dedup_rows_saved_total",
            "Rows not re-evaluated thanks to cross-request dedup")
        self._m_shed = {
            reason: metrics.counter(
                "repro_serve_shed_total",
                "Requests shed instead of evaluated", reason=reason)
            for reason in ("overload", "deadline")}
        self._m_isolated = metrics.counter(
            "repro_serve_isolated_failures_total",
            "Fused batches that failed and were re-run solo")
        self._m_depth = metrics.gauge(
            "repro_serve_queue_depth",
            "Requests parked in the coalescer queue")
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="serve-coalescer")
        self._thread.start()

    def _bump(self, **deltas) -> None:
        """Apply one consistent multi-counter stats update."""
        with self._stats_lock:
            for k, n in deltas.items():
                self.stats[k] += n

    def stats_snapshot(self) -> Dict[str, int]:
        """A mutually consistent copy of every coalescer counter."""
        with self._stats_lock:
            return dict(self.stats)

    # ---------------------------------------------------------- client side
    def submit_async(self, op: str, table: WorkloadTable, hw,
                     model: Optional[str] = None, *,
                     k: Optional[int] = None,
                     objectives: Optional[Tuple[str, ...]] = None,
                     calibration: Optional[_NamedCalibration] = None,
                     deadline: Optional[float] = None,
                     max_rows: Optional[int] = None,
                     on_done=None,
                     trace_id: Optional[str] = None) -> _Pending:
        """Park a request without blocking: the returned ``_Pending``'s
        ``event`` fires (and ``on_done`` runs, on the coalescer thread)
        once ``result``/``error`` is set.  This is the binary front end's
        entry point — its event loop must never block on an evaluation."""
        req = _Pending(op, table, k, objectives, deadline,
                       max_rows=max_rows, on_done=on_done,
                       trace_id=trace_id)
        group = (sweep.hardware_key(hw), model or sweep.default_route(hw),
                 calibration.name if calibration else None)
        with self._cv:
            if self._closed:
                raise RuntimeError("coalescer is shut down")
            if len(self._q) >= self.max_queue_depth:
                self._bump(shed_overload=1)
                self._m_shed["overload"].inc()
                raise errors.ServerOverloaded(
                    f"coalescer queue at its depth bound "
                    f"({self.max_queue_depth} requests parked) — load "
                    f"shed, retry after backoff",
                    retry_after_s=SHED_RETRY_AFTER_S)
            self._q.append((group, hw, model, calibration, req))
            self._bump(requests=1)
            self._m_depth.set(len(self._q))
            self._cv.notify()
        return req

    def submit(self, op: str, table: WorkloadTable, hw, model: Optional[str],
               k: Optional[int] = None,
               objectives: Optional[Tuple[str, ...]] = None,
               calibration: Optional[_NamedCalibration] = None,
               deadline: Optional[float] = None,
               max_rows: Optional[int] = None,
               trace_id: Optional[str] = None):
        req = self.submit_async(op, table, hw, model, k=k,
                                objectives=objectives,
                                calibration=calibration, deadline=deadline,
                                max_rows=max_rows, trace_id=trace_id)
        req.event.wait()
        if req.error is not None:
            raise req.error
        return req.result

    def _finish(self, r: _Pending) -> None:
        """Fire a parked request's completion: event first (blocking
        submitters wake), then the event-loop callback.  A callback that
        throws must not kill the coalescer thread."""
        r.event.set()
        cb = r.on_done
        if cb is not None:
            try:
                cb(r)
            except Exception:                # noqa: BLE001
                pass

    # ---------------------------------------------------------- worker side
    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._q and not self._closed:
                    self._cv.wait()
                if self._closed and not self._q:
                    return
            # batch is open: let concurrent companions land before draining
            if self.window_s > 0:
                time.sleep(self.window_s)
            with self._cv:
                drained = list(self._q)
                self._q.clear()
                self._m_depth.set(0)
            if drained:
                self._run_batch(drained)

    def _run_batch(self, drained: List) -> None:
        self._bump(batches=1)
        groups: Dict[Tuple, List] = {}
        for group, hw, model, calibration, req in drained:
            groups.setdefault(group, []).append((hw, model, calibration,
                                                 req))
        for members in groups.values():
            hw, model, calibration = members[0][:3]
            reqs = [m[3] for m in members]
            try:
                self._run_group(hw, model, calibration, reqs)
            except BaseException as e:       # noqa: BLE001 — reply, not die
                for r in reqs:
                    if not r.event.is_set():
                        r.error = e
                        self._finish(r)

    @staticmethod
    def _est_cost(table: WorkloadTable) -> int:
        """Estimated evaluation cost of a table in vectorized-row units.
        Rows with explicit hit rates take the wavefront model's scalar
        latency-walk fallback (~``SCALAR_ROW_COST``x a vectorized row), so
        a fused batch of them must stay ~50x smaller to hit the same
        latency budget."""
        if table.hit_rates is None:
            return len(table)
        n_scalar = sum(1 for h in table.hit_rates if h)
        return len(table) + (SCALAR_ROW_COST - 1) * n_scalar

    def _run_group(self, hw, model: Optional[str],
                   calibration: Optional[_NamedCalibration],
                   reqs: List[_Pending]) -> None:
        # split oversized groups so one fused evaluation stays inside the
        # adaptive cost budget (estimated units, not raw rows); a member's
        # ``max_rows`` hint tightens the budget for the batch it joins —
        # it is clamped to the server bound, never raises it
        start = 0
        while start < len(reqs):
            budget = float(self.max_fused_rows)
            cost = 0
            end = start
            while end < len(reqs):
                r = reqs[end]
                b = budget if r.max_rows is None \
                    else min(budget, float(r.max_rows))
                c = self._est_cost(r.table)
                if end > start and cost + c > b:
                    break
                budget = b
                cost += c
                end += 1
            self._run_fused(hw, model, calibration, reqs[start:end])
            start = end

    def _run_fused(self, hw, model: Optional[str],
                   calibration: Optional[_NamedCalibration],
                   reqs: List[_Pending]) -> None:
        cal = calibration.cal if calibration else None
        # shed requests whose deadline budget was spent while parked —
        # evaluating them would be work the client has already abandoned
        now = time.monotonic()
        live = []
        for r in reqs:
            self._m_queue_wait.observe(now - r.t_submit,
                                       trace_id=r.trace_id)
            if r.deadline is not None and now >= r.deadline:
                self._bump(shed_deadline=1)
                self._m_shed["deadline"].inc()
                r.error = errors.DeadlineExceeded(
                    "request deadline expired while queued — result would "
                    "arrive after the client stopped waiting")
                self._finish(r)
            else:
                live.append(r)
        if not live:
            return
        # cross-request dedup: requests whose tables share a content token
        # (within this group the hardware/route/calibration already match)
        # price once.  The token ignores row names — exactly like the memo
        # cache — and each request is answered from its OWN table, so
        # names stay per-request and answers remain bit-identical.
        order: List[Tuple] = []            # unique tokens, arrival order
        dedup: Dict[Tuple, List[_Pending]] = {}
        for r in live:
            tok = r.table.content_token()
            if tok in dedup:
                dedup[tok].append(r)
            else:
                dedup[tok] = [r]
                order.append(tok)
        n_dup = len(live) - len(order)
        if n_dup:
            rows_saved = sum(
                len(r.table) for tok in order for r in dedup[tok][1:])
            self._bump(deduped_requests=n_dup, dedup_rows_saved=rows_saved)
            self._m_dedup.inc(n_dup)
            self._m_dedup_rows.inc(rows_saved)
        if len(order) == 1:
            # one distinct table (a lone request, or all duplicates): the
            # memoizing solo path — identical replayed sweeps stay
            # whole-table content-token hits, and concurrent duplicates
            # now share one evaluation instead of fusing into 2N rows
            self._run_solo(dedup[order[0]], hw, model, cal)
            return
        t_fuse = time.monotonic()
        fused = WorkloadTable.concat([dedup[tok][0].table for tok in order])
        t_eval = time.monotonic()
        self._m_fuse.observe(t_eval - t_fuse, trace_id=live[0].trace_id)
        try:
            res = self.engine.predict_table(fused, hw, model=model,
                                            cache=False, calibration=cal)
        except BaseException:                # noqa: BLE001
            # one poisoned table must not share fate with its batchmates:
            # re-run each table alone so only the culprit(s) error (the
            # coalescing contract makes solo answers bit-identical)
            self._bump(isolated_failures=1)
            self._m_isolated.inc()
            for tok in order:
                self._run_solo(dedup[tok], hw, model, cal)
            return
        dt_eval = time.monotonic() - t_eval
        self._m_evaluate.observe(dt_eval, trace_id=live[0].trace_id)
        self._m_batch_reqs.observe(len(live))
        self._m_batch_rows.observe(len(fused))
        self._m_batch_cost.observe(self._est_cost(fused))
        self._bump(fused_evaluations=1, coalesced_requests=len(live),
                   fused_rows=len(fused))
        lo = 0
        for tok in order:
            members = dedup[tok]
            hi = lo + len(members[0].table)
            for i, r in enumerate(members):
                try:
                    r.result = self._answer(res, r, lo=lo, hi=hi)
                except BaseException as e:   # noqa: BLE001
                    r.error = e
                trace.record_span("serve.eval", r.trace_id,
                                  time.monotonic() - r.t_submit,
                                  op=r.op, fused=len(live),
                                  dedup=i > 0)
                self._finish(r)
            lo = hi

    def _run_solo(self, rs: List[_Pending], hw, model: Optional[str],
                  cal) -> None:
        """Evaluate one distinct table (cached path) and answer every
        request that shares its content."""
        if isinstance(rs, _Pending):
            rs = [rs]
        t_eval = time.monotonic()
        try:
            res = self.engine.predict_table(rs[0].table, hw, model=model,
                                            calibration=cal)
        except BaseException as e:           # noqa: BLE001
            for r in rs:
                r.error = e
                trace.record_span("serve.eval", r.trace_id,
                                  time.monotonic() - r.t_submit,
                                  op=r.op, solo=True, error=True)
                self._finish(r)
            return
        self._m_evaluate.observe(time.monotonic() - t_eval,
                                 trace_id=rs[0].trace_id)
        for i, r in enumerate(rs):
            try:
                r.result = self._answer(res, r, lo=0, hi=None)
            except BaseException as e:       # noqa: BLE001
                r.error = e
            trace.record_span("serve.eval", r.trace_id,
                              time.monotonic() - r.t_submit,
                              op=r.op, solo=True, dedup=i > 0)
            self._finish(r)

    @staticmethod
    def _answer(res, r: _Pending, lo: int, hi: Optional[int]):
        if r.op == "argmin":
            return [sweep.argmin_from_result(res, r.table, lo, hi)]
        if r.op == "topk":
            # k=0 must round-trip to [] like topk_table, not coerce to 1
            k = 1 if r.k is None else int(r.k)
            return sweep.topk_from_result(res, r.table, k, lo, hi)
        if r.op == "pareto":
            return sweep.pareto_from_result(
                res, r.table, r.objectives or ("compute", "memory"), lo, hi)
        # predict_table: the window's totals column
        return np.array(res.totals[lo:hi])

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._thread.join(timeout=5.0)


class PredictionServer:
    """The serving front end: HTTP endpoints over one engine + coalescer.

    ``port=0`` binds an ephemeral port (read it back from ``address``).
    ``jobs`` > 1 (or 0 for every core) starts a reusable ``WorkerPool``
    for streamed-lattice requests; table requests never need it.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 engine: Optional[sweep.SweepEngine] = None,
                 jobs=None,
                 coalesce_window_s: float = DEFAULT_COALESCE_WINDOW_S,
                 use_threads: Optional[bool] = None,
                 quiet: bool = True,
                 auth_token: Optional[str] = None,
                 max_queue_depth: Optional[int] = None,
                 mutate_rps: Optional[float] = None,
                 mutate_burst: int = 5,
                 state_dir: Optional[str] = None,
                 straggler_timeout_s: Optional[float] = None,
                 binary_port: Optional[int] = None,
                 max_fused_rows: Optional[int] = None,
                 metrics_enabled: Optional[bool] = None,
                 slow_request_ms: Optional[float] = None,
                 slow_log_sink=None):
        # --metrics off|on flips the process-global registry; None (the
        # in-process default) leaves whatever the host process chose
        if metrics_enabled is not None:
            metrics.set_enabled(metrics_enabled)
        #: slow-request threshold in ms (None = slow log off); lines are
        #: structured JSON carrying the request's trace id
        self.slow_request_ms = slow_request_ms
        self._slow_log_sink = slow_log_sink
        self._m_requests = {
            t: metrics.counter("repro_serve_requests_total",
                               "Sweep requests answered", transport=t)
            for t in ("http", "binary")}
        self._m_request_s = {
            t: metrics.histogram("repro_serve_request_seconds",
                                 "End-to-end sweep request latency",
                                 transport=t)
            for t in ("http", "binary")}
        self._m_slow = metrics.counter(
            "repro_serve_slow_requests_total",
            "Requests above the --slow-request-ms threshold")
        self.engine = engine or sweep.SweepEngine()
        self.coalescer = None
        self.pool = None
        self.binary = None
        self.started_at = time.time()
        self.n_requests = 0
        #: registered calibrations by name — what sweep requests with
        #: ``calibration=<name>`` resolve against
        self.calibrations: Dict[str, _NamedCalibration] = {}
        self._cal_lock = threading.Lock()
        #: shared secret gating mutating endpoints (None = open)
        self._auth_token = auth_token
        #: token bucket over mutating endpoints (None = unlimited)
        self._mutate_bucket = (TokenBucket(mutate_rps, mutate_burst)
                               if mutate_rps else None)
        self.state_dir = state_dir
        self._draining = False
        self._inflight = 0
        self._inflight_cv = threading.Condition()
        if state_dir:
            self._load_state()
        server = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, fmt, *args):  # noqa: N802
                if not quiet:
                    BaseHTTPRequestHandler.log_message(self, fmt, *args)

            def _reply(self, status: int, body: bytes,
                       retry_after_s: Optional[float] = None,
                       content_type: str = CONTENT_TYPE) -> None:
                self.send_response(status)
                self.send_header("Content-Type", content_type)
                self.send_header("Content-Length", str(len(body)))
                if retry_after_s is not None:
                    self.send_header("Retry-After", f"{retry_after_s:g}")
                if self.close_connection:
                    self.send_header("Connection", "close")
                self.end_headers()
                self.wfile.write(body)

            def _track(self, handler) -> None:
                """Count the request in-flight so a graceful shutdown can
                wait for it to finish before tearing down the engine."""
                with server._inflight_cv:
                    server._inflight += 1
                try:
                    handler()
                finally:
                    with server._inflight_cv:
                        server._inflight -= 1
                        server._inflight_cv.notify_all()

            def _shed_draining(self) -> bool:
                if not server._draining:
                    return False
                self.close_connection = True
                self._reply(503, codec.encode_error(errors.ServerOverloaded(
                    "server is draining — no new work accepted",
                    retry_after_s=DRAIN_RETRY_AFTER_S)),
                    retry_after_s=DRAIN_RETRY_AFTER_S)
                return True

            def _admit_mutation(self) -> bool:
                """Auth + rate-limit gate for mutating endpoints, checked
                BEFORE the body is read (an unauthorized client should not
                get to stream a 1 GiB payload in)."""
                try:
                    server._admit_mutation(self.headers)
                    return True
                except errors.Unauthorized as e:
                    self.close_connection = True
                    self._reply(401, codec.encode_error(e))
                except errors.RateLimited as e:
                    self.close_connection = True
                    self._reply(429, codec.encode_error(e),
                                retry_after_s=e.retry_after_s)
                return False

            def do_GET(self):  # noqa: N802
                self._track(self._get)

            def do_POST(self):  # noqa: N802
                self._track(self._post)

            def do_DELETE(self):  # noqa: N802
                self._track(self._delete)

            def _get(self):
                server.n_requests += 1
                if self.path == "/v1/health":
                    self._reply(200, codec.encode_json(server.health()))
                elif self.path == "/v1/metrics":
                    # Prometheus scrape surface: plain text, no auth,
                    # read-only; still answers while draining (like
                    # health) so the last scrape sees the drain counters
                    self._reply(200,
                                server.metrics_text().encode("utf-8"),
                                content_type=METRICS_CONTENT_TYPE)
                elif self.path == "/v1/cache_stats":
                    self._reply(200, codec.encode_json(server.stats()))
                elif self.path == "/v1/hardware":
                    self._reply(200, codec.encode_json(
                        server.hardware_directory()))
                elif self.path.startswith("/v1/hardware/"):
                    name = self.path[len("/v1/hardware/"):]
                    try:
                        self._reply(200, server.hardware_entry(name))
                    except KeyError as e:
                        self._reply(404, codec.encode_error(e))
                else:
                    self._reply(404, codec.encode_error(
                        LookupError(f"unknown endpoint {self.path}")))

            def _delete(self):
                server.n_requests += 1
                if self._shed_draining():
                    return
                if not self.path.startswith("/v1/hardware/"):
                    self._reply(404, codec.encode_error(
                        LookupError(f"unknown endpoint {self.path}")))
                    return
                if not self._admit_mutation():
                    return
                name = self.path[len("/v1/hardware/"):]
                try:
                    self._reply(200, server.delete_hardware(name))
                except KeyError as e:
                    self._reply(404, codec.encode_error(e))
                except Exception as e:       # noqa: BLE001
                    self._reply(500, codec.encode_error(e))

            def _post(self):
                server.n_requests += 1
                if self._shed_draining():
                    return
                path, _, query = self.path.partition("?")
                if path in ("/v1/hardware", "/v1/calibrate",
                            "/v1/clear_cache") \
                        and not self._admit_mutation():
                    return
                deadline = None
                raw = self.headers.get(errors.DEADLINE_HEADER)
                if raw is not None:
                    try:
                        budget = float(raw)
                    except ValueError:
                        self.close_connection = True
                        self._reply(400, codec.encode_error(ValueError(
                            f"invalid {errors.DEADLINE_HEADER} header "
                            f"{raw!r}: want a relative seconds budget")))
                        return
                    if budget <= 0:
                        # the budget was spent in flight — shed before
                        # reading the body, let alone evaluating
                        self.close_connection = True
                        self._reply(503, codec.encode_error(
                            errors.DeadlineExceeded(
                                "deadline budget already spent on "
                                "arrival")))
                        return
                    deadline = time.monotonic() + budget
                # every error reply below leaves the request body unread,
                # which would desync the next request on this keep-alive
                # socket — drop the connection after answering
                try:
                    length = int(self.headers.get("Content-Length", ""))
                except ValueError:
                    self.close_connection = True
                    self._reply(411, codec.encode_error(
                        ValueError("Content-Length required")))
                    return
                if length < 0:
                    # rfile.read(-1) would block on a keep-alive socket
                    self.close_connection = True
                    self._reply(400, codec.encode_error(ValueError(
                        f"invalid Content-Length {length}")))
                    return
                if length > MAX_BODY_BYTES:
                    self.close_connection = True
                    self._reply(413, codec.encode_error(ValueError(
                        f"body of {length} bytes exceeds "
                        f"{MAX_BODY_BYTES}")))
                    return
                body = self.rfile.read(length)
                if path == "/v1/clear_cache":
                    server.engine.clear_cache()
                    self._reply(200, codec.encode_json({"cleared": True}))
                    return
                if path == "/v1/hardware":
                    overwrite = "overwrite=1" in query.split("&")
                    try:
                        self._reply(200, server.register_hardware(
                            body, overwrite=overwrite))
                    except (codec.WireFormatError, ValueError,
                            TypeError) as e:
                        self._reply(400, codec.encode_error(e))
                    except Exception as e:   # noqa: BLE001
                        self._reply(500, codec.encode_error(e))
                    return
                if path == "/v1/calibrate":
                    try:
                        self._reply(200, server.calibrate(body))
                    except (codec.WireFormatError, KeyError, ValueError,
                            TypeError) as e:
                        self._reply(400, codec.encode_error(e))
                    except Exception as e:   # noqa: BLE001
                        self._reply(500, codec.encode_error(e))
                    return
                op = path.rsplit("/", 1)[-1]
                if path not in (
                        "/v1/predict", "/v1/predict_table", "/v1/argmin",
                        "/v1/topk", "/v1/pareto"):
                    self._reply(404, codec.encode_error(
                        LookupError(f"unknown endpoint {self.path}")))
                    return
                trace_id = trace.coerce_trace_id(
                    self.headers.get(trace.TRACE_HEADER))
                t0 = time.monotonic()
                status = 200
                try:
                    out = server.handle_request(
                        body, expect_op=None if op == "predict" else op,
                        deadline=deadline, trace_id=trace_id)
                    t_w = time.monotonic()
                    self._reply(200, out)
                    _stage_hist("write").observe(time.monotonic() - t_w,
                                                 trace_id=trace_id)
                except errors.ServerOverloaded as e:
                    status = 503
                    self._reply(503, codec.encode_error(e),
                                retry_after_s=e.retry_after_s)
                except errors.DeadlineExceeded as e:
                    status = 503
                    self._reply(503, codec.encode_error(e))
                except (codec.WireFormatError, KeyError, ValueError,
                        TypeError) as e:
                    status = 400
                    self._reply(400, codec.encode_error(e))
                except Exception as e:       # noqa: BLE001
                    status = 500
                    self._reply(500, codec.encode_error(e))
                server._observe_request("http", op, trace_id,
                                        time.monotonic() - t0, status)

        # bind before starting the coalescer thread / worker processes: a
        # bind failure (port in use) must not leak children the caller
        # has no handle to reap
        self.httpd = ThreadingHTTPServer((host, port), Handler)
        self.httpd.daemon_threads = True
        try:
            self.coalescer = Coalescer(
                self.engine, window_s=coalesce_window_s,
                max_fused_rows=(MAX_FUSED_ROWS if max_fused_rows is None
                                else int(max_fused_rows)),
                max_queue_depth=(DEFAULT_MAX_QUEUE_DEPTH
                                 if max_queue_depth is None
                                 else max_queue_depth))
            if jobs is not None and sweep.effective_jobs(jobs) > 1:
                from ..core import parallel
                self.pool = parallel.WorkerPool(
                    jobs, use_threads=use_threads,
                    straggler_timeout_s=straggler_timeout_s)
            if binary_port is not None:
                from .binserver import BinaryFrontend
                self.binary = BinaryFrontend(self, host, binary_port)
        except BaseException:
            self.httpd.server_close()
            if self.coalescer is not None:
                self.coalescer.close()
            if self.pool is not None:
                self.pool.close()
            raise

    # ------------------------------------------------------------ plumbing
    @property
    def address(self) -> Tuple[str, int]:
        return self.httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    @property
    def binary_address(self) -> Optional[Tuple[str, int]]:
        return self.binary.address if self.binary is not None else None

    def start(self) -> "PredictionServer":
        """Serve on a daemon thread (tests, in-process demos)."""
        self._serving = True
        if self.binary is not None:
            self.binary.start()
        t = threading.Thread(target=self.httpd.serve_forever, daemon=True,
                             name="serve-http")
        t.start()
        return self

    def serve_forever(self) -> None:
        self._serving = True
        if self.binary is not None:
            self.binary.start()
        self.httpd.serve_forever()

    def begin_drain(self) -> None:
        """Graceful-drain entry point (the SIGTERM handler): flag the
        server as draining — new POST/DELETE work gets 503 +
        ``Retry-After`` while GETs (health probes) still answer — and
        stop the accept loop.  ``shutdown()`` then finishes in-flight
        requests and snapshots state.  Idempotent."""
        if self._draining:
            return
        self._draining = True
        if self.binary is not None:
            self.binary.begin_drain()
        if getattr(self, "_serving", False):
            # httpd.shutdown() blocks until serve_forever exits; the
            # SIGTERM handler runs *on* the serve_forever thread, so the
            # call must come from elsewhere or it deadlocks
            threading.Thread(target=self.httpd.shutdown, daemon=True,
                             name="serve-drain").start()

    def shutdown(self) -> None:
        self._draining = True
        # httpd.shutdown() blocks on serve_forever's exit event, which
        # never fires for a server that was bound but never started
        if getattr(self, "_serving", False):
            self.httpd.shutdown()
        # let in-flight handler threads finish before tearing down the
        # engine/coalescer they are using
        with self._inflight_cv:
            self._inflight_cv.wait_for(lambda: self._inflight == 0,
                                       timeout=10.0)
        if self.state_dir:
            self._save_state()
        self.httpd.server_close()
        if self.binary is not None:
            self.binary.close()
        self.coalescer.close()
        if self.pool is not None:
            self.pool.close()

    def __enter__(self) -> "PredictionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------- queries
    def health(self) -> Dict:
        with self._cal_lock:
            n_cal = len(self.calibrations)
        bin_addr = self.binary_address
        return {"status": "draining" if self._draining else "ok",
                "draining": self._draining,
                "wire_version": codec.WIRE_VERSION,
                "hardware": sorted(hardware.REGISTRY),
                "n_calibrations": n_cal,
                "uptime_s": time.time() - self.started_at,
                "n_requests": self.n_requests,
                "pool_jobs": self.pool.njobs if self.pool else 0,
                # binary auto-negotiation: clients probe health over HTTP
                # and upgrade when a binary port is advertised
                "binary_port": bin_addr[1] if bin_addr else None}

    def stats(self) -> Dict:
        """One stats schema for both transports: HTTP's
        ``GET /v1/cache_stats`` and the binary ``OP_CACHE_STATS`` frame
        both return exactly this document — engine cache counters,
        every coalescer counter (dedup/shed/isolation included), the
        live fused-row budget, and binary-frontend connection counters
        (zeroed when no binary port is bound, so the schema never
        changes shape between transports).

        Every component contributes a *consistent* snapshot taken under
        its own counter lock — the document can never show a torn
        combination like ``deduped_requests`` > ``requests``."""
        out = dict(self.engine.cache_stats())
        out.update({f"coalescer_{k}": v
                    for k, v in self.coalescer.stats_snapshot().items()})
        out["coalescer_max_fused_rows"] = self.coalescer.max_fused_rows
        if self.binary is not None:
            out.update({f"binary_{k}": v
                        for k, v in self.binary.stats_snapshot().items()})
        else:
            from .binserver import BinaryFrontend
            out.update({f"binary_{k}": 0
                        for k in BinaryFrontend.STAT_KEYS})
        return out

    def metrics_text(self) -> str:
        """The Prometheus text exposition both transports serve:
        ``GET /v1/metrics`` returns it verbatim as ``text/plain`` (so a
        stock Prometheus scraper needs no adapter) and the binary
        ``OP_METRICS`` frame wraps the same string in a MSG_JSON."""
        return metrics.render_prometheus()

    def _observe_request(self, transport: str, op: str,
                         trace_id: Optional[str], duration_s: float,
                         status: int) -> None:
        """Transport-level request accounting: counter + latency
        histogram (exemplar = this trace), plus a structured slow-log
        line when the request crossed ``--slow-request-ms``."""
        self._m_requests[transport].inc()
        self._m_request_s[transport].observe(duration_s, trace_id=trace_id)
        if self.slow_request_ms is not None \
                and duration_s * 1e3 >= self.slow_request_ms:
            self._m_slow.inc()
            trace.slow_log({"event": "slow_request",
                            "transport": transport, "op": op,
                            "trace_id": trace_id,
                            "duration_ms": round(duration_s * 1e3, 3),
                            "status": status,
                            "threshold_ms": self.slow_request_ms},
                           sink=self._slow_log_sink)

    # ------------------------------------------------ admission control
    def _admit_mutation(self, headers) -> None:
        """Gate a mutating request: shared-secret auth first (401 beats
        429 — an attacker must not be able to probe the rate limiter),
        then the token bucket."""
        if self._auth_token is not None:
            supplied = headers.get(errors.AUTH_HEADER)
            if supplied is None:
                bearer = headers.get("Authorization", "")
                if bearer.startswith("Bearer "):
                    supplied = bearer[len("Bearer "):]
            if supplied is None or not hmac.compare_digest(
                    supplied.encode("utf-8", "replace"),
                    self._auth_token.encode("utf-8")):
                raise errors.Unauthorized(
                    f"mutating endpoints require the shared token in the "
                    f"{errors.AUTH_HEADER} header (or Authorization: "
                    f"Bearer)")
        if self._mutate_bucket is not None:
            wait = self._mutate_bucket.try_acquire()
            if wait > 0:
                raise errors.RateLimited(
                    f"mutation rate limit "
                    f"({self._mutate_bucket.rate:g}/s) exceeded",
                    retry_after_s=wait)

    # ------------------------------------------------ state persistence
    def _state_file(self) -> str:
        return os.path.join(self.state_dir, "calibrations.json")

    def _load_state(self) -> None:
        """Reload ``register_as`` calibrations snapshotted by a previous
        instance's drain.  A corrupt snapshot is a warning, not a crash —
        the server must come up (clients re-calibrate idempotently)."""
        path = self._state_file()
        try:
            with open(path, "r", encoding="utf-8") as f:
                blob = json.load(f)
            from ..core.calibrate import Calibration
            for name, d in dict(blob.get("calibrations", {})).items():
                self.calibrations[str(name)] = _NamedCalibration(
                    str(name), Calibration.from_dict(d))
        except FileNotFoundError:
            return
        except Exception as e:               # noqa: BLE001
            print(f"[serve] ignoring corrupt state file {path}: {e}",
                  file=sys.stderr)
            self.calibrations.clear()

    def _save_state(self) -> None:
        """Atomic snapshot (tmp + rename): a kill mid-write leaves the
        previous snapshot intact, never a half-written one."""
        os.makedirs(self.state_dir, exist_ok=True)
        path = self._state_file()
        with self._cal_lock:
            blob = {"calibrations": {name: nc.cal.to_dict()
                                     for name, nc in
                                     self.calibrations.items()}}
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        os.replace(tmp, path)

    # ------------------------------------------------- hardware library
    def hardware_directory(self) -> Dict:
        """GET /v1/hardware: every registry entry with a one-line summary
        (loads each entry — the directory is a browsing endpoint, not the
        hot path)."""
        out: Dict[str, Dict] = {}
        for name in sorted(hardware.REGISTRY):
            p = hardware.get(name)
            out[name] = {
                "vendor": p.vendor, "model_family": p.model_family,
                "num_sms": p.num_sms,
                "hbm_capacity_bytes": p.hbm_capacity,
                "hbm_sustained_bw": p.hbm_sustained_bw,
            }
        return {"hardware": out, "count": len(out)}

    def hardware_entry(self, name: str) -> bytes:
        """GET /v1/hardware/<name>: one entry as a HARDWARE message.

        File-backed entries travel with their full audit trail
        (provenance/units/source); runtime registrations (or entries that
        shadowed their file) travel as bare parameters."""
        from ..core import hwlib
        p = hardware.get(name)       # pointed KeyError when unknown
        path = hwlib.library_file(name)
        if path is not None:
            entry = hwlib.load_file(path)
            if entry.params == p:
                return codec.encode_hardware(entry)
        return codec.encode_hardware(p)

    def register_hardware(self, body: bytes, *,
                          overwrite: bool = False) -> bytes:
        """POST /v1/hardware: schema-validate and register an entry.

        Idempotent under the client's retry contract: re-posting a
        payload identical to the live entry succeeds without
        ``overwrite``; a *different* payload for a taken name still
        raises the collision error."""
        entry = codec.decode_hardware(body)
        p = entry.params
        existed = p.name in hardware.REGISTRY
        if existed and not overwrite and hardware.get(p.name) == p:
            return codec.encode_json({"registered": p.name,
                                      "replaced": False})
        hardware.register(p, overwrite=overwrite)
        return codec.encode_json({"registered": p.name,
                                  "replaced": existed})

    def delete_hardware(self, name: str) -> bytes:
        """DELETE /v1/hardware/<name>: tombstone-delete a registry entry
        (file-backed entries stay masked until re-registered).

        Raises ``KeyError`` (-> 404) on unknown names.  Under the retry
        contract a re-sent DELETE may observe the 404 its own first
        attempt caused — clients treat 404-on-retry as success."""
        del hardware.REGISTRY[name]          # KeyError -> 404
        return codec.encode_json({"deleted": name})

    # ---------------------------------------------- calibration-as-data
    def calibrate(self, body: bytes) -> bytes:
        """POST /v1/calibrate: fit disclosed multipliers for an uploaded
        measured suite against this server's own predictions, with the
        paper's train/holdout discipline (§IV-D).

        Deterministic (seeded split), so a client retry re-fits to the
        identical calibration — ``register_as`` stays idempotent."""
        from ..core import calibrate as calibrate_mod
        suite, params = codec.decode_calibrate_request(body)
        hw = hardware.get(params["hw"])
        model = params.get("model")

        def predict_fn(w):
            return self.engine.predict(w, hw, model=model)

        cal, report = calibrate_mod.fit_with_holdout(
            suite.workloads, suite.measured_s, predict_fn,
            mode=params["mode"],
            holdout_fraction=float(params.get("holdout_fraction", 0.3)),
            seed=int(params.get("seed", 0)))
        name = params.get("register_as")
        if name:
            with self._cal_lock:
                self.calibrations[str(name)] = _NamedCalibration(
                    str(name), cal)
        return codec.encode_calibration(cal, report)

    def _resolve_calibration(self, meta: Dict
                             ) -> Optional[_NamedCalibration]:
        name = meta.get("calibration")
        if name is None:
            return None
        with self._cal_lock:
            cal = self.calibrations.get(name)
        if cal is None:
            with self._cal_lock:
                known = sorted(self.calibrations)
            raise KeyError(
                f"unknown calibration '{name}' (registered: {known}); "
                f"POST /v1/calibrate with register_as first")
        return cal

    def handle_request(self, body: bytes,
                       expect_op: Optional[str] = None,
                       deadline: Optional[float] = None,
                       trace_id: Optional[str] = None) -> bytes:
        """Decode one REQUEST message, answer it, encode the reply.

        ``deadline`` is a ``time.monotonic()`` cutoff (from the client's
        ``X-Repro-Deadline-S`` budget): coalesced requests carry it into
        the queue and are shed there; direct paths check it once before
        evaluating.  ``trace_id`` (the transport's, e.g. the
        ``X-Repro-Trace`` header) wins over the request meta's.  Split
        out from the HTTP layer so tests can drive the full
        decode-dispatch-encode path without sockets."""
        t0 = time.monotonic()
        op, source, meta = codec.decode_request(body)
        _stage_hist("parse").observe(time.monotonic() - t0,
                                     trace_id=trace_id)
        if expect_op is not None and op != expect_op:
            raise codec.WireFormatError(
                f"endpoint /v1/{expect_op} got a request for op {op!r}")
        return self.answer_decoded(op, source, meta, deadline=deadline,
                                   trace_id=trace_id)

    def _resolve_sweep(self, meta: Dict):
        """Resolve a decoded request's metadata against server state:
        ``(hw, model, k, objectives, calibration, max_rows)``.  Raises
        the same typed errors as the HTTP path (KeyError for unknown
        hardware/calibration, ValueError for a bad hint)."""
        hw = hardware.get(meta["hw"])
        model = meta.get("model")
        k = meta.get("k")
        objectives = tuple(meta["objectives"]) if meta.get("objectives") \
            else None
        calibration = self._resolve_calibration(meta)
        max_rows = meta.get("max_fused_rows")
        if max_rows is not None:
            # a hint, clamped server-side: it may tighten the fused-batch
            # budget for batches this request joins, never widen it
            if not isinstance(max_rows, int) or isinstance(max_rows, bool) \
                    or max_rows < 1:
                raise ValueError(
                    f"invalid max_fused_rows hint {max_rows!r}: want an "
                    f"int >= 1")
            max_rows = min(max_rows, self.coalescer.max_fused_rows)
        return hw, model, k, objectives, calibration, max_rows

    def answer_decoded(self, op: str, source, meta: Dict,
                       deadline: Optional[float] = None,
                       trace_id: Optional[str] = None) -> bytes:
        """Answer one already-decoded request (shared by the HTTP handler
        via ``handle_request`` and the binary front end, which decodes on
        its event loop but answers here on a worker)."""
        if trace_id is None:
            # the codec meta's additive trace_id field — the only channel
            # on the binary transport (frames have no headers)
            trace_id = trace.coerce_trace_id(meta.get("trace_id"))
        hw, model, k, objectives, calibration, max_rows = \
            self._resolve_sweep(meta)
        if deadline is not None and time.monotonic() >= deadline \
                and not (isinstance(source, WorkloadTable)
                         and meta.get("coalesce", True)):
            # coalesced requests get shed inside the queue instead, so
            # the shed is attributed (stats) and ordered with batchmates
            raise errors.DeadlineExceeded(
                "request deadline expired before evaluation")
        if isinstance(source, WorkloadTable):
            if meta.get("coalesce", True):
                result = self.coalescer.submit(op, source, hw, model,
                                               k=k, objectives=objectives,
                                               calibration=calibration,
                                               deadline=deadline,
                                               max_rows=max_rows,
                                               trace_id=trace_id)
            else:
                t_eval = time.monotonic()
                res = self.engine.predict_table(
                    source, hw, model=model,
                    calibration=calibration.cal if calibration else None)
                result = Coalescer._answer(
                    res, _Pending(op, source, k, objectives), 0, None)
                trace.record_span("serve.eval", trace_id,
                                  time.monotonic() - t_eval,
                                  op=op, solo=True, coalesce=False)
            t_enc = time.monotonic()
            out = (codec.encode_totals(result) if op == "predict_table"
                   else codec.encode_winners(result))
            _stage_hist("encode").observe(time.monotonic() - t_enc,
                                          trace_id=trace_id)
            return out
        return self._handle_spec(op, source, hw, model, k, objectives,
                                 meta, calibration)

    def _handle_spec(self, op: str, spec: LatticeSpec, hw,
                     model: Optional[str], k, objectives, meta,
                     calibration: Optional[_NamedCalibration] = None
                     ) -> bytes:
        kw = dict(chunk_size=meta.get("chunk_size"), model=model,
                  engine=self.engine, jobs=meta.get("jobs"),
                  pool=self.pool,
                  calibration=calibration.cal if calibration else None)
        if op == "argmin":
            return codec.encode_winners([sweep.argmin_stream(spec, hw,
                                                             **kw)])
        if op == "topk":
            return codec.encode_winners(sweep.topk_stream(
                spec, hw, 1 if k is None else int(k), **kw))
        if op == "pareto":
            return codec.encode_winners(sweep.pareto_stream(
                spec, hw, objectives=objectives or ("compute", "memory"),
                **kw))
        return codec.encode_totals(
            sweep.predict_totals_stream(spec, hw, **kw))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Serve analytical sweep predictions over HTTP "
                    "(wire format: repro.serve.codec)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8707,
                    help="0 binds an ephemeral port (printed on start)")
    ap.add_argument("--binary-port", type=int, default=None,
                    help="also serve the length-prefixed binary protocol "
                         "(repro.serve.framing) on this port; 0 binds an "
                         "ephemeral port (printed on start); omit to "
                         "serve HTTP only")
    ap.add_argument("--max-fused-rows", type=int, default=None,
                    help="coalescer fused-batch cost budget in estimated "
                         "vectorized-row units (scalar-fallback rows "
                         f"count {SCALAR_ROW_COST}x; default "
                         f"{MAX_FUSED_ROWS})")
    ap.add_argument("--jobs", type=int, default=None,
                    help="worker pool size for streamed-lattice requests "
                         "(0 = every core; omit for serial)")
    ap.add_argument("--coalesce-window-ms", type=float,
                    default=DEFAULT_COALESCE_WINDOW_S * 1e3)
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="coalescer admission bound: submissions past "
                         "this many parked requests are shed with 503 "
                         f"(default {DEFAULT_MAX_QUEUE_DEPTH})")
    ap.add_argument("--auth-token",
                    default=os.environ.get("REPRO_SERVE_TOKEN"),
                    help="shared secret gating mutating endpoints "
                         "(default: $REPRO_SERVE_TOKEN; unset = open)")
    ap.add_argument("--mutate-rps", type=float, default=None,
                    help="token-bucket rate limit (requests/s) on "
                         "mutating endpoints (unset = unlimited)")
    ap.add_argument("--mutate-burst", type=int, default=5,
                    help="token-bucket burst for --mutate-rps")
    ap.add_argument("--state-dir", default=None,
                    help="snapshot register_as calibrations here on "
                         "drain and reload them on startup")
    ap.add_argument("--straggler-timeout-s", type=float, default=None,
                    help="re-dispatch a worker-pool shard that exceeds "
                         "this many seconds (unset = wait forever)")
    ap.add_argument("--metrics", choices=("on", "off"), default="on",
                    help="observability kill switch: 'off' disables every "
                         "counter/histogram/span process-wide (the "
                         "/v1/metrics surface stays up but stops moving)")
    ap.add_argument("--slow-request-ms", type=float, default=None,
                    help="emit a structured JSON log line to stderr for "
                         "every sweep request slower than this many ms "
                         "(carries the request's trace id; unset = off)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    server = PredictionServer(
        args.host, args.port, jobs=args.jobs,
        coalesce_window_s=args.coalesce_window_ms / 1e3,
        quiet=not args.verbose,
        auth_token=args.auth_token,
        max_queue_depth=args.max_queue_depth,
        mutate_rps=args.mutate_rps,
        mutate_burst=args.mutate_burst,
        state_dir=args.state_dir,
        straggler_timeout_s=args.straggler_timeout_s,
        binary_port=args.binary_port,
        max_fused_rows=args.max_fused_rows,
        metrics_enabled=(args.metrics == "on"),
        slow_request_ms=args.slow_request_ms)
    host, port = server.address
    # SIGTERM begins a graceful drain: stop accepting, 503 new work,
    # finish in-flight requests, snapshot --state-dir, reap the pool —
    # a bare process kill would instead orphan worker-pool children
    # (supervisors and benchmarks terminate the server with SIGTERM)
    import signal
    signal.signal(signal.SIGTERM, lambda *_: server.begin_drain())
    # parsed by clients that spawn the server as a subprocess — keep stable
    print(f"[serve] listening on http://{host}:{port}", flush=True)
    if server.binary is not None:
        bhost, bport = server.binary_address
        # second banner line, also parsed by subprocess spawners
        print(f"[serve] binary on {bhost}:{bport}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()


if __name__ == "__main__":
    main()

"""Spawn a ``repro.serve.server`` subprocess and parse its banner.

One copy of the PYTHONPATH plumbing, ``[serve] listening on http://...``
banner parsing, dead-server diagnostics, and kill-the-whole-session
teardown — shared by ``benchmarks/serve_bench.py``,
``examples/serve_predictions.py``, and the end-to-end tests, which had
each grown a slightly different (and slightly wrong) copy.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
from typing import Sequence, Tuple


def start_server_subprocess(
        extra_args: Sequence[str] = (),
        binary: bool = False):
    """Launch ``python -m repro.serve.server --port 0`` in its own session
    and return ``(proc, host, port)`` once the listening banner arrives —
    or ``(proc, host, port, binary_port)`` when ``binary=True``, which
    adds ``--binary-port 0`` and parses the second
    ``[serve] binary on host:port`` banner line.

    A server that dies at import/bind time is reaped and surfaced as a
    ``RuntimeError`` carrying its exit status, not an ``IndexError`` on
    the missing banner.
    """
    env = dict(os.environ)
    src = os.path.normpath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", ".."))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    args = [sys.executable, "-m", "repro.serve.server", "--port", "0"]
    if binary and "--binary-port" not in extra_args:
        args += ["--binary-port", "0"]
    proc = subprocess.Popen(
        [*args, *extra_args],
        stdout=subprocess.PIPE, text=True, env=env,
        start_new_session=True)
    line = proc.stdout.readline()
    if "http://" not in line:
        stop_server_subprocess(proc)
        raise RuntimeError(
            f"server failed to start (exit {proc.poll()}): {line!r}")
    addr = line.rsplit("http://", 1)[1].strip()
    host, port = addr.rsplit(":", 1)
    if not binary:
        return proc, host, int(port)
    line = proc.stdout.readline()
    if "binary on" not in line:
        stop_server_subprocess(proc)
        raise RuntimeError(
            f"server printed no binary banner (exit {proc.poll()}): "
            f"{line!r}")
    _, bport = line.rsplit("binary on ", 1)[1].strip().rsplit(":", 1)
    return proc, host, int(port), int(bport)


def stop_server_subprocess(proc: subprocess.Popen) -> None:
    """SIGTERM (the server's handler reaps its worker pool), then kill the
    whole session as a fallback so a wedged pool child can never outlive
    the caller."""
    proc.terminate()
    try:
        proc.wait(timeout=10)
    except subprocess.TimeoutExpired:
        pass
    try:
        os.killpg(os.getpgid(proc.pid), signal.SIGKILL)
    except (ProcessLookupError, PermissionError, OSError):
        pass

"""Versioned binary wire codec for tables, lattice plans and sweep results.

One message is one self-contained byte string:

    offset  size          field
    0       4             magic ``b"RPRW"``
    4       2             wire version (little-endian u16, currently 1)
    6       2             message type (u16, ``MSG_*``)
    8       4             section count (u32)
    12      24 * count    section table: (tag ``4s``, offset u64, len u64)
    ...                   section payloads, each 8-byte aligned

Sections come in two kinds: small structured metadata travels as one
UTF-8 JSON section (``meta``), bulk numeric data travels as raw
little-endian array bytes (``cols``/``pcod``/``wcod``/``tots``).  A
``WorkloadTable`` is therefore exactly its in-memory shape on the wire —
the (n, NV_COLS) float64 matrix plus two int64 code arrays — and decode
is zero-copy: NumPy views over the received buffer, read-only because the
buffer is immutable, which is precisely the frozen-columns contract the
engine's caches rely on.  ``content_token()`` of a decoded table equals
the sender's (property-tested in tests/test_serve_codec.py).

``LatticeSpec`` messages carry the spec's structural plan (JSON, tiny even
for 10^9-row lattices) plus any built tables the plan references as nested
table messages.  Result messages (``SweepWinner`` lists) are pure JSON —
Python's float repr round-trips bit-exactly, and the stdlib encoder/parser
pair handles NaN/Infinity — while totals columns are raw float64.

Wire version 2 adds the hardware-library and calibration-as-data message
types (``MSG_HARDWARE``/``MSG_CALIBRATION``/``MSG_SUITE``/``MSG_CALREQ``):
hardware entries travel as their schema-validated ``hwlib`` documents
(JSON numbers round-trip floats bit-exactly), measured microbench suites
as workload dicts plus a raw float64 measurement column, and fitted
``Calibration`` objects with their full §IV-D multiplier disclosure.
Every version-1 message decodes unchanged (the envelope and types 1-7
did not move) — a v2 decoder accepts ``version <= 2``.

Malformed input (truncated buffers, bad magic, unsupported versions,
out-of-range section offsets, wrong payload sizes) raises
``WireFormatError`` — never an IndexError or struct.error a server loop
would have to treat as a crash.

Integrity: every encoded message carries a ``csum`` section — the CRC32
of all other section payloads in section-table order.  Decode verifies
it when present, so a bit flip anywhere in the payload bytes (a float in
a column, a digit in the meta JSON, a section offset that reframes the
payload) surfaces as ``WireFormatError`` instead of a silently wrong
prediction.  Messages *without* the section (older encoders, hand-built
v1 payloads) still decode — the check is additive, like wire v2 itself.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.workload import LatticeSpec, NV_COLS, TimeBreakdown, \
    WorkloadTable, row_from_tb, tb_from_row

MAGIC = b"RPRW"
WIRE_VERSION = 2

MSG_TABLE = 1
MSG_SPEC = 2
MSG_REQUEST = 3
MSG_WINNERS = 4
MSG_TOTALS = 5
MSG_JSON = 6
MSG_ERROR = 7
# --- wire version 2 --------------------------------------------------------
MSG_HARDWARE = 8
MSG_CALIBRATION = 9
MSG_SUITE = 10
MSG_CALREQ = 11

_HEADER = struct.Struct("<4sHHI")
_SECTION = struct.Struct("<4sQQ")
_MAX_SECTIONS = 1024

Buf = Union[bytes, bytearray, memoryview]


class WireFormatError(ValueError):
    """Raised for any malformed/unsupported wire payload."""


# ---------------------------------------------------------------------------
# Envelope
# ---------------------------------------------------------------------------

#: integrity section tag: CRC32 over every other section payload, in
#: section-table order, as one LE u32
_CSUM_TAG = b"csum"


def _payload_crc(payloads: Sequence[Buf]) -> int:
    crc = 0
    for payload in payloads:
        crc = zlib.crc32(payload, crc)
    return crc


def _pack(msg_type: int, sections: Sequence[Tuple[bytes, Buf]], *,
          checksum: bool = True) -> bytes:
    """Assemble an envelope; each section payload lands 8-byte aligned so
    float64/int64 decode views are aligned views of the message buffer.
    ``checksum`` stamps the ``csum`` integrity section (always on in
    production; tests craft unstamped messages to drive the downstream
    validation paths the checksum would otherwise shadow)."""
    if checksum:
        crc = _payload_crc([payload for _, payload in sections])
        sections = list(sections) + [
            (_CSUM_TAG, struct.pack("<I", crc))]
    count = len(sections)
    table_end = _HEADER.size + _SECTION.size * count
    parts: List[bytes] = []
    entries = []
    pos = table_end
    for tag, payload in sections:
        pad = (-pos) % 8
        if pad:
            parts.append(b"\x00" * pad)
            pos += pad
        entries.append((tag, pos, len(payload)))
        parts.append(bytes(payload))
        pos += len(payload)
    head = [_HEADER.pack(MAGIC, WIRE_VERSION, msg_type, count)]
    head += [_SECTION.pack(tag, off, ln) for tag, off, ln in entries]
    return b"".join(head + parts)


def _unpack(data: Buf) -> Tuple[int, Dict[bytes, memoryview]]:
    """(msg_type, {tag: payload view}) with every bound checked."""
    mv = memoryview(data)
    if len(mv) < _HEADER.size:
        raise WireFormatError(
            f"truncated message: {len(mv)} bytes < {_HEADER.size}-byte "
            f"header")
    magic, version, msg_type, count = _HEADER.unpack_from(mv, 0)
    if magic != MAGIC:
        raise WireFormatError(f"bad magic {bytes(magic)!r} "
                              f"(expected {MAGIC!r})")
    if version > WIRE_VERSION or version < 1:
        raise WireFormatError(
            f"unsupported wire version {version} (this codec speaks "
            f"<= {WIRE_VERSION})")
    if count > _MAX_SECTIONS:
        raise WireFormatError(f"section count {count} exceeds "
                              f"{_MAX_SECTIONS}")
    table_end = _HEADER.size + _SECTION.size * count
    if len(mv) < table_end:
        raise WireFormatError(
            f"truncated section table: {len(mv)} bytes < {table_end}")
    sections: Dict[bytes, memoryview] = {}
    crc = 0
    for i in range(count):
        tag, off, ln = _SECTION.unpack_from(
            mv, _HEADER.size + _SECTION.size * i)
        if off < table_end or off + ln > len(mv):
            raise WireFormatError(
                f"section {bytes(tag)!r} spans [{off}, {off + ln}) outside "
                f"payload [{table_end}, {len(mv)})")
        view = mv[off:off + ln]
        sections[bytes(tag)] = view
        if tag != _CSUM_TAG:
            crc = zlib.crc32(view, crc)
    stamped = sections.get(_CSUM_TAG)
    if stamped is not None:
        if len(stamped) != 4:
            raise WireFormatError(
                f"checksum section holds {len(stamped)} bytes, expected 4")
        want = struct.unpack("<I", stamped)[0]
        if crc != want:
            raise WireFormatError(
                f"payload checksum mismatch (crc32 {crc:#010x} != stamped "
                f"{want:#010x}) — message corrupted in transit")
    return msg_type, sections


def _expect(data: Buf, want_type: int, label: str
            ) -> Dict[bytes, memoryview]:
    msg_type, sections = _unpack(data)
    if msg_type != want_type:
        raise WireFormatError(
            f"expected {label} message (type {want_type}), got type "
            f"{msg_type}")
    return sections


def _meta(sections: Dict[bytes, memoryview]) -> Dict:
    raw = sections.get(b"meta")
    if raw is None:
        raise WireFormatError("message is missing its meta section")
    try:
        meta = json.loads(bytes(raw).decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireFormatError(f"meta section is not valid JSON: {e}") \
            from None
    if not isinstance(meta, dict):
        raise WireFormatError("meta section must be a JSON object")
    return meta


def _json_bytes(obj) -> bytes:
    return json.dumps(obj, separators=(",", ":")).encode("utf-8")


def _array_section(sections: Dict[bytes, memoryview], tag: bytes,
                   dtype, count: int) -> np.ndarray:
    """Zero-copy typed view over a section, validated against the expected
    element count.  Views of a bytes-backed memoryview are read-only."""
    raw = sections.get(tag)
    if raw is None:
        raise WireFormatError(f"message is missing its {tag!r} section")
    want = count * np.dtype(dtype).itemsize
    if len(raw) != want:
        raise WireFormatError(
            f"section {tag!r} holds {len(raw)} bytes, expected {want} "
            f"({count} x {np.dtype(dtype).name})")
    return np.frombuffer(raw, dtype=dtype)


def message_type(data: Buf) -> int:
    """Peek a message's type (validates the envelope)."""
    return _unpack(data)[0]


# ---------------------------------------------------------------------------
# WorkloadTable
# ---------------------------------------------------------------------------

def encode_table(table: WorkloadTable) -> bytes:
    names = table.names
    if isinstance(names, tuple):
        meta_names: object = list(names)
        names_kind = "rows"
    elif names is None:
        meta_names, names_kind = None, "none"
    else:
        meta_names, names_kind = str(names), "shared"
    hr = None
    if table.hit_rates is not None:
        hr = [None if h is None else sorted(h.items())
              for h in table.hit_rates]
    meta = {
        "n": len(table),
        "nv_cols": NV_COLS,
        "precision_vocab": list(table.precision_vocab),
        "wclass_vocab": list(table.wclass_vocab),
        "names_kind": names_kind,
        "names": meta_names,
        "hit_rates": hr,
        "name_offset": table.name_offset,
    }
    return _pack(MSG_TABLE, [
        (b"meta", _json_bytes(meta)),
        (b"cols", np.ascontiguousarray(table.cols).tobytes()),
        (b"pcod", np.ascontiguousarray(table.precision_codes,
                                       dtype=np.int64).tobytes()),
        (b"wcod", np.ascontiguousarray(table.wclass_codes,
                                       dtype=np.int64).tobytes()),
    ])


def decode_table(data: Buf) -> WorkloadTable:
    """Zero-copy decode: the returned table's columns are read-only NumPy
    views over ``data`` (keep the buffer alive as long as the table)."""
    sections = _expect(data, MSG_TABLE, "table")
    meta = _meta(sections)
    try:
        n = int(meta["n"])
        nv = int(meta["nv_cols"])
        pv = tuple(str(v) for v in meta["precision_vocab"])
        wv = tuple(str(v) for v in meta["wclass_vocab"])
        names_kind = meta["names_kind"]
        name_offset = int(meta["name_offset"])
    except (KeyError, TypeError, ValueError) as e:
        raise WireFormatError(f"bad table meta: {e}") from None
    if n < 0:
        raise WireFormatError(f"negative row count {n}")
    if nv != NV_COLS:
        raise WireFormatError(
            f"table has {nv} numeric columns, this build expects "
            f"{NV_COLS} — incompatible schema generation")
    cols = _array_section(sections, b"cols", np.float64,
                          n * NV_COLS).reshape(n, NV_COLS)
    pcod = _array_section(sections, b"pcod", np.int64, n)
    wcod = _array_section(sections, b"wcod", np.int64, n)
    if len(pcod) and (pv == () or int(pcod.max()) >= len(pv)
                      or int(pcod.min()) < 0):
        raise WireFormatError("precision codes reference entries outside "
                              "the vocabulary")
    if len(wcod) and (wv == () or int(wcod.max()) >= len(wv)
                      or int(wcod.min()) < 0):
        raise WireFormatError("wclass codes reference entries outside "
                              "the vocabulary")
    if names_kind == "rows":
        names_raw = meta.get("names")
        if not isinstance(names_raw, list) or len(names_raw) != n:
            raise WireFormatError("per-row names must list one name per "
                                  "row")
        names: object = tuple(str(x) for x in names_raw)
    elif names_kind == "shared":
        names = str(meta.get("names"))
    elif names_kind == "none":
        names = None
    else:
        raise WireFormatError(f"unknown names_kind {names_kind!r}")
    hr_raw = meta.get("hit_rates")
    hit_rates = None
    if hr_raw is not None:
        if not isinstance(hr_raw, list) or len(hr_raw) != n:
            raise WireFormatError("hit_rates must list one entry per row")
        try:
            hit_rates = tuple(
                None if h is None else
                {str(k): float(v) for k, v in h} for h in hr_raw)
        except (TypeError, ValueError) as e:
            raise WireFormatError(f"bad hit_rates payload: {e}") from None
    return WorkloadTable(cols, pcod.astype(np.intp, copy=False), pv,
                         wcod.astype(np.intp, copy=False), wv,
                         names, hit_rates, name_offset=name_offset)


# ---------------------------------------------------------------------------
# LatticeSpec
# ---------------------------------------------------------------------------

def encode_spec(spec: LatticeSpec) -> bytes:
    tables: List[WorkloadTable] = []

    def sink(table: WorkloadTable) -> int:
        tables.append(table)
        return len(tables) - 1

    plan = spec.to_plan(sink)
    if len(tables) > 99:
        raise WireFormatError(
            f"plan references {len(tables)} built tables (max 99); "
            f"concat them into one table first")
    sections: List[Tuple[bytes, Buf]] = [
        (b"meta", _json_bytes({"plan": plan}))]
    for i, t in enumerate(tables):
        sections.append((f"tb{i:02d}".encode(), encode_table(t)))
    return _pack(MSG_SPEC, sections)


def decode_spec(data: Buf) -> LatticeSpec:
    sections = _expect(data, MSG_SPEC, "spec")
    meta = _meta(sections)
    plan = meta.get("plan")
    if not isinstance(plan, dict):
        raise WireFormatError("spec meta is missing its plan object")
    tables = []
    for i in range(100):
        raw = sections.get(f"tb{i:02d}".encode())
        if raw is None:
            break
        tables.append(decode_table(raw))
    try:
        return LatticeSpec.from_plan(plan, tables)
    except (KeyError, TypeError, ValueError) as e:
        if isinstance(e, WireFormatError):
            raise
        raise WireFormatError(f"bad lattice plan: {e}") from None


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------

REQUEST_OPS = ("predict_table", "argmin", "topk", "pareto")


def encode_request(op: str, source, *, hw: str,
                   model: Optional[str] = None,
                   k: Optional[int] = None,
                   objectives: Optional[Sequence[str]] = None,
                   chunk_size: Optional[int] = None,
                   jobs=None,
                   coalesce: bool = True,
                   calibration: Optional[str] = None,
                   max_fused_rows: Optional[int] = None,
                   trace_id: Optional[str] = None) -> bytes:
    """One prediction request: an operation + its parameters + the sweep
    source (a built ``WorkloadTable`` or a lazy ``LatticeSpec``).
    Hardware travels by registry name — parameter files live server-side.
    ``calibration`` names a server-side calibration (registered via
    ``/v1/calibrate``) whose multipliers scale the predictions.
    ``max_fused_rows`` is a coalescing hint: cap the estimated row-cost
    budget of any fused batch this request joins (clamped server-side —
    a hint can tighten the server's bound, never raise it).
    ``trace_id`` (16-hex, see ``repro.obs.trace``) propagates a client
    trace through both transports; like ``calibration`` it is additive
    — requests without one stay byte-identical to v1 payloads.
    """
    if op not in REQUEST_OPS:
        raise ValueError(f"unknown op {op!r}; valid: {REQUEST_OPS}")
    meta = {"op": op, "hw": str(hw), "model": model, "k": k,
            "objectives": list(objectives) if objectives else None,
            "chunk_size": chunk_size, "jobs": jobs,
            "coalesce": bool(coalesce)}
    if calibration is not None:
        # only stamped when used: v2 request metas without calibration
        # stay byte-identical to v1 ones
        meta["calibration"] = str(calibration)
    if max_fused_rows is not None:
        if int(max_fused_rows) < 1:
            raise ValueError(
                f"max_fused_rows must be >= 1, got {max_fused_rows}")
        meta["max_fused_rows"] = int(max_fused_rows)
    if trace_id is not None:
        meta["trace_id"] = str(trace_id)
    sections: List[Tuple[bytes, Buf]] = [(b"meta", _json_bytes(meta))]
    if isinstance(source, WorkloadTable):
        sections.append((b"tabl", encode_table(source)))
    elif isinstance(source, LatticeSpec):
        sections.append((b"spec", encode_spec(source)))
    else:
        raise TypeError(f"source must be WorkloadTable or LatticeSpec, "
                        f"got {type(source).__name__}")
    return _pack(MSG_REQUEST, sections)


def decode_request(data: Buf):
    """(op, source, params dict).  ``source`` is a WorkloadTable or a
    LatticeSpec; params carries hw/model/k/objectives/chunk_size/jobs/
    coalesce exactly as sent."""
    sections = _expect(data, MSG_REQUEST, "request")
    meta = _meta(sections)
    op = meta.get("op")
    if op not in REQUEST_OPS:
        raise WireFormatError(f"unknown request op {op!r}")
    if not isinstance(meta.get("hw"), str):
        raise WireFormatError("request is missing its hardware name")
    table_raw = sections.get(b"tabl")
    spec_raw = sections.get(b"spec")
    if (table_raw is None) == (spec_raw is None):
        raise WireFormatError(
            "request must carry exactly one of a table or a spec section")
    source = decode_table(table_raw) if table_raw is not None \
        else decode_spec(spec_raw)
    return op, source, meta


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

def _tb_to_jsonable(tb: TimeBreakdown) -> Dict:
    fields, dkeys, dvals = row_from_tb(tb)
    return {"fields": list(fields), "detail_keys": list(dkeys),
            "detail_vals": list(dvals)}


def _tb_from_jsonable(d: Dict) -> TimeBreakdown:
    try:
        return tb_from_row((tuple(float(v) for v in d["fields"]),
                            tuple(str(k) for k in d["detail_keys"]),
                            tuple(float(v) for v in d["detail_vals"])))
    except (KeyError, TypeError, ValueError) as e:
        raise WireFormatError(f"bad breakdown payload: {e}") from None


def encode_winners(winners) -> bytes:
    """A ``SweepWinner`` list (argmin returns a list of one).  Floats are
    JSON round-trip exact (repr shortest round-trip; NaN/Infinity via the
    stdlib's JSON extension)."""
    if not isinstance(winners, (list, tuple)):
        winners = [winners]
    meta = {"winners": [
        {"index": w.index, "name": w.name, "total": w.total,
         "breakdown": _tb_to_jsonable(w.breakdown)} for w in winners]}
    return _pack(MSG_WINNERS, [(b"meta", json.dumps(meta).encode("utf-8"))])


def decode_winners(data: Buf):
    from ..core.sweep import SweepWinner
    sections = _expect(data, MSG_WINNERS, "winners")
    meta = _meta(sections)
    raw = meta.get("winners")
    if not isinstance(raw, list):
        raise WireFormatError("winners meta is missing its list")
    out = []
    for d in raw:
        try:
            out.append(SweepWinner(
                index=int(d["index"]), name=str(d["name"]),
                total=float(d["total"]),
                breakdown=_tb_from_jsonable(d["breakdown"])))
        except (KeyError, TypeError, ValueError) as e:
            if isinstance(e, WireFormatError):
                raise
            raise WireFormatError(f"bad winner payload: {e}") from None
    return out


def encode_totals(totals: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(totals, dtype=np.float64)
    return _pack(MSG_TOTALS, [
        (b"meta", _json_bytes({"n": int(arr.shape[0])})),
        (b"tots", arr.tobytes()),
    ])


def decode_totals(data: Buf) -> np.ndarray:
    """Read-only zero-copy float64 view over the message buffer."""
    sections = _expect(data, MSG_TOTALS, "totals")
    meta = _meta(sections)
    try:
        n = int(meta["n"])
    except (KeyError, TypeError, ValueError) as e:
        raise WireFormatError(f"bad totals meta: {e}") from None
    return _array_section(sections, b"tots", np.float64, n)


def encode_json(obj, msg_type: int = MSG_JSON) -> bytes:
    """Small structured payloads (health, cache stats)."""
    return _pack(msg_type, [(b"meta", json.dumps(
        {"payload": obj}).encode("utf-8"))])


def decode_json(data: Buf):
    sections = _expect(data, MSG_JSON, "json")
    return _meta(sections).get("payload")


# ---------------------------------------------------------------------------
# Wire version 2: hardware library + calibration-as-data
# ---------------------------------------------------------------------------

def encode_hardware(entry) -> bytes:
    """A hardware-library entry (``hwlib.HardwareEntry`` or a bare
    ``HardwareParams``) as its schema-validated document.  JSON floats
    round-trip bit-exactly, so a decoded entry predicts identically to
    the sender's."""
    from ..core import hwlib
    if not isinstance(entry, hwlib.HardwareEntry):
        entry = hwlib.HardwareEntry(params=entry)
    return _pack(MSG_HARDWARE, [(b"meta", _json_bytes(
        {"entry": entry.to_doc()}))])


def decode_hardware(data: Buf):
    """-> ``hwlib.HardwareEntry`` (schema-validated; a payload that fails
    the hardware schema raises ``WireFormatError``)."""
    from ..core import hwlib
    sections = _expect(data, MSG_HARDWARE, "hardware")
    meta = _meta(sections)
    doc = meta.get("entry")
    if not isinstance(doc, dict):
        raise WireFormatError("hardware message is missing its entry "
                              "document")
    try:
        return hwlib.load_entry(doc, where="<wire>")
    except hwlib.HardwareSchemaError as e:
        raise WireFormatError(f"bad hardware entry: {e}") from None


def encode_calibration(cal, report: Optional[Dict] = None) -> bytes:
    """A fitted ``core.calibrate.Calibration`` with its full multiplier
    disclosure (paper §IV-D: factors must be disclosed — the wire form IS
    the disclosure), plus the optional train/holdout report."""
    return _pack(MSG_CALIBRATION, [(b"meta", json.dumps(
        {"calibration": cal.to_dict(), "report": report}).encode("utf-8"))])


def decode_calibration(data: Buf):
    """-> (``Calibration``, report dict | None)."""
    from ..core.calibrate import Calibration
    sections = _expect(data, MSG_CALIBRATION, "calibration")
    meta = _meta(sections)
    try:
        cal = Calibration.from_dict(meta.get("calibration"))
    except ValueError as e:
        raise WireFormatError(f"bad calibration payload: {e}") from None
    report = meta.get("report")
    if report is not None and not isinstance(report, dict):
        raise WireFormatError("calibration report must be an object")
    return cal, report


def encode_suite(suite) -> bytes:
    """A measured microbench suite (``microbench.MeasuredSuite``):
    workload characterizations as JSON, the measured medians as one raw
    float64 column."""
    meas = np.ascontiguousarray(suite.measured_s, dtype=np.float64)
    meta = {"name": suite.name,
            "workloads": [w.to_dict() for w in suite.workloads],
            "meta": dict(suite.meta), "n": int(meas.shape[0])}
    return _pack(MSG_SUITE, [(b"meta", _json_bytes(meta)),
                             (b"meas", meas.tobytes())])


def decode_suite(data: Buf):
    """-> ``microbench.MeasuredSuite`` (measured column read as float64)."""
    from ..core.microbench import MeasuredSuite
    sections = _expect(data, MSG_SUITE, "suite")
    meta = _meta(sections)
    try:
        n = int(meta["n"])
    except (KeyError, TypeError, ValueError) as e:
        raise WireFormatError(f"bad suite meta: {e}") from None
    meas = _array_section(sections, b"meas", np.float64, n)
    try:
        return MeasuredSuite.from_dict(
            {"name": meta.get("name"), "workloads": meta.get("workloads"),
             "measured_s": meas.tolist(), "meta": meta.get("meta")})
    except ValueError as e:
        raise WireFormatError(str(e)) from None


CALIBRATE_MODES = ("case", "class")


def encode_calibrate_request(suite, *, hw: str, mode: str = "class",
                             holdout_fraction: float = 0.3, seed: int = 0,
                             model: Optional[str] = None,
                             register_as: Optional[str] = None) -> bytes:
    """'Here are my measured times — fit multipliers against your
    predictions.'  ``register_as`` stores the fit server-side under that
    name so follow-up sweep requests can price against it
    (``encode_request(..., calibration=name)``)."""
    if mode not in CALIBRATE_MODES:
        raise ValueError(f"unknown calibrate mode {mode!r}; valid: "
                         f"{CALIBRATE_MODES}")
    meta = {"hw": str(hw), "mode": mode,
            "holdout_fraction": float(holdout_fraction), "seed": int(seed),
            "model": model, "register_as": register_as}
    return _pack(MSG_CALREQ, [(b"meta", _json_bytes(meta)),
                              (b"suit", encode_suite(suite))])


def decode_calibrate_request(data: Buf):
    """-> (``MeasuredSuite``, params dict with hw/mode/holdout_fraction/
    seed/model/register_as)."""
    sections = _expect(data, MSG_CALREQ, "calibrate-request")
    meta = _meta(sections)
    if not isinstance(meta.get("hw"), str):
        raise WireFormatError("calibrate request is missing its hardware "
                              "name")
    if meta.get("mode") not in CALIBRATE_MODES:
        raise WireFormatError(f"unknown calibrate mode "
                              f"{meta.get('mode')!r}")
    raw = sections.get(b"suit")
    if raw is None:
        raise WireFormatError("calibrate request is missing its suite "
                              "section")
    return decode_suite(raw), meta


class RemoteError(RuntimeError):
    """A server-side failure, re-raised client-side with the original
    exception class name preserved in the message."""


def encode_error(exc: BaseException) -> bytes:
    meta = {"error": type(exc).__name__, "message": str(exc)}
    # ServeFault retry hints travel in-band: the binary transport has no
    # Retry-After header, so the error payload itself carries the hint
    # (additive key — older decoders ignore it)
    retry_after = getattr(exc, "retry_after_s", None)
    if retry_after is not None:
        meta["retry_after_s"] = float(retry_after)
    return _pack(MSG_ERROR, [(b"meta", _json_bytes(meta))])


def decode_error(data: Buf) -> Tuple[str, str, Optional[float]]:
    """Decode an ERROR message to ``(class name, message,
    retry_after_s | None)`` without raising it — the binary client uses
    this to rebuild the server's typed fault (``ServerOverloaded`` et
    al. carry their retryability in the class)."""
    meta = _meta(_expect(data, MSG_ERROR, "error"))
    retry_after = meta.get("retry_after_s")
    if retry_after is not None:
        try:
            retry_after = float(retry_after)
        except (TypeError, ValueError):
            raise WireFormatError(
                f"bad retry_after_s {retry_after!r}") from None
    return (str(meta.get("error", "Error")), str(meta.get("message", "")),
            retry_after)


def raise_if_error(data: Buf) -> None:
    """Raise ``RemoteError`` when ``data`` is an error message; no-op (and
    no validation beyond the envelope) otherwise."""
    if message_type(data) == MSG_ERROR:
        meta = _meta(_unpack(data)[1])
        raise RemoteError(f"{meta.get('error', 'Error')}: "
                          f"{meta.get('message', '')}")

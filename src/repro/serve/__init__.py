"""Prediction-serving subsystem: wire codec + HTTP sweep server + client.

The analytical models answer "what will this kernel cost on B200/MI300A"
in microseconds, which makes them viable as an online pricing service.
This package opens the repo's first cross-process scenario:

``repro.serve.codec``
    Versioned binary wire format for ``WorkloadTable`` (one contiguous
    float64 matrix + two small code arrays — exactly the shape the
    columnar engine consumes, so decode is zero-copy), lazy
    ``LatticeSpec`` plans, and the result types (``SweepWinner`` lists,
    totals columns).

``repro.serve.server``
    Stdlib-only HTTP server that owns one ``SweepEngine`` and a reusable
    worker pool, with request micro-batching: concurrent small requests
    for the same hardware fuse into one columnar evaluation.

``repro.serve.client``
    Blocking client speaking the same codec over ``http.client``.

See ``README.md`` in this directory for the wire format, the coalescing
contract, and when to hit the server vs calling ``SweepEngine``
in-process.
"""
from .codec import (WIRE_VERSION, WireFormatError, decode_calibrate_request,
                    decode_calibration, decode_hardware, decode_json,
                    decode_request, decode_spec, decode_suite, decode_table,
                    decode_totals, decode_winners,
                    encode_calibrate_request, encode_calibration,
                    encode_error, encode_hardware, encode_json,
                    encode_request, encode_spec, encode_suite, encode_table,
                    encode_totals, encode_winners, raise_if_error)


def __getattr__(name):
    # lazy so `python -m repro.serve.server` doesn't import the server
    # module twice (once via the package, once as __main__)
    if name == "PredictionClient":
        from .client import PredictionClient
        return PredictionClient
    if name == "PredictionServer":
        from .server import PredictionServer
        return PredictionServer
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "WIRE_VERSION", "WireFormatError", "PredictionClient",
    "PredictionServer", "decode_calibrate_request", "decode_calibration",
    "decode_hardware", "decode_json", "decode_request", "decode_spec",
    "decode_suite", "decode_table", "decode_totals", "decode_winners",
    "encode_calibrate_request", "encode_calibration", "encode_error",
    "encode_hardware", "encode_json", "encode_request", "encode_spec",
    "encode_suite", "encode_table", "encode_totals", "encode_winners",
    "raise_if_error",
]

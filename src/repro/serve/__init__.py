"""Prediction-serving subsystem: wire codec + HTTP sweep server + client.

The analytical models answer "what will this kernel cost on B200/MI300A"
in microseconds, which makes them viable as an online pricing service.
This package opens the repo's first cross-process scenario:

``repro.serve.codec``
    Versioned binary wire format for ``WorkloadTable`` (one contiguous
    float64 matrix + two small code arrays — exactly the shape the
    columnar engine consumes, so decode is zero-copy), lazy
    ``LatticeSpec`` plans, and the result types (``SweepWinner`` lists,
    totals columns).

``repro.serve.server``
    Stdlib-only HTTP server that owns one ``SweepEngine`` and a reusable
    worker pool, with request micro-batching: concurrent small requests
    for the same hardware fuse into one columnar evaluation.

``repro.serve.client``
    Blocking client speaking the same codec over ``http.client``, with
    retries + backoff, split connect/read timeouts, per-call deadlines
    and a circuit breaker.

``repro.serve.errors``
    The typed fault vocabulary (``Unauthorized``, ``RateLimited``,
    ``ServerOverloaded``, ``DeadlineExceeded``, ``CircuitOpenError``)
    shared by both sides, plus the status-code contract.

``repro.serve.chaos``
    Deterministic fault-injection TCP proxy (delay/stall/truncate/
    bitflip/sever on a seeded schedule) used by the fault-tolerance
    tests and the availability-under-chaos bench section.

See ``README.md`` in this directory for the wire format, the coalescing
contract, the robustness/status-code contract, and when to hit the
server vs calling ``SweepEngine`` in-process.
"""
from .codec import (WIRE_VERSION, RemoteError, WireFormatError,
                    decode_calibrate_request,
                    decode_calibration, decode_hardware, decode_json,
                    decode_request, decode_spec, decode_suite, decode_table,
                    decode_totals, decode_winners,
                    encode_calibrate_request, encode_calibration,
                    encode_error, encode_hardware, encode_json,
                    encode_request, encode_spec, encode_suite, encode_table,
                    encode_totals, encode_winners, raise_if_error)
from .errors import (CircuitOpenError, DeadlineExceeded, RateLimited,
                     ServeFault, ServerOverloaded, Unauthorized)


def __getattr__(name):
    # lazy so `python -m repro.serve.server` doesn't import the server
    # module twice (once via the package, once as __main__)
    if name == "PredictionClient":
        from .client import PredictionClient
        return PredictionClient
    if name == "PredictionServer":
        from .server import PredictionServer
        return PredictionServer
    if name in ("ChaosProxy", "FaultSpec", "seeded_schedule"):
        from . import chaos
        return getattr(chaos, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "WIRE_VERSION", "ChaosProxy", "CircuitOpenError", "DeadlineExceeded",
    "FaultSpec", "PredictionClient", "PredictionServer", "RateLimited",
    "RemoteError", "ServeFault", "ServerOverloaded", "Unauthorized",
    "WireFormatError", "decode_calibrate_request", "decode_calibration",
    "decode_hardware", "decode_json", "decode_request", "decode_spec",
    "decode_suite", "decode_table", "decode_totals", "decode_winners",
    "encode_calibrate_request", "encode_calibration", "encode_error",
    "encode_hardware", "encode_json", "encode_request", "encode_spec",
    "encode_suite", "encode_table", "encode_totals", "encode_winners",
    "raise_if_error", "seeded_schedule",
]

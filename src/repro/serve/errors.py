"""Typed fault vocabulary for the serving stack.

Every way the serve stack can refuse, shed, or fail a request maps to
one exception class here, shared by server (which maps them to HTTP
status codes) and client (which reconstructs them from status codes and
decides retryability).  The contract, documented in ``README.md``:

    400  malformed request (``WireFormatError``/``ValueError``/...)
    401  ``Unauthorized``     — mutating endpoint, bad/missing token
    404  unknown endpoint / unknown hardware entry
    411  missing Content-Length
    413  body exceeds ``MAX_BODY_BYTES``
    429  ``RateLimited``      — mutating-endpoint token bucket empty
    503  ``ServerOverloaded`` — coalescer queue beyond its depth bound,
         server draining, or the request's propagated deadline already
         expired (``DeadlineExceeded``)

``RateLimited``/``ServerOverloaded`` replies carry a ``Retry-After``
header; they (plus transport faults) are the *retryable* class — the
client backs off and re-sends because every endpoint is idempotent.
``Unauthorized`` and ordinary 400s are terminal.  ``CircuitOpenError``
and ``DeadlineExceeded`` can also originate purely client-side: a
breaker refusing to touch a dead server, or a per-call deadline running
out before/while retrying.
"""
from __future__ import annotations

#: HTTP header carrying the caller's remaining deadline budget in
#: (float) seconds at send time.  The server sheds work whose budget is
#: already spent — an answer the client has stopped waiting for is pure
#: wasted evaluation.
DEADLINE_HEADER = "X-Repro-Deadline-S"

#: Auth header for the mutating endpoints (``X-Auth-Token: <secret>``;
#: ``Authorization: Bearer <secret>`` is accepted too).
AUTH_HEADER = "X-Auth-Token"


class ServeFault(RuntimeError):
    """Base class for every typed serving fault."""

    #: safe to re-send after backing off (all endpoints are idempotent)
    retryable = False


class Unauthorized(ServeFault):
    """Mutating endpoint called without the server's shared secret."""


class RateLimited(ServeFault):
    """Mutating-endpoint token bucket is empty (HTTP 429)."""

    retryable = True

    def __init__(self, message: str, retry_after_s: float = 0.1):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class ServerOverloaded(ServeFault):
    """Load shed: coalescer queue beyond its depth bound, or the server
    is draining (HTTP 503 + ``Retry-After``)."""

    retryable = True

    def __init__(self, message: str, retry_after_s: float = 0.05):
        super().__init__(message)
        self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(ServeFault):
    """The request's deadline budget ran out — either server-side (the
    propagated budget expired while queued, HTTP 503) or client-side
    (the per-call ``deadline_s`` elapsed across connect/read/retries)."""


class CircuitOpenError(ServeFault):
    """Client-side circuit breaker is open: recent consecutive connect
    failures mean the server is down — fail fast instead of paying a
    connect timeout per call.  Closes again after a cooldown probe
    succeeds."""

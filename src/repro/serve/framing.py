"""Binary frame layer (framing version 1) for the persistent-socket
transport.

The codec (``repro.serve.codec``) already makes every payload a
self-contained, CRC32-stamped binary message; HTTP added nothing but
text framing, header parsing, and a thread handoff per request.  This
module replaces that framing with a fixed 24-byte header:

    offset  size  field
    0       4     magic ``b"RPB1"`` (framing version is baked into the
                  magic — ``RPB2`` would be a new, incompatible framing)
    4       1     op (u8, ``OP_*``)
    5       1     flags (u8; reply-only ``FLAG_ERROR``)
    6       2     reserved (u16, must be 0)
    8       4     payload length (LE u32, bounded by
                  ``MAX_FRAME_BYTES``)
    12      8     request id (LE u64, client-chosen, echoed verbatim in
                  the reply)
    20      4     deadline budget (LE f32 relative seconds; 0 = none —
                  same no-clock-sync semantics as the HTTP
                  ``X-Repro-Deadline-S`` header)
    24      ...   payload: one ``repro.serve.codec`` message

Request ids exist for **pipelining**: a client may write many frames
down one socket before reading anything back, and replies may return in
any order (the server's coalescer completes fused batches as they
finish) — each reply carries the id of the request it answers.  Ids
must be unique among a connection's in-flight requests; the server
closes the connection on a duplicate rather than risk handing one
reply to two callers.

Strictness is the point of the fixed header: bad magic, a nonzero
reserved field, an unknown op, an unknown flag bit, or a length beyond
``MAX_FRAME_BYTES`` all raise ``WireFormatError`` from the parser —
after which the stream offset can no longer be trusted, so both sides
close the connection instead of resynchronizing heuristically.  A
*truncated* frame is not an error (more bytes may arrive); the reader's
timeout bounds how long anyone waits for the remainder.

``FrameParser`` is the shared incremental reader (server event loop and
client demultiplexer both feed received bytes in and iterate complete
frames out); ``pack_frame`` is the shared writer.  Everything here is
transport-agnostic byte shuffling — no sockets, no threads.
"""
from __future__ import annotations

import math
import struct
from typing import Iterator, NamedTuple

from .codec import WireFormatError

__all__ = ["BIN_MAGIC", "FLAG_ERROR", "Frame", "FrameParser", "HEADER",
           "MAX_FRAME_BYTES", "OP_CACHE_STATS", "OP_HEALTH", "OP_METRICS",
           "OP_NAMES", "OP_SWEEP", "pack_frame"]

BIN_MAGIC = b"RPB1"

#: one frame's payload may not exceed this (mirrors the HTTP front end's
#: ``MAX_BODY_BYTES``: a 2^31-row table is a streamed lattice plan, not
#: an upload)
MAX_FRAME_BYTES = 1 << 30

HEADER = struct.Struct("<4sBBHIQf")

OP_HEALTH = 1        #: empty payload -> MSG_JSON health document
OP_CACHE_STATS = 2   #: empty payload -> MSG_JSON stats document
OP_SWEEP = 3         #: MSG_REQUEST payload -> MSG_WINNERS / MSG_TOTALS
OP_METRICS = 4       #: empty payload -> MSG_JSON Prometheus text snapshot

OP_NAMES = {OP_HEALTH: "health", OP_CACHE_STATS: "cache_stats",
            OP_SWEEP: "sweep", OP_METRICS: "metrics"}

#: reply flag: the payload is a ``MSG_ERROR`` codec message
FLAG_ERROR = 0x01

_KNOWN_FLAGS = FLAG_ERROR


class Frame(NamedTuple):
    op: int
    flags: int
    req_id: int
    deadline_s: float
    payload: bytes


def pack_frame(op: int, req_id: int, payload: bytes, *, flags: int = 0,
               deadline_s: float = 0.0) -> bytes:
    """One header + payload byte string (a single ``sendall`` per frame —
    with ``TCP_NODELAY`` that is one segment burst, no Nagle/delayed-ACK
    stall like the HTTP front end's split header/body writes)."""
    if op not in OP_NAMES:
        raise ValueError(f"unknown op {op}; valid: {sorted(OP_NAMES)}")
    if len(payload) > MAX_FRAME_BYTES:
        raise ValueError(f"payload of {len(payload)} bytes exceeds "
                         f"MAX_FRAME_BYTES ({MAX_FRAME_BYTES})")
    if not 0 <= int(req_id) < 1 << 64:
        raise ValueError(f"request id {req_id} outside u64 range")
    return HEADER.pack(BIN_MAGIC, op, flags, 0, len(payload),
                       int(req_id), float(deadline_s)) + payload


class FrameParser:
    """Incremental frame reader: ``feed()`` received bytes, iterate
    ``frames()``.  Malformed headers raise ``WireFormatError`` and poison
    the parser (the stream offset is untrustworthy after a framing error
    — the owner must close the connection)."""

    __slots__ = ("_buf", "_dead", "max_frame_bytes")

    def __init__(self, max_frame_bytes: int = MAX_FRAME_BYTES):
        self._buf = bytearray()
        self._dead = False
        self.max_frame_bytes = int(max_frame_bytes)

    def __len__(self) -> int:
        return len(self._buf)

    def feed(self, data: bytes) -> None:
        if self._dead:
            raise WireFormatError(
                "frame stream already failed — close the connection")
        self._buf += data

    def frames(self) -> Iterator[Frame]:
        """Yield every complete frame buffered so far; stop (without
        error) at a partial frame."""
        while True:
            frame = self._next()
            if frame is None:
                return
            yield frame

    def _next(self):
        buf = self._buf
        if self._dead:
            raise WireFormatError(
                "frame stream already failed — close the connection")
        if len(buf) < HEADER.size:
            return None
        magic, op, flags, reserved, length, req_id, deadline_s = \
            HEADER.unpack_from(buf)
        try:
            if magic != BIN_MAGIC:
                raise WireFormatError(
                    f"bad frame magic {bytes(magic)!r} (expected "
                    f"{BIN_MAGIC!r}) — stream desynchronized")
            if reserved != 0:
                raise WireFormatError(
                    f"nonzero reserved header field {reserved:#06x}")
            if op not in OP_NAMES:
                raise WireFormatError(f"unknown frame op {op}")
            if flags & ~_KNOWN_FLAGS:
                raise WireFormatError(
                    f"unknown frame flag bits {flags:#04x}")
            if length > self.max_frame_bytes:
                raise WireFormatError(
                    f"frame payload of {length} bytes exceeds "
                    f"{self.max_frame_bytes}")
            if math.isnan(deadline_s) or math.isinf(deadline_s) \
                    or deadline_s < 0.0:
                raise WireFormatError(
                    f"invalid frame deadline {deadline_s!r}: want a "
                    f"non-negative relative seconds budget")
        except WireFormatError:
            self._dead = True
            raise
        end = HEADER.size + length
        if len(buf) < end:
            return None
        payload = bytes(buf[HEADER.size:end])
        del buf[:end]
        return Frame(op, flags, req_id, float(deadline_s), payload)

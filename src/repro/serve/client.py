"""Blocking client for the prediction server (stdlib ``http.client``).

One ``PredictionClient`` is safe to share across threads: each thread
keeps its own persistent HTTP/1.1 connection (``threading.local``), so a
load generator with N threads holds N sockets — reconnecting per request
would dominate the microsecond-scale model latencies being measured.

The client speaks exactly the in-process sweep API shapes:
``argmin``/``topk``/``pareto`` return ``SweepWinner`` objects and
``predict_totals`` returns the float64 totals column, all bit-identical
to calling ``sweep.argmin_table``/... locally (the acceptance criterion
tests/test_serve_server.py pins).  Pass a built ``WorkloadTable`` for
sweeps you hold, or a lazy ``LatticeSpec`` to let the server stream a
lattice far bigger than the wire could carry materialized.

Fault tolerance (the full contract lives in ``serve/README.md``):

* **Split timeouts** — ``connect_timeout`` (default 5 s) bounds the TCP
  handshake independently of ``timeout`` (the read budget); a dead host
  no longer costs a full read timeout just to fail to connect.
* **Retries with backoff** — transport faults (reset, stale keep-alive,
  truncated frame), corrupt replies (the codec's CRC32 catches bit
  flips in transit) and retryable statuses (429/503) are re-sent up to
  ``max_retries`` times with exponential backoff + jitter, honoring the
  server's ``Retry-After`` hint.  Safe because every endpoint is
  idempotent (the server's documented contract).
* **Deadlines** — ``deadline_s=...`` on any call bounds the *whole*
  call, connect + reads + every retry; the budget is computed once at
  entry, so retries and reconnects shrink it rather than reset it.  The
  remaining budget travels in the ``X-Repro-Deadline-S`` header so the
  server can shed work the caller has already abandoned.
* **Circuit breaker** — ``breaker_threshold`` consecutive connection
  failures open the circuit: further calls fail fast with
  ``CircuitOpenError`` instead of each paying a connect timeout, until
  a ``breaker_cooldown_s`` half-open probe succeeds.
* **Auth** — ``auth_token`` is stamped on every request
  (``X-Auth-Token``) for servers gating their mutating endpoints.
"""
from __future__ import annotations

import argparse
import http.client
import random
import threading
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from . import codec, errors


class _CircuitBreaker:
    """Consecutive-connect-failure breaker with half-open probing."""

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._fails = 0
        self._opened_at: Optional[float] = None
        self._probing = False
        self._lock = threading.Lock()

    def admit(self) -> None:
        """Raise ``CircuitOpenError`` while open; after the cooldown let
        exactly one caller through as the half-open probe."""
        if self.threshold <= 0:
            return
        with self._lock:
            if self._opened_at is None:
                return
            if (time.monotonic() - self._opened_at >= self.cooldown_s
                    and not self._probing):
                self._probing = True
                return
            raise errors.CircuitOpenError(
                f"circuit open after {self._fails} consecutive "
                f"connection failures — failing fast (half-open probe "
                f"every {self.cooldown_s:g}s)")

    def success(self) -> None:
        with self._lock:
            self._fails = 0
            self._opened_at = None
            self._probing = False

    def failure(self) -> None:
        with self._lock:
            self._fails += 1
            self._probing = False
            if self._fails >= self.threshold > 0:
                self._opened_at = time.monotonic()


class PredictionClient:
    """Client for one server address."""

    def __init__(self, host: str = "127.0.0.1", port: int = 8707, *,
                 timeout: float = 120.0,
                 connect_timeout: float = 5.0,
                 max_retries: int = 3,
                 backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 auth_token: Optional[str] = None):
        self.host = host
        self.port = port
        self.timeout = timeout
        self.connect_timeout = connect_timeout
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.auth_token = auth_token
        self._breaker = _CircuitBreaker(breaker_threshold,
                                        breaker_cooldown_s)
        self._rng = random.Random()
        self._local = threading.local()
        self._conns: set = set()      # every thread's conn, for close()
        self._conns_lock = threading.Lock()

    # ------------------------------------------------------------ plumbing
    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            # the constructor timeout governs connect(); reads get their
            # own budget via sock.settimeout() once connected
            conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.connect_timeout)
            self._local.conn = conn
        with self._conns_lock:
            # re-registering on every request keeps the set accurate even
            # when http.client transparently reconnects a closed conn
            self._conns.add(conn)
        return conn

    def _discard_conn(self) -> None:
        """Drop only the calling thread's socket (stale keep-alive
        rebuild) — other threads' in-flight connections stay up."""
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            finally:
                self._local.conn = None

    def _once(self, method: str, path: str, body: Optional[bytes],
              headers: dict, remaining: Optional[float]
              ) -> Tuple[int, Optional[str], bytes]:
        """One attempt: connect (breaker-gated) if needed, send, read.
        Returns ``(status, retry_after_header, body_bytes)``."""
        conn = self._conn()
        if conn.sock is None:
            self._breaker.admit()
            connect_t = self.connect_timeout
            if remaining is not None:
                connect_t = min(connect_t, max(1e-3, remaining))
            conn.timeout = connect_t
            try:
                conn.connect()
            except OSError:
                self._breaker.failure()
                raise
            self._breaker.success()
        read_t = self.timeout
        if remaining is not None:
            read_t = min(read_t, max(1e-3, remaining))
        conn.sock.settimeout(read_t)
        conn.request(method, path, body=body, headers=headers)
        resp = conn.getresponse()
        data = resp.read()
        retry_after = resp.getheader("Retry-After")
        if resp.will_close:
            # the server asked us to drop the socket (Connection: close);
            # http.client already closed the conn — forget it so the next
            # attempt builds a fresh one instead of poking a dead object
            self._discard_conn()
        return resp.status, retry_after, data

    def _request(self, method: str, path: str,
                 body: Optional[bytes] = None, *,
                 deadline_s: Optional[float] = None) -> bytes:
        """Send with retries/backoff/deadline; return the verified reply.

        The deadline is computed ONCE here — reconnects, retries and
        ``close()`` shrink the remaining budget, never reset it."""
        base_headers = {}
        if body is not None:
            base_headers["Content-Type"] = "application/x-repro-wire"
        if self.auth_token is not None:
            base_headers[errors.AUTH_HEADER] = self.auth_token
        deadline = None if deadline_s is None \
            else time.monotonic() + float(deadline_s)
        last_exc: Optional[BaseException] = None
        attempt = 0
        while True:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise errors.DeadlineExceeded(
                        f"deadline_s={deadline_s:g} spent after "
                        f"{attempt} attempt(s) on {method} {path}"
                    ) from last_exc
            headers = dict(base_headers)
            if remaining is not None:
                headers[errors.DEADLINE_HEADER] = f"{remaining:.6f}"
            try:
                status, retry_after, data = self._once(
                    method, path, body, headers, remaining)
            except (http.client.HTTPException, ConnectionError,
                    OSError) as e:
                # Severed/stale socket or truncated frame.  The failure
                # usually surfaces at getresponse(), after the request
                # bytes went out, so a retry can re-execute a POST the
                # server already ran — every endpoint must therefore
                # stay idempotent (the server's documented contract).
                self._discard_conn()
                if deadline is not None and time.monotonic() >= deadline:
                    # the read was already capped to the remaining
                    # budget, so a timeout here IS the deadline expiring
                    raise errors.DeadlineExceeded(
                        f"deadline_s={deadline_s:g} expired during "
                        f"attempt {attempt + 1} ({type(e).__name__})"
                    ) from e
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, None,
                                                 deadline)
                continue
            if status == 401:
                raise errors.Unauthorized(self._remote_message(data))
            if status in (429, 503):
                ra = _parse_retry_after(retry_after)
                cls = errors.RateLimited if status == 429 \
                    else errors.ServerOverloaded
                e = cls(self._remote_message(data),
                        retry_after_s=0.05 if ra is None else ra)
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, ra, deadline)
                continue
            try:
                codec.raise_if_error(data)    # CRC-verifies the envelope
            except codec.WireFormatError as e:
                # reply corrupted in transit (bit flip caught by the
                # codec checksum, or a garbled envelope): the request
                # itself succeeded server-side, so re-asking is safe
                self._discard_conn()
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, None,
                                                 deadline)
                continue
            return data

    def _backoff_or_raise(self, attempt: int, exc: BaseException,
                          retry_after: Optional[float],
                          deadline: Optional[float]) -> int:
        """Sleep the backoff for ``attempt`` and return ``attempt + 1``,
        or raise ``exc`` when retries/deadline budget are exhausted."""
        if attempt >= self.max_retries:
            raise exc
        delay = min(self.backoff_cap_s,
                    self.backoff_base_s * (2.0 ** attempt))
        delay *= 0.5 + self._rng.random() * 0.5       # full-ish jitter
        if retry_after is not None:
            delay = max(delay, retry_after)
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= delay:
                raise errors.DeadlineExceeded(
                    f"deadline would expire during the {delay:.3f}s "
                    f"backoff before retry {attempt + 1}") from exc
        time.sleep(delay)
        return attempt + 1

    @staticmethod
    def _remote_message(data: bytes) -> str:
        """Best-effort text of an ERROR reply body."""
        try:
            codec.raise_if_error(data)
        except codec.RemoteError as e:
            return str(e)
        except codec.WireFormatError:
            pass
        return "(no server detail)"

    def close(self) -> None:
        """Close every thread's persistent connection (the per-thread
        sockets a shared client accumulates), not just the caller's.
        Does not touch in-flight call deadlines — those were computed at
        call entry and keep counting."""
        self._discard_conn()
        with self._conns_lock:
            conns, self._conns = list(self._conns), set()
        for conn in conns:
            try:
                conn.close()
            except Exception:       # noqa: BLE001 — best-effort teardown
                pass

    def __enter__(self) -> "PredictionClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- queries
    def health(self, *, deadline_s: Optional[float] = None) -> dict:
        return codec.decode_json(
            self._request("GET", "/v1/health", deadline_s=deadline_s))

    def cache_stats(self, *, deadline_s: Optional[float] = None) -> dict:
        return codec.decode_json(
            self._request("GET", "/v1/cache_stats",
                          deadline_s=deadline_s))

    def clear_cache(self, *, deadline_s: Optional[float] = None) -> dict:
        return codec.decode_json(
            self._request("POST", "/v1/clear_cache", b"",
                          deadline_s=deadline_s))

    def _sweep(self, op: str, source, hw: str,
               deadline_s: Optional[float], **kw) -> bytes:
        body = codec.encode_request(op, source, hw=hw, **kw)
        return self._request("POST", f"/v1/{op}", body,
                             deadline_s=deadline_s)

    def predict_totals(self, source, hw: str, *,
                       model: Optional[str] = None,
                       chunk_size: Optional[int] = None, jobs=None,
                       coalesce: bool = True,
                       calibration: Optional[str] = None,
                       deadline_s: Optional[float] = None) -> np.ndarray:
        """Every row's total seconds (the ``predict_table(...).totals``
        column, served).  ``calibration`` names a server-side calibration
        (see :meth:`calibrate`) whose multipliers scale the totals."""
        data = self._sweep("predict_table", source, hw, deadline_s,
                           model=model, chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_totals(data)

    def argmin(self, source, hw: str, *, model: Optional[str] = None,
               chunk_size: Optional[int] = None, jobs=None,
               coalesce: bool = True, calibration: Optional[str] = None,
               deadline_s: Optional[float] = None):
        """The cheapest configuration (a ``SweepWinner``)."""
        data = self._sweep("argmin", source, hw, deadline_s, model=model,
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_winners(data)[0]

    def topk(self, source, hw: str, k: int, *,
             model: Optional[str] = None,
             chunk_size: Optional[int] = None, jobs=None,
             coalesce: bool = True, calibration: Optional[str] = None,
             deadline_s: Optional[float] = None):
        data = self._sweep("topk", source, hw, deadline_s, model=model,
                           k=int(k), chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_winners(data)

    def pareto(self, source, hw: str, *,
               objectives: Sequence[str] = ("compute", "memory"),
               model: Optional[str] = None,
               chunk_size: Optional[int] = None, jobs=None,
               coalesce: bool = True, calibration: Optional[str] = None,
               deadline_s: Optional[float] = None):
        data = self._sweep("pareto", source, hw, deadline_s, model=model,
                           objectives=tuple(objectives),
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_winners(data)

    # ------------------------------------------------- hardware library
    def hardware_list(self, *, deadline_s: Optional[float] = None) -> dict:
        """GET /v1/hardware: {name: summary} directory of the server's
        hardware library."""
        return codec.decode_json(
            self._request("GET", "/v1/hardware", deadline_s=deadline_s))

    def hardware_get(self, name: str, *,
                     deadline_s: Optional[float] = None):
        """GET /v1/hardware/<name> -> ``hwlib.HardwareEntry`` (file-backed
        entries arrive with their provenance/units audit trail)."""
        return codec.decode_hardware(
            self._request("GET", f"/v1/hardware/{name}",
                          deadline_s=deadline_s))

    def hardware_register(self, entry, *, overwrite: bool = False,
                          deadline_s: Optional[float] = None) -> dict:
        """POST /v1/hardware: register a ``HardwareParams`` or
        ``hwlib.HardwareEntry`` server-side.  Collides (HTTP 400) on a
        taken name with different parameters unless ``overwrite``;
        re-posting the identical payload is a no-op success."""
        path = "/v1/hardware?overwrite=1" if overwrite else "/v1/hardware"
        return codec.decode_json(
            self._request("POST", path, codec.encode_hardware(entry),
                          deadline_s=deadline_s))

    def hardware_delete(self, name: str, *,
                        deadline_s: Optional[float] = None) -> dict:
        """DELETE /v1/hardware/<name>: tombstone-delete a registry entry.

        404 (``RemoteError``) on unknown names.  A *retried* DELETE may
        see the 404 its own first attempt caused — treat 404-on-retry as
        success if you need exactly-once semantics."""
        return codec.decode_json(
            self._request("DELETE", f"/v1/hardware/{name}",
                          deadline_s=deadline_s))

    # ---------------------------------------------- calibration-as-data
    def calibrate(self, suite, hw: str, *, mode: str = "class",
                  holdout_fraction: float = 0.3, seed: int = 0,
                  model: Optional[str] = None,
                  register_as: Optional[str] = None,
                  deadline_s: Optional[float] = None):
        """POST /v1/calibrate: upload a measured ``MeasuredSuite``, get
        back ``(Calibration, report)`` fitted against the *server's*
        predictions with train/holdout discipline (paper §IV-D).

        ``register_as`` stores the fit server-side so follow-up sweeps
        can price with it (``calibration=<name>`` on the query methods).
        """
        body = codec.encode_calibrate_request(
            suite, hw=hw, mode=mode, holdout_fraction=holdout_fraction,
            seed=seed, model=model, register_as=register_as)
        return codec.decode_calibration(
            self._request("POST", "/v1/calibrate", body,
                          deadline_s=deadline_s))


def _parse_retry_after(value: Optional[str]) -> Optional[float]:
    if value is None:
        return None
    try:
        return max(0.0, float(value))
    except ValueError:
        return None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Query a running prediction server")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8707)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("health")
    sub.add_parser("cache-stats")
    demo = sub.add_parser(
        "argmin-demo",
        help="price a GEMM tile lattice on the server and print the "
             "winning tile")
    demo.add_argument("--hw", default="b200")
    demo.add_argument("--gemm", default="8192,8192,8192",
                      help="m,n,k")
    demo.add_argument("--precision", default="fp16")
    args = ap.parse_args(argv)

    client = PredictionClient(args.host, args.port)
    if args.cmd == "health":
        print(client.health())
    elif args.cmd == "cache-stats":
        print(client.cache_stats())
    else:
        from ..core.workload import TileConfig, WorkloadTable, gemm_workload
        m, n, k = (int(x) for x in args.gemm.split(","))
        tiles = [TileConfig(bm, bn, bk)
                 for bm in (64, 128, 256) for bn in (64, 128, 256)
                 for bk in (16, 32, 64)]
        table = WorkloadTable.tile_lattice(
            gemm_workload("demo", m, n, k, precision=args.precision),
            tiles)
        win = client.argmin(table, args.hw)
        tile = tiles[win.index]
        print(f"argmin over {len(tiles)} tiles on {args.hw}: "
              f"bm={tile.bm} bn={tile.bn} bk={tile.bk} "
              f"-> {win.total * 1e3:.3f} ms ({win.breakdown.dominant}"
              f"-bound)")


if __name__ == "__main__":
    main()

"""Blocking client for the prediction server (stdlib ``http.client``).

One ``PredictionClient`` is safe to share across threads: each thread
keeps its own persistent HTTP/1.1 connection (``threading.local``), so a
load generator with N threads holds N sockets — reconnecting per request
would dominate the microsecond-scale model latencies being measured.

The client speaks exactly the in-process sweep API shapes:
``argmin``/``topk``/``pareto`` return ``SweepWinner`` objects and
``predict_totals`` returns the float64 totals column, all bit-identical
to calling ``sweep.argmin_table``/... locally (the acceptance criterion
tests/test_serve_server.py pins).  Pass a built ``WorkloadTable`` for
sweeps you hold, or a lazy ``LatticeSpec`` to let the server stream a
lattice far bigger than the wire could carry materialized.

Fault tolerance (the full contract lives in ``serve/README.md``):

* **Split timeouts** — ``connect_timeout`` (default 5 s) bounds the TCP
  handshake independently of ``timeout`` (the read budget); a dead host
  no longer costs a full read timeout just to fail to connect.
* **Retries with backoff** — transport faults (reset, stale keep-alive,
  truncated frame), corrupt replies (the codec's CRC32 catches bit
  flips in transit) and retryable statuses (429/503) are re-sent up to
  ``max_retries`` times with exponential backoff + jitter, honoring the
  server's ``Retry-After`` hint.  Safe because every endpoint is
  idempotent (the server's documented contract).
* **Deadlines** — ``deadline_s=...`` on any call bounds the *whole*
  call, connect + reads + every retry; the budget is computed once at
  entry, so retries and reconnects shrink it rather than reset it.  The
  remaining budget travels in the ``X-Repro-Deadline-S`` header so the
  server can shed work the caller has already abandoned.
* **Circuit breaker** — ``breaker_threshold`` consecutive connection
  failures open the circuit: further calls fail fast with
  ``CircuitOpenError`` instead of each paying a connect timeout, until
  a ``breaker_cooldown_s`` half-open probe succeeds.
* **Auth** — ``auth_token`` is stamped on every request
  (``X-Auth-Token``) for servers gating their mutating endpoints.

Transports: sweeps ride the length-prefixed binary protocol
(:mod:`repro.serve.framing`) when the server offers one, falling back
to HTTP otherwise.  ``transport="auto"`` (the default) probes
``/v1/health`` once for an advertised ``binary_port``; ``"binary"``
requires it; ``"http"`` never upgrades.  The binary path keeps one
persistent socket per thread, supports **pipelining** (see
:meth:`argmin_many`: many request ids in flight, replies demuxed by
id), and carries the exact same deadline/backoff/circuit-breaker
semantics — server faults arrive as typed in-band error frames instead
of status codes, and every retryable case (severed socket, corrupt
frame, overload shed) re-sends under the same budget rules as HTTP.
"""
from __future__ import annotations

import argparse
import http.client
import random
import socket
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import metrics, trace
from . import codec, errors
from .framing import (FLAG_ERROR, OP_CACHE_STATS, OP_HEALTH, OP_METRICS,
                      OP_SWEEP, FrameParser, pack_frame)

#: server fault classes rebuilt from binary error frames by name —
#: parity with the HTTP status mapping (401/429/503)
_FAULT_BY_NAME = {
    "Unauthorized": errors.Unauthorized,
    "RateLimited": errors.RateLimited,
    "ServerOverloaded": errors.ServerOverloaded,
    "DeadlineExceeded": errors.DeadlineExceeded,
}

#: faults the binary path retries in-band, mirroring HTTP's 429/503
#: handling (DeadlineExceeded replies only happen when the caller set a
#: budget, so the caller's own deadline bounds the retries)
_RETRYABLE_NAMES = ("RateLimited", "ServerOverloaded", "DeadlineExceeded")

# client-side series (process registry; near-free when metrics are off)
_M_ATTEMPTS = {t: metrics.counter("repro_client_attempts_total",
                                  "Request attempts (retries included)",
                                  transport=t)
               for t in ("http", "binary")}
_M_ATTEMPT_S = {t: metrics.histogram("repro_client_attempt_seconds",
                                     "Per-attempt request latency",
                                     transport=t)
                for t in ("http", "binary")}
_M_RETRIES = metrics.counter("repro_client_retries_total",
                             "Attempts that were retried after backoff")
_M_BACKOFF_S = metrics.counter("repro_client_backoff_seconds_total",
                               "Cumulative seconds slept in backoff")
_M_BREAKER_OPEN = metrics.counter("repro_client_breaker_open_total",
                                  "Circuit breaker closed->open "
                                  "transitions")


def _observe_attempt(transport: str, trace_id, t0: float,
                     status=None, error=None) -> None:
    """One per-attempt span + latency observation (both transports)."""
    dt = time.monotonic() - t0
    _M_ATTEMPTS[transport].inc()
    _M_ATTEMPT_S[transport].observe(dt, trace_id=trace_id)
    attrs = {"transport": transport}
    if status is not None:
        attrs["status"] = status
    if error is not None:
        attrs["error"] = type(error).__name__
    trace.record_span("client.attempt", trace_id, dt, **attrs)


class _CircuitBreaker:
    """Consecutive-connect-failure breaker with half-open probing."""

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._fails = 0
        self._opened_at: Optional[float] = None
        self._probing = False
        self._lock = threading.Lock()

    def admit(self) -> None:
        """Raise ``CircuitOpenError`` while open; after the cooldown let
        exactly one caller through as the half-open probe."""
        if self.threshold <= 0:
            return
        with self._lock:
            if self._opened_at is None:
                return
            if (time.monotonic() - self._opened_at >= self.cooldown_s
                    and not self._probing):
                self._probing = True
                return
            raise errors.CircuitOpenError(
                f"circuit open after {self._fails} consecutive "
                f"connection failures — failing fast (half-open probe "
                f"every {self.cooldown_s:g}s)")

    def success(self) -> None:
        with self._lock:
            self._fails = 0
            self._opened_at = None
            self._probing = False

    def failure(self) -> None:
        with self._lock:
            self._fails += 1
            self._probing = False
            if self._fails >= self.threshold > 0:
                if self._opened_at is None:
                    _M_BREAKER_OPEN.inc()
                self._opened_at = time.monotonic()


class PredictionClient:
    """Client for one server address."""

    def __init__(self, host: str = "127.0.0.1", port: int = 8707, *,
                 timeout: float = 120.0,
                 connect_timeout: float = 5.0,
                 max_retries: int = 3,
                 backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 auth_token: Optional[str] = None,
                 transport: str = "auto",
                 binary_port: Optional[int] = None,
                 http_fallback: bool = True):
        if transport not in ("auto", "binary", "http"):
            raise ValueError(f"transport must be 'auto', 'binary' or "
                             f"'http', got {transport!r}")
        self.host = host
        self.port = port
        self.timeout = timeout
        self.connect_timeout = connect_timeout
        self.max_retries = int(max_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.auth_token = auth_token
        self.transport = transport
        #: explicit binary port skips the health probe; ``None`` under
        #: auto/binary means "discover via /v1/health"
        self._binary_port = binary_port
        self._http_fallback = bool(http_fallback)
        self._breaker = _CircuitBreaker(breaker_threshold,
                                        breaker_cooldown_s)
        self._rng = random.Random()
        self._local = threading.local()
        self._conns: set = set()      # every thread's conn, for close()
        self._conns_lock = threading.Lock()
        self._bin_lock = threading.Lock()
        self._bin_resolved = False
        self._bin_target: Optional[Tuple[str, int]] = None
        #: set when auto-negotiation downgrades to HTTP for good (binary
        #: connect failed but HTTP works — e.g. a proxy in the way)
        self._bin_disabled = False

    # ------------------------------------------------------------ plumbing
    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            # the constructor timeout governs connect(); reads get their
            # own budget via sock.settimeout() once connected
            conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.connect_timeout)
            self._local.conn = conn
        with self._conns_lock:
            # re-registering on every request keeps the set accurate even
            # when http.client transparently reconnects a closed conn
            self._conns.add(conn)
        return conn

    def _discard_conn(self) -> None:
        """Drop only the calling thread's socket (stale keep-alive
        rebuild) — other threads' in-flight connections stay up."""
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            finally:
                self._local.conn = None

    def _once(self, method: str, path: str, body: Optional[bytes],
              headers: dict, remaining: Optional[float]
              ) -> Tuple[int, Optional[str], bytes]:
        """One attempt: connect (breaker-gated) if needed, send, read.
        Returns ``(status, retry_after_header, body_bytes)``."""
        conn = self._conn()
        if conn.sock is None:
            self._breaker.admit()
            connect_t = self.connect_timeout
            if remaining is not None:
                connect_t = min(connect_t, max(1e-3, remaining))
            conn.timeout = connect_t
            try:
                conn.connect()
            except OSError:
                self._breaker.failure()
                raise
            self._breaker.success()
        read_t = self.timeout
        if remaining is not None:
            read_t = min(read_t, max(1e-3, remaining))
        conn.sock.settimeout(read_t)
        conn.request(method, path, body=body, headers=headers)
        resp = conn.getresponse()
        data = resp.read()
        retry_after = resp.getheader("Retry-After")
        if resp.will_close:
            # the server asked us to drop the socket (Connection: close);
            # http.client already closed the conn — forget it so the next
            # attempt builds a fresh one instead of poking a dead object
            self._discard_conn()
        return resp.status, retry_after, data

    def _request(self, method: str, path: str,
                 body: Optional[bytes] = None, *,
                 deadline_s: Optional[float] = None,
                 trace_id: Optional[str] = None,
                 raw: bool = False) -> bytes:
        """Send with retries/backoff/deadline; return the verified reply.

        The deadline is computed ONCE here — reconnects, retries and
        ``close()`` shrink the remaining budget, never reset it.
        ``trace_id`` rides the ``X-Repro-Trace`` header; ``raw`` skips
        the codec envelope check for non-codec bodies (``/v1/metrics``
        is plain Prometheus text)."""
        base_headers = {}
        if body is not None:
            base_headers["Content-Type"] = "application/x-repro-wire"
        if self.auth_token is not None:
            base_headers[errors.AUTH_HEADER] = self.auth_token
        if trace_id is not None:
            base_headers[trace.TRACE_HEADER] = trace_id
        deadline = None if deadline_s is None \
            else time.monotonic() + float(deadline_s)
        last_exc: Optional[BaseException] = None
        attempt = 0
        while True:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise errors.DeadlineExceeded(
                        f"deadline_s={deadline_s:g} spent after "
                        f"{attempt} attempt(s) on {method} {path}"
                    ) from last_exc
            headers = dict(base_headers)
            if remaining is not None:
                headers[errors.DEADLINE_HEADER] = f"{remaining:.6f}"
            ta = time.monotonic()
            try:
                status, retry_after, data = self._once(
                    method, path, body, headers, remaining)
            except (http.client.HTTPException, ConnectionError,
                    OSError) as e:
                _observe_attempt("http", trace_id, ta, error=e)
                # Severed/stale socket or truncated frame.  The failure
                # usually surfaces at getresponse(), after the request
                # bytes went out, so a retry can re-execute a POST the
                # server already ran — every endpoint must therefore
                # stay idempotent (the server's documented contract).
                self._discard_conn()
                if deadline is not None and time.monotonic() >= deadline:
                    # the read was already capped to the remaining
                    # budget, so a timeout here IS the deadline expiring
                    raise errors.DeadlineExceeded(
                        f"deadline_s={deadline_s:g} expired during "
                        f"attempt {attempt + 1} ({type(e).__name__})"
                    ) from e
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, None,
                                                 deadline)
                continue
            _observe_attempt("http", trace_id, ta, status=status)
            if status == 401:
                raise errors.Unauthorized(self._remote_message(data))
            if status in (429, 503):
                ra = _parse_retry_after(retry_after)
                cls = errors.RateLimited if status == 429 \
                    else errors.ServerOverloaded
                e = cls(self._remote_message(data),
                        retry_after_s=0.05 if ra is None else ra)
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, ra, deadline)
                continue
            if raw and status < 400:
                return data
            try:
                codec.raise_if_error(data)    # CRC-verifies the envelope
            except codec.WireFormatError as e:
                # reply corrupted in transit (bit flip caught by the
                # codec checksum, or a garbled envelope): the request
                # itself succeeded server-side, so re-asking is safe
                self._discard_conn()
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, None,
                                                 deadline)
                continue
            return data

    def _backoff_or_raise(self, attempt: int, exc: BaseException,
                          retry_after: Optional[float],
                          deadline: Optional[float]) -> int:
        """Sleep the backoff for ``attempt`` and return ``attempt + 1``,
        or raise ``exc`` when retries/deadline budget are exhausted."""
        if attempt >= self.max_retries:
            raise exc
        delay = min(self.backoff_cap_s,
                    self.backoff_base_s * (2.0 ** attempt))
        delay *= 0.5 + self._rng.random() * 0.5       # full-ish jitter
        if retry_after is not None:
            delay = max(delay, retry_after)
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= delay:
                raise errors.DeadlineExceeded(
                    f"deadline would expire during the {delay:.3f}s "
                    f"backoff before retry {attempt + 1}") from exc
        _M_RETRIES.inc()
        _M_BACKOFF_S.inc(delay)
        time.sleep(delay)
        return attempt + 1

    @staticmethod
    def _remote_message(data: bytes) -> str:
        """Best-effort text of an ERROR reply body."""
        try:
            codec.raise_if_error(data)
        except codec.RemoteError as e:
            return str(e)
        except codec.WireFormatError:
            pass
        return "(no server detail)"

    # ---------------------------------------------------- binary transport
    def _binary_target(self, deadline_s: Optional[float] = None
                       ) -> Optional[Tuple[str, int]]:
        """The binary address to use, or ``None`` for HTTP.  Resolved
        once: an explicit ``binary_port`` wins; otherwise ``auto`` and
        ``binary`` probe ``/v1/health`` for the advertised port.
        ``transport="binary"`` raises if the server offers none.
        ``deadline_s`` bounds the one-time probe so a stalled server
        can't eat more than the caller's budget before the caller's own
        attempt (which is charged for the probe's time) even starts."""
        if self.transport == "http" or self._bin_disabled:
            return None
        with self._bin_lock:
            if self._bin_resolved:
                return self._bin_target
            if self._binary_port is not None:
                self._bin_target = (self.host, int(self._binary_port))
                self._bin_resolved = True
                return self._bin_target
            try:
                port = codec.decode_json(self._request(
                    "GET", "/v1/health",
                    deadline_s=deadline_s)).get("binary_port")
            except Exception:                # noqa: BLE001
                if self.transport == "binary":
                    raise
                # can't probe — leave unresolved so the sweep's own HTTP
                # attempt surfaces the real connectivity error
                return None
            if port is None and self.transport == "binary":
                raise RuntimeError(
                    f"transport='binary' but the server at {self.host}:"
                    f"{self.port} advertises no binary port")
            self._bin_target = (self.host, int(port)) if port else None
            self._bin_resolved = True
            return self._bin_target

    def _bconn(self, remaining: Optional[float]) -> socket.socket:
        """The calling thread's persistent binary socket (breaker-gated
        connect on first use, like the HTTP path)."""
        sock = getattr(self._local, "bsock", None)
        if sock is None:
            self._breaker.admit()
            connect_t = self.connect_timeout
            if remaining is not None:
                connect_t = min(connect_t, max(1e-3, remaining))
            try:
                sock = socket.create_connection(self._bin_target,
                                                timeout=connect_t)
            except OSError:
                self._breaker.failure()
                raise
            self._breaker.success()
            # one sendall per frame + NODELAY: no Nagle/delayed-ACK
            # stall (the HTTP path's split writes pay ~40 ms here)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._local.bsock = sock
            self._local.bparser = FrameParser()
            self._local.bgot: Dict[int, object] = {}
            self._local.bnext_id = 0
        with self._conns_lock:
            self._conns.add(sock)
        return sock

    def _discard_bconn(self) -> None:
        """Drop the calling thread's binary socket.  Any replies still
        in flight on it are lost — the retry loop re-sends under fresh
        ids, so nothing can demux onto a stale request."""
        sock = getattr(self._local, "bsock", None)
        if sock is not None:
            with self._conns_lock:
                self._conns.discard(sock)
            try:
                sock.close()
            finally:
                self._local.bsock = None
                self._local.bparser = None
                self._local.bgot = {}

    def _read_frame_into(self, expected: set) -> None:
        """Read from the thread's binary socket until at least one more
        frame lands in ``self._local.bgot``.  A reply id outside
        ``expected`` means the stream can no longer be trusted."""
        st = self._local
        before = len(st.bgot)
        while len(st.bgot) == before:
            data = st.bsock.recv(1 << 18)
            if not data:
                raise ConnectionError(
                    "server closed the binary connection")
            st.bparser.feed(data)
            for frame in st.bparser.frames():
                if frame.req_id not in expected:
                    raise codec.WireFormatError(
                        f"reply for unknown request id {frame.req_id} — "
                        f"stream desynchronized")
                st.bgot[frame.req_id] = frame

    def _rebuild_fault(self, payload: bytes) -> BaseException:
        """Typed exception from an error frame's payload (parity with
        the HTTP status mapping + ``raise_if_error`` message shape)."""
        name, message, retry_after = codec.decode_error(payload)
        cls = _FAULT_BY_NAME.get(name)
        if cls is None:
            return codec.RemoteError(f"{name}: {message}")
        if name in ("RateLimited", "ServerOverloaded"):
            return cls(message, retry_after_s=(0.05 if retry_after is None
                                               else retry_after))
        return cls(message)

    def _request_binary_many(self, bodies: List[bytes], *,
                             deadline_s: Optional[float] = None,
                             trace_ids: Optional[List[Optional[str]]] = None
                             ) -> List[bytes]:
        """Pipelined sweep round-trips: every outstanding request goes
        out in ONE write burst, replies demux by id in any order.  Same
        budget rules as ``_request``: one deadline computed at entry,
        retries/backoff/breaker shared with HTTP.  ``trace_ids`` aligns
        with ``bodies`` (per-request attempt spans/exemplars)."""
        deadline = None if deadline_s is None \
            else time.monotonic() + float(deadline_s)
        results: List[Optional[bytes]] = [None] * len(bodies)
        outstanding = list(range(len(bodies)))
        last_exc: Optional[BaseException] = None
        attempt = 0
        while outstanding:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise errors.DeadlineExceeded(
                        f"deadline_s={deadline_s:g} spent after "
                        f"{attempt} attempt(s), "
                        f"{len(outstanding)} reply(ies) outstanding"
                    ) from last_exc
            ta = time.monotonic()
            try:
                outstanding, retry_after, fault = self._bin_round(
                    bodies, outstanding, results, remaining, trace_ids)
            except (OSError, ConnectionError) as e:
                _observe_attempt(
                    "binary",
                    trace_ids[outstanding[0]] if trace_ids else None,
                    ta, error=e)
                self._discard_bconn()
                if deadline is not None and time.monotonic() >= deadline:
                    raise errors.DeadlineExceeded(
                        f"deadline_s={deadline_s:g} expired during "
                        f"attempt {attempt + 1} ({type(e).__name__})"
                    ) from e
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, None,
                                                 deadline)
                continue
            except codec.WireFormatError as e:
                # reply corrupted or stream desynced: the socket's frame
                # offsets are unusable — rebuild and re-ask (idempotent)
                self._discard_bconn()
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, None,
                                                 deadline)
                continue
            if outstanding:
                # only retryable in-band faults (overload shed, rate
                # limit) remain — back off like HTTP's 429/503 handling
                last_exc = fault
                attempt = self._backoff_or_raise(attempt, fault,
                                                 retry_after, deadline)
        return results                       # type: ignore[return-value]

    def _bin_round(self, bodies, outstanding, results, remaining,
                   trace_ids=None):
        """One pipelined attempt over the current socket.  Returns
        ``(still_outstanding, retry_after, fault)``; raises transport /
        wire errors for the caller's retry loop."""
        t0 = time.monotonic()
        sock = self._bconn(remaining)
        st = self._local
        read_t = self.timeout
        if remaining is not None:
            read_t = min(read_t, max(1e-3, remaining))
        sock.settimeout(read_t)
        ids = {}
        burst = bytearray()
        for idx in outstanding:
            req_id = st.bnext_id
            st.bnext_id += 1
            ids[req_id] = idx
            burst += pack_frame(OP_SWEEP, req_id, bodies[idx],
                                deadline_s=remaining or 0.0)
        sock.sendall(burst)
        expected = set(ids)
        still, retry_after, fault = [], None, None
        pending = set(ids)
        while pending:
            self._read_frame_into(expected)
            for req_id in list(pending):
                frame = st.bgot.pop(req_id, None)
                if frame is None:
                    continue
                pending.discard(req_id)
                idx = ids[req_id]
                tid = trace_ids[idx] if trace_ids else None
                if frame.flags & FLAG_ERROR:
                    exc = self._rebuild_fault(frame.payload)
                    _observe_attempt("binary", tid, t0, error=exc)
                    if type(exc).__name__ in _RETRYABLE_NAMES:
                        still.append(idx)
                        ra = getattr(exc, "retry_after_s", None)
                        if ra is not None:
                            retry_after = ra if retry_after is None \
                                else max(retry_after, ra)
                        fault = exc
                        continue
                    raise exc
                try:
                    codec.raise_if_error(frame.payload)  # CRC check
                except codec.RemoteError:
                    # an ERROR payload without FLAG_ERROR: the frame
                    # header and payload disagree (header bit flip) —
                    # trust neither
                    raise codec.WireFormatError(
                        "error payload in a success-flagged frame — "
                        "frame header untrustworthy") from None
                _observe_attempt("binary", tid, t0, status=200)
                results[idx] = frame.payload
        still.sort()
        return still, retry_after, fault

    def _request_binary(self, body: bytes, *,
                        deadline_s: Optional[float] = None) -> bytes:
        return self._request_binary_many([body],
                                         deadline_s=deadline_s)[0]

    def _simple_binary(self, op: int, *,
                       deadline_s: Optional[float] = None) -> bytes:
        """Health/stats over the binary transport (no retry loop
        subtleties needed beyond the shared one: reuse the sweep path's
        machinery with an empty payload)."""
        deadline = None if deadline_s is None \
            else time.monotonic() + float(deadline_s)
        last_exc: Optional[BaseException] = None
        attempt = 0
        while True:
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise errors.DeadlineExceeded(
                        f"deadline_s={deadline_s:g} spent after "
                        f"{attempt} attempt(s)") from last_exc
            try:
                sock = self._bconn(remaining)
                st = self._local
                read_t = self.timeout
                if remaining is not None:
                    read_t = min(read_t, max(1e-3, remaining))
                sock.settimeout(read_t)
                req_id = st.bnext_id
                st.bnext_id += 1
                sock.sendall(pack_frame(op, req_id, b"",
                                        deadline_s=remaining or 0.0))
                self._read_frame_into({req_id})
                frame = st.bgot.pop(req_id)
                if frame.flags & FLAG_ERROR:
                    raise self._rebuild_fault(frame.payload)
                return frame.payload
            except (OSError, ConnectionError, codec.WireFormatError) as e:
                self._discard_bconn()
                last_exc = e
                attempt = self._backoff_or_raise(attempt, e, None,
                                                 deadline)

    def close(self) -> None:
        """Close every thread's persistent connection (the per-thread
        sockets a shared client accumulates), not just the caller's.
        Does not touch in-flight call deadlines — those were computed at
        call entry and keep counting."""
        self._discard_conn()
        with self._conns_lock:
            conns, self._conns = list(self._conns), set()
        for conn in conns:
            try:
                conn.close()
            except Exception:       # noqa: BLE001 — best-effort teardown
                pass

    def __enter__(self) -> "PredictionClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- queries
    def health(self, *, deadline_s: Optional[float] = None) -> dict:
        if self.transport == "binary" and self._binary_target(deadline_s):
            return codec.decode_json(self._simple_binary(
                OP_HEALTH, deadline_s=deadline_s))
        return codec.decode_json(
            self._request("GET", "/v1/health", deadline_s=deadline_s))

    def cache_stats(self, *, deadline_s: Optional[float] = None) -> dict:
        """One stats schema regardless of transport: the binary
        ``OP_CACHE_STATS`` frame and ``GET /v1/cache_stats`` return the
        identical document (engine cache + coalescer dedup/shed/
        isolation counters + binary frontend counters)."""
        if self.transport == "binary" and self._binary_target(deadline_s):
            return codec.decode_json(self._simple_binary(
                OP_CACHE_STATS, deadline_s=deadline_s))
        return codec.decode_json(
            self._request("GET", "/v1/cache_stats",
                          deadline_s=deadline_s))

    def clear_cache(self, *, deadline_s: Optional[float] = None) -> dict:
        return codec.decode_json(
            self._request("POST", "/v1/clear_cache", b"",
                          deadline_s=deadline_s))

    def metrics_text(self, *, deadline_s: Optional[float] = None) -> str:
        """The server's Prometheus text exposition — the same snapshot
        whether fetched as raw ``GET /v1/metrics`` or a binary
        ``OP_METRICS`` frame (the frame wraps the identical text in a
        JSON codec message)."""
        if self.transport == "binary" and self._binary_target(deadline_s):
            return codec.decode_json(self._simple_binary(
                OP_METRICS, deadline_s=deadline_s))
        return self._request("GET", "/v1/metrics", deadline_s=deadline_s,
                             raw=True).decode("utf-8")

    def _sweep(self, op: str, source, hw: str,
               deadline_s: Optional[float],
               trace_id: Optional[str] = None, **kw) -> bytes:
        if trace_id is None:
            trace_id = trace.new_trace_id()
        body = codec.encode_request(op, source, hw=hw,
                                    trace_id=trace_id, **kw)
        t0 = time.monotonic()
        if self._binary_target(deadline_s) is not None:
            try:
                return self._request_binary_many(
                    [body], deadline_s=deadline_s,
                    trace_ids=[trace_id])[0]
            except (OSError, ConnectionError):
                # the binary port is unreachable (stale advertisement,
                # proxy in the way): under auto-negotiation downgrade to
                # HTTP for good rather than paying this again per call
                if self.transport != "auto" or not self._http_fallback:
                    raise
                self._discard_bconn()
                self._bin_disabled = True
        if deadline_s is not None:
            # one budget per call: the probe / failed binary attempt
            # already spent part of it
            deadline_s -= time.monotonic() - t0
        return self._request("POST", f"/v1/{op}", body,
                             deadline_s=deadline_s, trace_id=trace_id)

    def argmin_many(self, tables, hw: str, *,
                    model: Optional[str] = None,
                    coalesce: bool = True,
                    calibration: Optional[str] = None,
                    max_fused_rows: Optional[int] = None,
                    deadline_s: Optional[float] = None,
                    trace_ids: Optional[List[Optional[str]]] = None):
        """Pipelined ``argmin`` over many tables: every request goes out
        in one burst on the thread's binary socket and the coalescer
        fuses (and dedups) them into shared evaluations — the intended
        operating mode of the binary transport.  Falls back to
        sequential HTTP calls when no binary port is available.
        Returns one ``SweepWinner`` per table, in order.  ``trace_ids``
        aligns with ``tables`` (one fresh id per table by default)."""
        tables = list(tables)
        if trace_ids is None:
            trace_ids = [trace.new_trace_id() for _ in tables]
        bodies = [codec.encode_request(
            "argmin", t, hw=hw, model=model, coalesce=coalesce,
            calibration=calibration, max_fused_rows=max_fused_rows,
            trace_id=tid)
            for t, tid in zip(tables, trace_ids)]
        t0 = time.monotonic()
        if self._binary_target(deadline_s) is not None:
            try:
                replies = self._request_binary_many(
                    bodies, deadline_s=deadline_s, trace_ids=trace_ids)
                return [codec.decode_winners(d)[0] for d in replies]
            except (OSError, ConnectionError):
                if self.transport != "auto" or not self._http_fallback:
                    raise
                self._discard_bconn()
                self._bin_disabled = True
        if deadline_s is not None:
            deadline_s = deadline_s - (time.monotonic() - t0)
        return [codec.decode_winners(self._request(
            "POST", "/v1/argmin", b, deadline_s=deadline_s,
            trace_id=tid))[0]
            for b, tid in zip(bodies, trace_ids)]

    def predict_totals(self, source, hw: str, *,
                       model: Optional[str] = None,
                       chunk_size: Optional[int] = None, jobs=None,
                       coalesce: bool = True,
                       calibration: Optional[str] = None,
                       max_fused_rows: Optional[int] = None,
                       deadline_s: Optional[float] = None,
                       trace_id: Optional[str] = None) -> np.ndarray:
        """Every row's total seconds (the ``predict_table(...).totals``
        column, served).  ``calibration`` names a server-side calibration
        (see :meth:`calibrate`) whose multipliers scale the totals.
        ``max_fused_rows`` caps the estimated row-cost of any coalesced
        batch this request joins (a hint — clamped server-side)."""
        data = self._sweep("predict_table", source, hw, deadline_s,
                           trace_id,
                           model=model, chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration,
                           max_fused_rows=max_fused_rows)
        return codec.decode_totals(data)

    def argmin(self, source, hw: str, *, model: Optional[str] = None,
               chunk_size: Optional[int] = None, jobs=None,
               coalesce: bool = True, calibration: Optional[str] = None,
               max_fused_rows: Optional[int] = None,
               deadline_s: Optional[float] = None,
               trace_id: Optional[str] = None):
        """The cheapest configuration (a ``SweepWinner``)."""
        data = self._sweep("argmin", source, hw, deadline_s, trace_id,
                           model=model,
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration,
                           max_fused_rows=max_fused_rows)
        return codec.decode_winners(data)[0]

    def topk(self, source, hw: str, k: int, *,
             model: Optional[str] = None,
             chunk_size: Optional[int] = None, jobs=None,
             coalesce: bool = True, calibration: Optional[str] = None,
             max_fused_rows: Optional[int] = None,
             deadline_s: Optional[float] = None,
             trace_id: Optional[str] = None):
        data = self._sweep("topk", source, hw, deadline_s, trace_id,
                           model=model,
                           k=int(k), chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration,
                           max_fused_rows=max_fused_rows)
        return codec.decode_winners(data)

    def pareto(self, source, hw: str, *,
               objectives: Sequence[str] = ("compute", "memory"),
               model: Optional[str] = None,
               chunk_size: Optional[int] = None, jobs=None,
               coalesce: bool = True, calibration: Optional[str] = None,
               max_fused_rows: Optional[int] = None,
               deadline_s: Optional[float] = None,
               trace_id: Optional[str] = None):
        data = self._sweep("pareto", source, hw, deadline_s, trace_id,
                           model=model,
                           objectives=tuple(objectives),
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration,
                           max_fused_rows=max_fused_rows)
        return codec.decode_winners(data)

    # ------------------------------------------------- hardware library
    def hardware_list(self, *, deadline_s: Optional[float] = None) -> dict:
        """GET /v1/hardware: {name: summary} directory of the server's
        hardware library."""
        return codec.decode_json(
            self._request("GET", "/v1/hardware", deadline_s=deadline_s))

    def hardware_get(self, name: str, *,
                     deadline_s: Optional[float] = None):
        """GET /v1/hardware/<name> -> ``hwlib.HardwareEntry`` (file-backed
        entries arrive with their provenance/units audit trail)."""
        return codec.decode_hardware(
            self._request("GET", f"/v1/hardware/{name}",
                          deadline_s=deadline_s))

    def hardware_register(self, entry, *, overwrite: bool = False,
                          deadline_s: Optional[float] = None) -> dict:
        """POST /v1/hardware: register a ``HardwareParams`` or
        ``hwlib.HardwareEntry`` server-side.  Collides (HTTP 400) on a
        taken name with different parameters unless ``overwrite``;
        re-posting the identical payload is a no-op success."""
        path = "/v1/hardware?overwrite=1" if overwrite else "/v1/hardware"
        return codec.decode_json(
            self._request("POST", path, codec.encode_hardware(entry),
                          deadline_s=deadline_s))

    def hardware_delete(self, name: str, *,
                        deadline_s: Optional[float] = None) -> dict:
        """DELETE /v1/hardware/<name>: tombstone-delete a registry entry.

        404 (``RemoteError``) on unknown names.  A *retried* DELETE may
        see the 404 its own first attempt caused — treat 404-on-retry as
        success if you need exactly-once semantics."""
        return codec.decode_json(
            self._request("DELETE", f"/v1/hardware/{name}",
                          deadline_s=deadline_s))

    # ---------------------------------------------- calibration-as-data
    def calibrate(self, suite, hw: str, *, mode: str = "class",
                  holdout_fraction: float = 0.3, seed: int = 0,
                  model: Optional[str] = None,
                  register_as: Optional[str] = None,
                  deadline_s: Optional[float] = None):
        """POST /v1/calibrate: upload a measured ``MeasuredSuite``, get
        back ``(Calibration, report)`` fitted against the *server's*
        predictions with train/holdout discipline (paper §IV-D).

        ``register_as`` stores the fit server-side so follow-up sweeps
        can price with it (``calibration=<name>`` on the query methods).
        """
        body = codec.encode_calibrate_request(
            suite, hw=hw, mode=mode, holdout_fraction=holdout_fraction,
            seed=seed, model=model, register_as=register_as)
        return codec.decode_calibration(
            self._request("POST", "/v1/calibrate", body,
                          deadline_s=deadline_s))


def _parse_retry_after(value: Optional[str]) -> Optional[float]:
    if value is None:
        return None
    try:
        return max(0.0, float(value))
    except ValueError:
        return None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Query a running prediction server")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8707)
    ap.add_argument("--transport", choices=("auto", "binary", "http"),
                    default="auto",
                    help="auto probes /v1/health for a binary port and "
                         "upgrades sweeps when one is advertised")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("health")
    sub.add_parser("cache-stats")
    sub.add_parser("metrics",
                   help="dump the server's Prometheus text exposition")
    demo = sub.add_parser(
        "argmin-demo",
        help="price a GEMM tile lattice on the server and print the "
             "winning tile")
    demo.add_argument("--hw", default="b200")
    demo.add_argument("--gemm", default="8192,8192,8192",
                      help="m,n,k")
    demo.add_argument("--precision", default="fp16")
    args = ap.parse_args(argv)

    client = PredictionClient(args.host, args.port,
                              transport=args.transport)
    if args.cmd == "health":
        print(client.health())
    elif args.cmd == "cache-stats":
        print(client.cache_stats())
    elif args.cmd == "metrics":
        print(client.metrics_text(), end="")
    else:
        from ..core.workload import TileConfig, WorkloadTable, gemm_workload
        m, n, k = (int(x) for x in args.gemm.split(","))
        tiles = [TileConfig(bm, bn, bk)
                 for bm in (64, 128, 256) for bn in (64, 128, 256)
                 for bk in (16, 32, 64)]
        table = WorkloadTable.tile_lattice(
            gemm_workload("demo", m, n, k, precision=args.precision),
            tiles)
        win = client.argmin(table, args.hw)
        tile = tiles[win.index]
        print(f"argmin over {len(tiles)} tiles on {args.hw}: "
              f"bm={tile.bm} bn={tile.bn} bk={tile.bk} "
              f"-> {win.total * 1e3:.3f} ms ({win.breakdown.dominant}"
              f"-bound)")


if __name__ == "__main__":
    main()

"""Blocking client for the prediction server (stdlib ``http.client``).

One ``PredictionClient`` is safe to share across threads: each thread
keeps its own persistent HTTP/1.1 connection (``threading.local``), so a
load generator with N threads holds N sockets — reconnecting per request
would dominate the microsecond-scale model latencies being measured.

The client speaks exactly the in-process sweep API shapes:
``argmin``/``topk``/``pareto`` return ``SweepWinner`` objects and
``predict_totals`` returns the float64 totals column, all bit-identical
to calling ``sweep.argmin_table``/... locally (the acceptance criterion
tests/test_serve_server.py pins).  Pass a built ``WorkloadTable`` for
sweeps you hold, or a lazy ``LatticeSpec`` to let the server stream a
lattice far bigger than the wire could carry materialized.
"""
from __future__ import annotations

import argparse
import http.client
import threading
from typing import Optional, Sequence, Tuple

import numpy as np

from . import codec


class PredictionClient:
    """Client for one server address."""

    def __init__(self, host: str = "127.0.0.1", port: int = 8707, *,
                 timeout: float = 120.0):
        self.host = host
        self.port = port
        self.timeout = timeout
        self._local = threading.local()
        self._conns: set = set()      # every thread's conn, for close()
        self._conns_lock = threading.Lock()

    # ------------------------------------------------------------ plumbing
    def _conn(self) -> http.client.HTTPConnection:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            conn = http.client.HTTPConnection(self.host, self.port,
                                              timeout=self.timeout)
            self._local.conn = conn
        with self._conns_lock:
            # re-registering on every request keeps the set accurate even
            # when http.client transparently reconnects a closed conn
            self._conns.add(conn)
        return conn

    def _discard_conn(self) -> None:
        """Drop only the calling thread's socket (stale keep-alive
        rebuild) — other threads' in-flight connections stay up."""
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            finally:
                self._local.conn = None

    def _request(self, method: str, path: str,
                 body: Optional[bytes] = None) -> bytes:
        headers = {"Content-Type": "application/x-repro-wire"} \
            if body is not None else {}
        for attempt in (0, 1):
            conn = self._conn()
            try:
                conn.request(method, path, body=body, headers=headers)
                resp = conn.getresponse()
                data = resp.read()
                break
            except (http.client.HTTPException, ConnectionError, OSError):
                # Stale keep-alive socket: rebuild once, then give up.
                # The failure usually surfaces at getresponse(), after the
                # request bytes went out, so the retry can re-execute a
                # POST the server already ran — every endpoint must
                # therefore stay idempotent (all current ones are,
                # including clear_cache).
                self._discard_conn()
                if attempt:
                    raise
        codec.raise_if_error(data)
        return data

    def close(self) -> None:
        """Close every thread's persistent connection (the per-thread
        sockets a shared client accumulates), not just the caller's."""
        self._discard_conn()
        with self._conns_lock:
            conns, self._conns = list(self._conns), set()
        for conn in conns:
            try:
                conn.close()
            except Exception:       # noqa: BLE001 — best-effort teardown
                pass

    def __enter__(self) -> "PredictionClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- queries
    def health(self) -> dict:
        return codec.decode_json(self._request("GET", "/v1/health"))

    def cache_stats(self) -> dict:
        return codec.decode_json(self._request("GET", "/v1/cache_stats"))

    def clear_cache(self) -> dict:
        return codec.decode_json(
            self._request("POST", "/v1/clear_cache", b""))

    def _sweep(self, op: str, source, hw: str, **kw) -> bytes:
        body = codec.encode_request(op, source, hw=hw, **kw)
        return self._request("POST", f"/v1/{op}", body)

    def predict_totals(self, source, hw: str, *,
                       model: Optional[str] = None,
                       chunk_size: Optional[int] = None, jobs=None,
                       coalesce: bool = True,
                       calibration: Optional[str] = None) -> np.ndarray:
        """Every row's total seconds (the ``predict_table(...).totals``
        column, served).  ``calibration`` names a server-side calibration
        (see :meth:`calibrate`) whose multipliers scale the totals."""
        data = self._sweep("predict_table", source, hw, model=model,
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_totals(data)

    def argmin(self, source, hw: str, *, model: Optional[str] = None,
               chunk_size: Optional[int] = None, jobs=None,
               coalesce: bool = True, calibration: Optional[str] = None):
        """The cheapest configuration (a ``SweepWinner``)."""
        data = self._sweep("argmin", source, hw, model=model,
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_winners(data)[0]

    def topk(self, source, hw: str, k: int, *,
             model: Optional[str] = None,
             chunk_size: Optional[int] = None, jobs=None,
             coalesce: bool = True, calibration: Optional[str] = None):
        data = self._sweep("topk", source, hw, model=model, k=int(k),
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_winners(data)

    def pareto(self, source, hw: str, *,
               objectives: Sequence[str] = ("compute", "memory"),
               model: Optional[str] = None,
               chunk_size: Optional[int] = None, jobs=None,
               coalesce: bool = True, calibration: Optional[str] = None):
        data = self._sweep("pareto", source, hw, model=model,
                           objectives=tuple(objectives),
                           chunk_size=chunk_size, jobs=jobs,
                           coalesce=coalesce, calibration=calibration)
        return codec.decode_winners(data)

    # ------------------------------------------------- hardware library
    def hardware_list(self) -> dict:
        """GET /v1/hardware: {name: summary} directory of the server's
        hardware library."""
        return codec.decode_json(self._request("GET", "/v1/hardware"))

    def hardware_get(self, name: str):
        """GET /v1/hardware/<name> -> ``hwlib.HardwareEntry`` (file-backed
        entries arrive with their provenance/units audit trail)."""
        return codec.decode_hardware(
            self._request("GET", f"/v1/hardware/{name}"))

    def hardware_register(self, entry, *, overwrite: bool = False) -> dict:
        """POST /v1/hardware: register a ``HardwareParams`` or
        ``hwlib.HardwareEntry`` server-side.  Collides (HTTP 400) on a
        taken name with different parameters unless ``overwrite``;
        re-posting the identical payload is a no-op success."""
        path = "/v1/hardware?overwrite=1" if overwrite else "/v1/hardware"
        return codec.decode_json(
            self._request("POST", path, codec.encode_hardware(entry)))

    # ---------------------------------------------- calibration-as-data
    def calibrate(self, suite, hw: str, *, mode: str = "class",
                  holdout_fraction: float = 0.3, seed: int = 0,
                  model: Optional[str] = None,
                  register_as: Optional[str] = None):
        """POST /v1/calibrate: upload a measured ``MeasuredSuite``, get
        back ``(Calibration, report)`` fitted against the *server's*
        predictions with train/holdout discipline (paper §IV-D).

        ``register_as`` stores the fit server-side so follow-up sweeps
        can price with it (``calibration=<name>`` on the query methods).
        """
        body = codec.encode_calibrate_request(
            suite, hw=hw, mode=mode, holdout_fraction=holdout_fraction,
            seed=seed, model=model, register_as=register_as)
        return codec.decode_calibration(
            self._request("POST", "/v1/calibrate", body))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Query a running prediction server")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8707)
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("health")
    sub.add_parser("cache-stats")
    demo = sub.add_parser(
        "argmin-demo",
        help="price a GEMM tile lattice on the server and print the "
             "winning tile")
    demo.add_argument("--hw", default="b200")
    demo.add_argument("--gemm", default="8192,8192,8192",
                      help="m,n,k")
    demo.add_argument("--precision", default="fp16")
    args = ap.parse_args(argv)

    client = PredictionClient(args.host, args.port)
    if args.cmd == "health":
        print(client.health())
    elif args.cmd == "cache-stats":
        print(client.cache_stats())
    else:
        from ..core.workload import TileConfig, WorkloadTable, gemm_workload
        m, n, k = (int(x) for x in args.gemm.split(","))
        tiles = [TileConfig(bm, bn, bk)
                 for bm in (64, 128, 256) for bn in (64, 128, 256)
                 for bk in (16, 32, 64)]
        table = WorkloadTable.tile_lattice(
            gemm_workload("demo", m, n, k, precision=args.precision),
            tiles)
        win = client.argmin(table, args.hw)
        tile = tiles[win.index]
        print(f"argmin over {len(tiles)} tiles on {args.hw}: "
              f"bm={tile.bm} bn={tile.bn} bk={tile.bk} "
              f"-> {win.total * 1e3:.3f} ms ({win.breakdown.dominant}"
              f"-bound)")


if __name__ == "__main__":
    main()

"""Fault-injection TCP proxy: deterministic chaos for the serve stack.

The source paper validates its models by injecting controlled variation
across four architectures; this module applies the same discipline to
the serving layer.  A ``ChaosProxy`` sits between ``PredictionClient``
and ``PredictionServer`` on loopback and injures the server->client byte
stream on a **seeded, per-connection schedule**, so the fault-tolerance
tests (``tests/test_serve_faults.py``) and the availability-under-chaos
bench section can prove, reproducibly, that every injected fault
surfaces as a typed error or a successful retry — never a hang past the
deadline, a wrong answer, or a corrupted cache.

Fault classes (``FaultSpec.kind``):

    pass      forward untouched (the control)
    delay     hold the response back ``delay_s`` before forwarding — a
              slow peer; the client's read timeout / deadline governs
    stall     forward the request, swallow the response forever — a hung
              peer; only the client's read timeout can save it
    truncate  forward the first ``after_bytes`` of the response, then
              close — a truncated frame (``IncompleteRead`` client-side)
    bitflip   XOR ``flip_mask`` into the response byte at stream offset
              ``flip_at`` — silent corruption; the codec's CRC32
              integrity section is what turns this into a clean
              ``WireFormatError`` instead of a wrong float
    sever     close both directions after ``after_bytes`` (default 0:
              the connection dies before a single response byte)

Faults are assigned per accepted **connection** (a keep-alive connection
carries many requests; after a destructive fault the client reconnects
and the next connection takes the next schedule slot).  The schedule is
a plain list — build it explicitly for pinpoint tests, or with
``seeded_schedule(seed, n)`` for a reproducible mixed barrage; once the
schedule is exhausted, ``default`` (normally ``"pass"``) applies, so a
finite schedule never starves a retrying client.

Beyond the byte-stream injuries, ``kill_server_process`` is the
process-level scenario: SIGKILL the whole server session mid-stream (no
graceful drain, no FIN from the worker pool) and let the client prove
that a vanished peer surfaces as a typed retryable transport error —
and, once retries exhaust against the dead address, that the circuit
breaker opens (``repro_client_breaker_open_total``) so subsequent calls
fail fast instead of each paying a connect timeout.
"""
from __future__ import annotations

import os
import random
import signal
import socket
import subprocess
import threading
import time
from typing import List, Optional, Sequence, Tuple, Union

__all__ = ["ChaosProxy", "FAULT_KINDS", "FaultSpec", "kill_server_process",
           "seeded_schedule"]

FAULT_KINDS = ("pass", "delay", "stall", "truncate", "bitflip", "sever")

_RECV = 65536


class FaultSpec:
    """One connection's injury: a kind plus its parameters."""

    __slots__ = ("kind", "delay_s", "after_bytes", "flip_at", "flip_mask")

    def __init__(self, kind: str, *, delay_s: float = 0.05,
                 after_bytes: int = 0, flip_at: int = 200,
                 flip_mask: int = 0x40):
        if kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; valid: "
                             f"{FAULT_KINDS}")
        if not 1 <= int(flip_mask) <= 255:
            raise ValueError(f"flip_mask must be a byte-sized non-zero "
                             f"mask, got {flip_mask}")
        self.kind = kind
        self.delay_s = float(delay_s)
        self.after_bytes = int(after_bytes)
        self.flip_at = int(flip_at)
        self.flip_mask = int(flip_mask)

    def __repr__(self) -> str:
        extras = {"delay": f" delay_s={self.delay_s}",
                  "truncate": f" after_bytes={self.after_bytes}",
                  "sever": f" after_bytes={self.after_bytes}",
                  "bitflip": f" flip_at={self.flip_at} "
                             f"mask={self.flip_mask:#04x}"}
        return f"FaultSpec({self.kind!r}{extras.get(self.kind, '')})"


def _as_spec(fault: Union[str, FaultSpec]) -> FaultSpec:
    return fault if isinstance(fault, FaultSpec) else FaultSpec(fault)


def seeded_schedule(seed: int, n: int,
                    kinds: Sequence[str] = ("pass", "delay", "truncate",
                                            "bitflip", "sever")
                    ) -> List[FaultSpec]:
    """A reproducible mixed schedule: same ``(seed, n, kinds)`` -> the
    exact same fault sequence and parameters, process- and
    machine-independent (``random.Random(seed)`` is specified).  ``stall``
    is excluded by default because each stall costs a full client read
    timeout — opt in where the time budget allows."""
    rng = random.Random(seed)
    out = []
    for _ in range(n):
        kind = rng.choice(list(kinds))
        out.append(FaultSpec(
            kind,
            delay_s=round(0.01 + 0.04 * rng.random(), 4),
            after_bytes=rng.randrange(0, 64),
            flip_at=rng.randrange(32, 512),
            flip_mask=1 << rng.randrange(8)))
    return out


class ChaosProxy:
    """Forwarding TCP proxy that injures server->client streams.

    ``port=0`` binds an ephemeral loopback port (read ``address`` back).
    ``connection_log`` records the ``FaultSpec`` consumed by each
    accepted connection, in accept order — tests assert against it to
    prove the intended fault actually fired.  Thread-per-connection;
    ``close()`` tears down the listener and every live pipe.
    """

    def __init__(self, upstream_host: str, upstream_port: int,
                 schedule: Sequence[Union[str, FaultSpec]] = (), *,
                 default: Union[str, FaultSpec] = "pass",
                 host: str = "127.0.0.1", port: int = 0):
        self.upstream = (upstream_host, int(upstream_port))
        self.schedule = [_as_spec(f) for f in schedule]
        self.default = _as_spec(default)
        self.connection_log: List[FaultSpec] = []
        self._closed = False
        self._lock = threading.Lock()
        self._socks: List[socket.socket] = []
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, int(port)))
        self._listener.listen(64)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="chaos-accept")
        self._accept_thread.start()

    # ------------------------------------------------------------ plumbing
    @property
    def address(self) -> Tuple[str, int]:
        return self._listener.getsockname()[:2]

    @property
    def n_connections(self) -> int:
        with self._lock:
            return len(self.connection_log)

    def faults_injected(self) -> int:
        """Connections that were actually injured (kind != pass)."""
        with self._lock:
            return sum(1 for f in self.connection_log if f.kind != "pass")

    def _next_fault(self) -> FaultSpec:
        with self._lock:
            i = len(self.connection_log)
            fault = self.schedule[i] if i < len(self.schedule) \
                else self.default
            self.connection_log.append(fault)
        return fault

    def _track(self, sock: socket.socket) -> socket.socket:
        with self._lock:
            self._socks.append(sock)
        return sock

    # ----------------------------------------------------------- data path
    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                client, _ = self._listener.accept()
            except OSError:
                return                       # listener closed
            fault = self._next_fault()
            self._track(client)
            threading.Thread(target=self._handle, args=(client, fault),
                             daemon=True, name="chaos-pipe").start()

    def _handle(self, client: socket.socket, fault: FaultSpec) -> None:
        if fault.kind == "sever" and fault.after_bytes <= 0:
            # dead before a single byte moves either way
            _close(client)
            return
        try:
            up = socket.create_connection(self.upstream, timeout=10.0)
        except OSError:
            _close(client)
            return
        self._track(up)
        threading.Thread(target=self._pump_up, args=(client, up),
                         daemon=True, name="chaos-up").start()
        self._pump_down(up, client, fault)

    def _pump_up(self, client: socket.socket, up: socket.socket) -> None:
        """client -> upstream, always transparent (requests go through so
        the server does real work; the injury is on the reply path)."""
        try:
            while True:
                data = client.recv(_RECV)
                if not data:
                    break
                up.sendall(data)
        except OSError:
            pass
        finally:
            # half-close toward upstream; the down pump owns full teardown
            try:
                up.shutdown(socket.SHUT_WR)
            except OSError:
                pass

    def _pump_down(self, up: socket.socket, client: socket.socket,
                   fault: FaultSpec) -> None:
        """upstream -> client with ``fault`` applied."""
        forwarded = 0
        first = True
        try:
            while True:
                data = up.recv(_RECV)
                if not data:
                    break
                if fault.kind == "stall":
                    continue                 # swallow the response forever
                if first and fault.kind == "delay":
                    time.sleep(fault.delay_s)
                first = False
                if fault.kind == "truncate" or fault.kind == "sever":
                    room = fault.after_bytes - forwarded
                    if room <= 0:
                        break
                    data = data[:room]
                elif fault.kind == "bitflip":
                    off = fault.flip_at - forwarded
                    if 0 <= off < len(data):
                        buf = bytearray(data)
                        buf[off] ^= fault.flip_mask
                        data = bytes(buf)
                client.sendall(data)
                forwarded += len(data)
                if fault.kind in ("truncate", "sever") \
                        and forwarded >= fault.after_bytes:
                    break
        except OSError:
            pass
        finally:
            _close(up)
            _close(client)

    def close(self) -> None:
        self._closed = True
        _close(self._listener)
        with self._lock:
            socks, self._socks = list(self._socks), []
        for sock in socks:
            _close(sock)
        self._accept_thread.join(timeout=2.0)

    def __enter__(self) -> "ChaosProxy":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _close(sock: Optional[socket.socket]) -> None:
    if sock is None:
        return
    try:
        sock.close()
    except OSError:
        pass


def kill_server_process(proc: "subprocess.Popen",
                        timeout_s: float = 10.0) -> int:
    """SIGKILL a server subprocess session mid-stream and reap it.

    The process-level chaos scenario: unlike ``stop_server_subprocess``
    (SIGTERM -> graceful drain -> fallback kill), this kills the whole
    session group immediately — in-flight requests never get a reply
    byte, listening sockets close with RSTs in flight, the worker pool
    dies with its parent.  The client contract under this injury:

      * requests in flight (or sent after death) surface as retryable
        transport errors (``ConnectionError``/``OSError`` family, or
        ``DeadlineExceeded`` once a caller budget expires),
      * after ``breaker_threshold`` consecutive connect failures the
        circuit opens (``CircuitOpenError`` fail-fast; the
        ``repro_client_breaker_open_total`` counter records the
        closed->open transition).

    Returns the reaped exit status (negative signal number on POSIX).
    Falls back to killing the bare PID when the process is not a session
    leader.  Idempotent: killing an already-dead process just reaps it.
    """
    try:
        os.killpg(os.getpgid(proc.pid), signal.SIGKILL)
    except (ProcessLookupError, PermissionError, OSError):
        try:
            proc.kill()
        except OSError:
            pass
    return proc.wait(timeout=timeout_s)

"""Event-loop binary front end for :class:`~repro.serve.server.
PredictionServer`.

The HTTP front end spends its single-row latency budget on text
framing, header parsing, and a thread handoff per request (and, on the
wire, on ``http.client``'s split header/body writes colliding with
Nagle + delayed ACK).  This front end serves the same codec payloads
behind the fixed 24-byte header from :mod:`repro.serve.framing`, on ONE
``selectors``-based event-loop thread instead of a thread per
connection:

* the loop accepts, reads, parses frames, and writes replies — it never
  evaluates anything and never blocks;
* coalesced table sweeps go straight into the shared
  :class:`~repro.serve.server.Coalescer` via ``submit_async`` — the
  coalescer thread fires an ``on_done`` callback that encodes the reply
  and hands it back to the loop through a completion queue plus a
  socketpair wakeup;
* everything that can block for real time (lattice-spec streams,
  ``coalesce=False`` tables) runs on a small worker pool calling the
  same ``answer_decoded`` path HTTP uses.

Answers are therefore bit-identical across transports: both front ends
feed the identical coalescer/engine and encode with the identical
codec — only the framing differs.

Protocol errors (bad magic, unknown op, duplicate in-flight request id,
oversized frame) poison the connection: the stream offset can no longer
be trusted, so the server closes the socket rather than risk handing a
reply to the wrong request id.  Request-level errors (unknown hardware,
deadline exceeded, overload shed) are answered in-band as
``FLAG_ERROR`` frames carrying a codec ERROR message, and the
connection stays usable.
"""
from __future__ import annotations

import selectors
import socket
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Tuple

from ..core.workload import WorkloadTable
from ..obs import metrics, trace
from . import codec, errors
from .codec import WireFormatError
from .framing import (FLAG_ERROR, OP_CACHE_STATS, OP_HEALTH, OP_METRICS,
                      OP_SWEEP, FrameParser, pack_frame)
from .server import DRAIN_RETRY_AFTER_S, _stage_hist

__all__ = ["BinaryFrontend"]

#: per-recv read size: large enough that a fat pipelined burst drains in
#: few syscalls, small enough not to balloon per-connection buffers
_RECV_BYTES = 1 << 18

#: worker threads for requests the event loop must not run inline
#: (streamed lattices, ``coalesce=False`` tables) — table sweeps bypass
#: this pool entirely via the coalescer's async path
_SLOW_POOL_WORKERS = 4


class _Conn:
    """Per-connection state owned by the event-loop thread."""

    __slots__ = ("sock", "parser", "inflight", "out")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.parser = FrameParser()
        #: request ids awaiting a reply — duplicates are a protocol
        #: error (an id is the only demux key a pipelining client has)
        self.inflight = set()
        self.out = bytearray()

    @property
    def dead(self) -> bool:
        return self.sock.fileno() == -1


class BinaryFrontend:
    """The binary transport: one listening socket, one event-loop
    thread, shared ``PredictionServer`` behind it.

    Binds in ``__init__`` (so a port collision surfaces before any
    thread starts, mirroring the HTTP front end), serves after
    ``start()``.
    """

    #: stats schema, also used by the HTTP front end to zero-fill when
    #: no binary port is bound so ``cache_stats`` keeps one shape
    STAT_KEYS = ("connections", "connections_open", "frames_in",
                 "frames_out", "requests", "protocol_errors")

    def __init__(self, server, host: str = "127.0.0.1", port: int = 0):
        self.server = server
        self._stats = {"connections": 0, "frames_in": 0, "frames_out": 0,
                       "requests": 0, "protocol_errors": 0}
        #: one lock over stats mutations + snapshot: the loop thread is
        #: the only writer, but ``cache_stats`` reads from handler
        #: threads and must never see a torn multi-key combination
        self._stats_lock = threading.Lock()
        #: sweep frames accepted but not yet answered (pipeline depth)
        self._inflight_n = 0
        self._m_inflight = metrics.gauge(
            "repro_serve_binary_inflight",
            "Sweep frames in flight on the binary transport")
        self._m_accepted = metrics.counter(
            "repro_serve_binary_connections_total",
            "Connections accepted on the binary port")
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        try:
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            self._listener.listen(128)
            self._listener.setblocking(False)
        except BaseException:
            self._listener.close()
            raise
        # loop-wakeup channel: any thread may hand the loop work (reply
        # completions, drain/close flags) by writing one byte here
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self._sel = selectors.DefaultSelector()
        self._conns: set = set()
        #: cross-thread completion queue: (conn, op, req_id, payload,
        #: flags) tuples appended by coalescer/worker threads, drained
        #: by the loop (deque append/popleft are atomic)
        self._completed: deque = deque()
        self._pool = ThreadPoolExecutor(max_workers=_SLOW_POOL_WORKERS,
                                        thread_name_prefix="serve-bin")
        self._thread: Optional[threading.Thread] = None
        self._draining = False
        self._closed = False

    # ------------------------------------------------------------ plumbing
    @property
    def address(self) -> Tuple[str, int]:
        return self._listener.getsockname()[:2]

    @property
    def stats(self) -> Dict[str, int]:
        return self.stats_snapshot()

    def stats_snapshot(self) -> Dict[str, int]:
        """A mutually consistent copy of the frontend counters."""
        with self._stats_lock:
            out = dict(self._stats)
        out["connections_open"] = len(self._conns)
        return out

    def _bump(self, key: str, n: int = 1) -> None:
        with self._stats_lock:
            self._stats[key] += n

    def _track_inflight(self, delta: int) -> None:
        # loop-thread only — the gauge mirrors it for scrapers
        self._inflight_n += delta
        self._m_inflight.set(self._inflight_n)

    def start(self) -> "BinaryFrontend":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop, daemon=True,
                                            name="serve-binary")
            self._thread.start()
        return self

    def begin_drain(self) -> None:
        """Stop taking new work: new connections are refused and new
        sweep frames answered with an overload error; health/stats
        frames (probes) still answer; queued replies still flush."""
        self._draining = True
        self._wake()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._wake()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        else:
            # bound but never served: nothing owns the sockets yet
            self._listener.close()
            self._wake_r.close()
            self._wake_w.close()
        self._pool.shutdown(wait=False)

    def _wake(self) -> None:
        try:
            self._wake_w.send(b"\0")
        except OSError:
            pass                             # full pipe still wakes; closed
            #                                  pipe means the loop is gone

    # ----------------------------------------------------------- the loop
    def _loop(self) -> None:
        sel = self._sel
        sel.register(self._listener, selectors.EVENT_READ, "accept")
        sel.register(self._wake_r, selectors.EVENT_READ, "wake")
        try:
            while not self._closed:
                for key, mask in sel.select(timeout=0.5):
                    if key.data == "accept":
                        self._accept()
                    elif key.data == "wake":
                        self._drain_wakeups()
                    else:
                        conn = key.data
                        try:
                            if mask & selectors.EVENT_READ \
                                    and not conn.dead:
                                self._readable(conn)
                            if mask & selectors.EVENT_WRITE \
                                    and not conn.dead:
                                self._flush(conn)
                        except Exception:    # noqa: BLE001 — loop survives
                            self._close_conn(conn)
                self._drain_completed()
        finally:
            for conn in list(self._conns):
                self._close_conn(conn)
            sel.close()
            self._listener.close()
            self._wake_r.close()
            self._wake_w.close()

    def _drain_wakeups(self) -> None:
        try:
            while self._wake_r.recv(4096):
                pass
        except (BlockingIOError, InterruptedError):
            pass
        except OSError:
            pass

    def _accept(self) -> None:
        while True:
            try:
                s, _ = self._listener.accept()
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                return
            if self._draining or self._closed:
                s.close()
                continue
            s.setblocking(False)
            # one sendall per frame + NODELAY = no Nagle/delayed-ACK
            # stall — the entire point of this transport
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Conn(s)
            self._conns.add(conn)
            self._sel.register(s, selectors.EVENT_READ, conn)
            self._bump("connections")
            self._m_accepted.inc()

    def _close_conn(self, conn: _Conn) -> None:
        try:
            self._sel.unregister(conn.sock)
        except (KeyError, ValueError):
            pass
        if conn in self._conns and conn.inflight:
            self._track_inflight(-len(conn.inflight))
        self._conns.discard(conn)
        try:
            conn.sock.close()
        except OSError:
            pass

    def _readable(self, conn: _Conn) -> None:
        try:
            data = conn.sock.recv(_RECV_BYTES)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            self._close_conn(conn)
            return
        if not data:                         # peer closed / severed
            self._close_conn(conn)
            return
        try:
            conn.parser.feed(data)
            for frame in conn.parser.frames():
                self._bump("frames_in")
                self._handle_frame(conn, frame)
                if conn.dead:                # closed mid-burst
                    return
        except WireFormatError:
            # the stream offset is untrustworthy — close instead of
            # guessing where the next frame starts
            self._bump("protocol_errors")
            self._close_conn(conn)

    # ------------------------------------------------------------ dispatch
    def _handle_frame(self, conn: _Conn, frame) -> None:
        if frame.req_id in conn.inflight:
            # two outstanding requests with one id cannot be demuxed —
            # closing is safer than ever answering the wrong caller
            self._bump("protocol_errors")
            self._close_conn(conn)
            return
        self._bump("requests")
        server = self.server
        server.n_requests += 1
        if frame.op == OP_HEALTH:
            self._send_local(conn, frame.op, frame.req_id,
                             codec.encode_json(server.health()))
            return
        if frame.op == OP_CACHE_STATS:
            self._send_local(conn, frame.op, frame.req_id,
                             codec.encode_json(server.stats()))
            return
        if frame.op == OP_METRICS:
            # the same Prometheus text /v1/metrics serves, wrapped in a
            # MSG_JSON; answers during drain like the other probe ops
            self._send_local(conn, frame.op, frame.req_id,
                             codec.encode_json(server.metrics_text()))
            return
        # OP_SWEEP from here on
        if self._draining or self._closed:
            self._send_local(conn, frame.op, frame.req_id,
                             codec.encode_error(errors.ServerOverloaded(
                                 "server is draining — no new work "
                                 "accepted",
                                 retry_after_s=DRAIN_RETRY_AFTER_S)),
                             flags=FLAG_ERROR)
            return
        deadline = (time.monotonic() + frame.deadline_s
                    if frame.deadline_s > 0.0 else None)
        conn.inflight.add(frame.req_id)
        self._track_inflight(+1)
        t0 = time.monotonic()
        try:
            op, source, meta = codec.decode_request(frame.payload)
            trace_id = trace.coerce_trace_id(meta.get("trace_id"))
            _stage_hist("parse").observe(time.monotonic() - t0,
                                         trace_id=trace_id)
            if isinstance(source, WorkloadTable) \
                    and meta.get("coalesce", True):
                # the fast path: park in the coalescer without blocking;
                # the reply is encoded on the coalescer thread and
                # flushed by the loop after a wakeup
                hw, model, k, objectives, calibration, max_rows = \
                    server._resolve_sweep(meta)
                req_id = frame.req_id

                def on_done(r, conn=conn, op=op, req_id=req_id,
                            trace_id=trace_id, t0=t0):
                    if r.error is not None:
                        payload, flags = codec.encode_error(r.error), \
                            FLAG_ERROR
                    else:
                        try:
                            t_enc = time.monotonic()
                            payload = (codec.encode_totals(r.result)
                                       if op == "predict_table"
                                       else codec.encode_winners(r.result))
                            _stage_hist("encode").observe(
                                time.monotonic() - t_enc,
                                trace_id=trace_id)
                            flags = 0
                        except Exception as e:  # noqa: BLE001
                            payload, flags = codec.encode_error(e), \
                                FLAG_ERROR
                    self._completed.append(
                        (conn, OP_SWEEP, req_id, payload, flags))
                    self._wake()
                    self.server._observe_request(
                        "binary", op, trace_id, time.monotonic() - t0,
                        400 if flags & FLAG_ERROR else 200)

                server.coalescer.submit_async(
                    op, source, hw, model, k=k, objectives=objectives,
                    calibration=calibration, deadline=deadline,
                    max_rows=max_rows, on_done=on_done,
                    trace_id=trace_id)
                return
        except Exception as e:               # noqa: BLE001 — typed reply
            self._send_local(conn, OP_SWEEP, frame.req_id,
                             codec.encode_error(e), flags=FLAG_ERROR)
            return
        # the slow path: lattice specs and coalesce=False tables block
        # for real evaluation time — never on the loop
        self._pool.submit(self._answer_slow, conn, op, source, meta,
                          deadline, frame.req_id, trace_id, t0)

    def _answer_slow(self, conn: _Conn, op, source, meta, deadline,
                     req_id: int, trace_id=None, t0=None) -> None:
        try:
            payload, flags = self.server.answer_decoded(
                op, source, meta, deadline=deadline,
                trace_id=trace_id), 0
        except BaseException as e:           # noqa: BLE001 — typed reply
            payload, flags = codec.encode_error(e), FLAG_ERROR
        self._completed.append((conn, OP_SWEEP, req_id, payload, flags))
        self._wake()
        if t0 is not None:
            self.server._observe_request(
                "binary", op, trace_id, time.monotonic() - t0,
                400 if flags & FLAG_ERROR else 200)

    # -------------------------------------------------------------- output
    def _drain_completed(self) -> None:
        while True:
            try:
                conn, op, req_id, payload, flags = \
                    self._completed.popleft()
            except IndexError:
                return
            if conn.dead:                    # died while evaluating
                continue
            self._send_local(conn, op, req_id, payload, flags)

    def _send_local(self, conn: _Conn, op: int, req_id: int,
                    payload: bytes, flags: int = 0) -> None:
        """Queue one reply frame and push bytes opportunistically (send
        now if the socket will take them — a select round-trip per reply
        would put scheduler latency back on the fast path)."""
        if req_id in conn.inflight:
            conn.inflight.discard(req_id)
            self._track_inflight(-1)
        conn.out += pack_frame(op, req_id, payload, flags=flags)
        self._bump("frames_out")
        self._flush(conn)

    def _flush(self, conn: _Conn) -> None:
        if conn.out:
            try:
                sent = conn.sock.send(conn.out)
                del conn.out[:sent]
            except (BlockingIOError, InterruptedError):
                pass
            except OSError:
                self._close_conn(conn)
                return
        events = selectors.EVENT_READ | (selectors.EVENT_WRITE
                                         if conn.out else 0)
        try:
            self._sel.modify(conn.sock, events, conn)
        except (KeyError, ValueError):
            pass

"""Sharding rules: logical axes -> mesh axes, param specs by naming
convention, activation constraints.

Parallelism layout (DESIGN.md §5):
  * batch ("batch")            -> ("pod", "data")     DP across pods+pod-local
  * params (FSDP dim)          -> "data"              ZeRO-3 inside a pod,
                                                      replicated across pods
  * heads / ffn / experts /
    vocab ("tensor" dims)      -> "model"             TP/EP
  * long-context KV seq        -> "data"              SP (batch=1 decode)

Param placement is inferred from leaf NAMES (naming convention, enforced by
the model code):
  TP on last dim : wq wk wv wg wu wi w_router w_dkv w_uk w_uv w_qa w_qb
                   lm_head w_gates
  TP on first dim: wo wd w_out
  tok_embed      : vocab dim (0) on "model"
  1-D / conv / scalars: replicated.
FSDP shards the largest non-TP dim on "data".
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True):
    """Version-portable ``shard_map``.

    Newer JAX exposes ``jax.shard_map(..., check_vma=...)``; older releases
    only have ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
    ``check_vma`` is the renamed ``check_rep`` — forward it to whichever
    spelling this JAX build understands.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)

TP_LAST = {"wq", "wk", "wv", "wg", "wu", "wi", "w_router", "w_dkv", "w_uk",
           "w_uv", "w_qa", "w_qb", "lm_head", "w_gates", "w_in", "wx", "wy",
           "w_z", "w_xs", "w_dtp"}
# mamba2's w_b / w_c deliberately NOT TP (2N per token is tiny; computing
# B/C replicated avoids per-head all-reduces in the SSD contraction)
TP_FIRST = {"wo", "wd", "w_out"}
EXPERT = {"we_g", "we_u", "we_d"}          # (E, in, out): EP on dim 0
EMBED = {"tok_embed", "frame_embed", "patch_embed"}

_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh", default=None)
_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_rules", default=None)
_MANUAL: contextvars.ContextVar = contextvars.ContextVar(
    "repro_manual", default=False)


@contextlib.contextmanager
def manual_region():
    """Mark a shard_map body: constrain() must no-op on manual axes."""
    tok = _MANUAL.set(True)
    try:
        yield
    finally:
        _MANUAL.reset(tok)

# logical activation axis -> mesh axes
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,            # set to "data" for long-context SP plans
    "heads": "model",
    "head_shard": "model",     # inner (vectorized) head axis in SSD blocks
    "embed": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "fsdp": "data",
}


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    """Install mesh + rules for constrain()/param_sharding() lookups."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    # drop mesh axes that don't exist (single-pod meshes have no "pod")
    axis_names = set(mesh.axis_names)

    def filt(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in axis_names else None
        vv = tuple(a for a in v if a in axis_names)
        return vv or None
    rules = {k: filt(v) for k, v in rules.items()}
    tok_m = _ACTIVE_MESH.set(mesh)
    tok_r = _RULES.set(rules)
    try:
        with mesh:
            yield
    finally:
        _ACTIVE_MESH.reset(tok_m)
        _RULES.reset(tok_r)


def current_rules() -> Optional[dict]:
    return _RULES.get()


def axis_size(name: str) -> int:
    """Size of a mesh axis under the active mesh (1 outside use_mesh)."""
    mesh = _ACTIVE_MESH.get()
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(name, 1))


def constrain(x, logical: Tuple[Optional[str], ...]):
    """with_sharding_constraint via logical axis names; no-op outside
    use_mesh()."""
    mesh = _ACTIVE_MESH.get()
    rules = _RULES.get()
    if mesh is None or rules is None or _MANUAL.get():
        return x
    spec = P(*(rules.get(a) if a else None for a in logical))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def _axes_size(mesh_shape: Optional[dict], axes) -> int:
    if mesh_shape is None or axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh_shape.get(axes, 1))
    n = 1
    for a in axes:
        n *= int(mesh_shape.get(a, 1))
    return n


def _guard(spec_list, shape, mesh_shape):
    """Replace axis assignments whose size does not divide the dim with
    None (divisibility guard; e.g. minicpm's 122753 vocab)."""
    out = []
    for dim, axes in zip(shape, spec_list):
        if axes is None:
            out.append(None)
            continue
        n = _axes_size(mesh_shape, axes)
        out.append(axes if n > 0 and dim % n == 0 else None)
    return out


def leaf_spec(path: str, shape, *, rules: dict,
              stacked: bool = False,
              mesh_shape: Optional[dict] = None) -> P:
    """PartitionSpec for one param leaf from its name + shape."""
    parts = path.split("/")
    name = parts[-1]
    # q8 moment leaves (optim/quantized_moments.q8nd_*): inherit the parent
    # weight's spec on the leading dims; q carries an extra trailing
    # (blocks, 256) split of the last dim, scale carries (blocks[, 2]).
    if name in ("q", "scale") and len(parts) >= 2:
        parent = parts[-2]
        if name == "q" and len(shape) >= 2:
            base = leaf_spec("/".join(parts[:-1]), shape[:-1], rules=rules,
                             stacked=stacked, mesh_shape=mesh_shape)
            return P(*base, None)
        if name == "scale" and len(shape) >= 1:
            # nonneg scales end with a packed [lmin, lrange] pair dim
            trailing_pair = shape[-1] == 2 and len(shape) >= 2
            core = shape[:-1] if trailing_pair else shape
            base = leaf_spec("/".join(parts[:-1]), core, rules=rules,
                             stacked=stacked, mesh_shape=mesh_shape)
            return P(*base, None) if trailing_pair else base
    tp = rules.get("heads") or rules.get("ffn")
    fsdp = rules.get("fsdp")
    lead_n = 1 if stacked else 0
    body = len(shape) - lead_n
    bshape = shape[lead_n:]
    lead = (None,) * lead_n

    if body <= 1:
        return P(*lead, *((None,) * body))
    if name in EMBED:
        spec = [tp, fsdp] + [None] * (body - 2)    # (V, D)
    elif name in EXPERT:
        spec = [tp, fsdp] + [None] * (body - 2)    # (E, in, out): EP
    elif name in TP_LAST:
        spec = [None] * body
        spec[-1] = tp
        spec[0] = fsdp
    elif name in TP_FIRST:
        spec = [None] * body
        spec[0] = tp
        spec[-1] = fsdp
    else:
        spec = [None] * body
        spec[0] = fsdp if body >= 2 else None
    spec = _guard(spec, bshape, mesh_shape)
    return P(*lead, *spec)


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params, *, rules: Optional[dict] = None,
                mesh=None,
                stacked_prefixes: Tuple[str, ...] = ("blocks", "groups",
                                                     "prefix")):
    """PartitionSpec pytree mirroring ``params``.

    Leaves under ``stacked_prefixes`` carry a leading layer-stacking dim
    (scan-over-layers) which is never sharded.  ``mesh`` (or the active
    mesh) enables the divisibility guard.
    """
    rules = rules if rules is not None else (_RULES.get() or DEFAULT_RULES)
    mesh = mesh if mesh is not None else _ACTIVE_MESH.get()
    mesh_shape = dict(mesh.shape) if mesh is not None else None

    def spec_of(kp, leaf):
        path = _path_str(kp)
        stacked = any(path.startswith(p) or f"/{p}" in path
                      for p in stacked_prefixes)
        return leaf_spec(path, leaf.shape, rules=rules, stacked=stacked,
                         mesh_shape=mesh_shape)

    return jax.tree_util.tree_map_with_path(spec_of, params)


def param_shardings(mesh: Mesh, params, **kw):
    specs = param_specs(params, mesh=mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_specs_tree(batch, *, rules: Optional[dict] = None,
                     mesh=None):
    """PartitionSpecs for a data batch: dim 0 (global batch) over the DP
    axes, guarded for divisibility (long_500k has batch 1 -> replicated)."""
    rules = rules if rules is not None else (_RULES.get() or DEFAULT_RULES)
    mesh = mesh if mesh is not None else _ACTIVE_MESH.get()
    mesh_shape = dict(mesh.shape) if mesh is not None else None
    dp = rules.get("batch")

    def spec_of(leaf):
        if leaf.ndim == 0:
            return P()
        spec = [dp] + [None] * (leaf.ndim - 1)
        return P(*_guard(spec, leaf.shape, mesh_shape))

    return jax.tree.map(spec_of, batch)


# cache leaf name -> (which dim gets the DP axes, which gets "model")
_CACHE_LAYOUT = {
    # stacked caches: dim0 = layer group
    "k": (1, 2),        # (G, B, S, Hkv, hd): B->dp, S->model (seq shard)
    "v": (1, 2),
    "latent": (1, 2),   # (G, B, S, rank)
    "k_rope": (1, 2),
    "ssm": (1, 2),      # (G, B, H, N, P): B->dp, H->model
    "conv": (1, 3),     # (G, B, w, C): B->dp, C->model
    "h": (1, 2),        # (G, B, W): B->dp, W->model
}


def cache_specs_tree(cache, *, rules: Optional[dict] = None, mesh=None):
    """PartitionSpecs for decode caches (divisibility-guarded)."""
    rules = rules if rules is not None else (_RULES.get() or DEFAULT_RULES)
    mesh = mesh if mesh is not None else _ACTIVE_MESH.get()
    mesh_shape = dict(mesh.shape) if mesh is not None else None
    dp = rules.get("batch")
    tp = rules.get("heads") or rules.get("ffn")

    def spec_of(kp, leaf):
        name = _path_str(kp).split("/")[-1]
        layout = _CACHE_LAYOUT.get(name)
        spec = [None] * leaf.ndim
        if layout is not None:
            dp_dim, tp_dim = layout
            if dp_dim < leaf.ndim:
                spec[dp_dim] = dp
            if tp_dim < leaf.ndim:
                spec[tp_dim] = tp
        return P(*_guard(spec, leaf.shape, mesh_shape))

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def tree_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))

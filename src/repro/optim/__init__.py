from .adamw import adamw_init, adamw_update, clip_by_global_norm  # noqa
from .schedule import cosine_schedule, linear_schedule, wsd_schedule  # noqa
from .grad_compression import (compress_int8, decompress_int8,  # noqa
                               error_feedback_update)

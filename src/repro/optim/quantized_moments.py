"""Block-wise int8 quantized Adam moments (8-bit-Adam-style; Dettmers et
al.), the §Perf fix for the >=400B single-pod HBM budget: m and v stored
as int8 + fp32 scale per 256-element block => 2.5 bytes/param for both
moments vs 8 (fp32) / 4 (bf16).

Quantization: m (signed) symmetric linear int8; v (non-negative) linear
uint8-style on [0, max].  Dequant -> update -> requant each step; the
fp32 master arithmetic stays exact within the step.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def quantize_signed(x) -> Tuple[jax.Array, jax.Array]:
    """x (flat fp32) -> (int8 blocks, fp32 scales per block)."""
    n = x.size
    xp = jnp.pad(x.reshape(-1), (0, _pad_len(n) - n)).reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xp), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xp / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_signed(q, scale, shape) -> jax.Array:
    x = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return x[:n].reshape(shape)


V_FLOOR = 1e-30


def quantize_nonneg(x) -> Tuple[jax.Array, jax.Array]:
    """Non-negative x (second moment) -> int8 blocks in LOG space.

    v spans many orders of magnitude; linear quantization flushes small
    entries to zero and mhat/(sqrt(0)+eps) explodes (observed: parameter
    drift 1.4 after 30 steps).  Log-space affine quantization keeps
    ~2.3% RELATIVE resolution across the whole block range.

    Returns (q int8, packed scales (blocks, 2) = [lmin, lrange])."""
    n = x.size
    # edge-pad: padding with a constant would stretch the last block's log
    # range and destroy its resolution
    xp = jnp.pad(x.reshape(-1), (0, _pad_len(n) - n),
                 mode="edge").reshape(-1, BLOCK)
    l = jnp.log(jnp.maximum(xp, V_FLOOR))
    lmin = jnp.min(l, axis=1)
    lrange = jnp.maximum(jnp.max(l, axis=1) - lmin, 1e-6)
    q = jnp.clip(jnp.round(255.0 * (l - lmin[:, None]) / lrange[:, None]),
                 0, 255)
    return (q - 128).astype(jnp.int8), jnp.stack([lmin, lrange], axis=1)


def dequantize_nonneg(q, scales, shape) -> jax.Array:
    lmin, lrange = scales[:, 0], scales[:, 1]
    l = lmin[:, None] + (q.astype(jnp.float32) + 128.0) / 255.0 \
        * lrange[:, None]
    x = jnp.exp(l).reshape(-1)
    x = jnp.where(x <= V_FLOOR * 2.0, 0.0, x)
    n = 1
    for d in shape:
        n *= d
    return x[:n].reshape(shape)


def q8_init(params) -> Dict:
    def zeros_m(p):
        blocks = _pad_len(p.size) // BLOCK
        return {"q": jnp.zeros((blocks, BLOCK), jnp.int8),
                "scale": jnp.zeros((blocks,), jnp.float32)}

    def zeros_v(p):
        blocks = _pad_len(p.size) // BLOCK
        q, s = quantize_nonneg(jnp.zeros(p.shape, jnp.float32))
        return {"q": q, "scale": s}
    return {
        "mu": jax.tree.map(zeros_m, params),
        "nu": jax.tree.map(zeros_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def q8_adamw_update(params, grads, state: Dict, *, lr,
                    b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                    weight_decay: float = 0.1,
                    max_grad_norm: float = 1.0):
    """AdamW with int8 block-quantized moments.  Same signature contract
    as optim.adamw.adamw_update."""
    from .adamw import clip_by_global_norm

    step = state["step"] + 1
    lr_t = lr(step) if callable(lr) else lr
    if max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = jnp.zeros(())
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])

    new_p, new_m, new_v = [], [], []
    for p, g, mq, vq in zip(flat_p, flat_g, flat_m, flat_v):
        gf = g.astype(jnp.float32)
        m = dequantize_signed(mq["q"], mq["scale"], p.shape)
        v = dequantize_nonneg(vq["q"], vq["scale"], p.shape)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) \
            + weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype))
        q, s = quantize_signed(m)
        new_m.append({"q": q, "scale": s})
        q, s = quantize_nonneg(v)
        new_v.append({"q": q, "scale": s})

    return (tdef.unflatten(new_p),
            {"mu": tdef.unflatten(new_m), "nu": tdef.unflatten(new_v),
             "step": step},
            {"grad_norm": gnorm, "lr": lr_t})


def moment_bytes_per_param() -> float:
    """2 int8 + (1 + 2) fp32 scale words per 256-block ~ 2.05
    bytes/param for both moments."""
    return 2.0 + 3.0 * 4.0 / BLOCK


# ---------------------------------------------------------------------------
# Shape-preserving block quantization (§Perf #6 fix).
#
# The flat (blocks, 256) layout destroys TP/EP sharding (everything folds
# into one dim that can only shard over "data").  Here blocks live along
# the LAST axis only: q has shape p.shape[:-1] + (ceil(last/256), 256) and
# scales p.shape[:-1] + (blocks, ...), so the leading dims keep the exact
# sharding of the parameter (distributed/sharding.py special-cases
# "q"/"scale" leaves to inherit the parent weight's spec).
# ---------------------------------------------------------------------------

def _last_blocks(last: int) -> int:
    return -(-last // BLOCK)


def _pad_last(x):
    last = x.shape[-1]
    pad = _last_blocks(last) * BLOCK - last
    if pad:
        cfg = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, cfg, mode="edge")
    return x.reshape(*x.shape[:-1], _last_blocks(last), BLOCK)


def quantize_signed_nd(x) -> Tuple[jax.Array, jax.Array]:
    xb = _pad_last(x.astype(jnp.float32))
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_signed_nd(q, scale, shape):
    x = q.astype(jnp.float32) * scale[..., None]
    return x.reshape(*shape[:-1], -1)[..., :shape[-1]]


def quantize_nonneg_nd(x) -> Tuple[jax.Array, jax.Array]:
    xb = _pad_last(x.astype(jnp.float32))
    l = jnp.log(jnp.maximum(xb, V_FLOOR))
    lmin = jnp.min(l, axis=-1)
    lrange = jnp.maximum(jnp.max(l, axis=-1) - lmin, 1e-6)
    q = jnp.clip(jnp.round(255.0 * (l - lmin[..., None])
                           / lrange[..., None]), 0, 255)
    return (q - 128).astype(jnp.int8), jnp.stack([lmin, lrange], axis=-1)


def dequantize_nonneg_nd(q, scales, shape):
    lmin, lrange = scales[..., 0], scales[..., 1]
    l = lmin[..., None] + (q.astype(jnp.float32) + 128.0) / 255.0 \
        * lrange[..., None]
    x = jnp.exp(l)
    x = jnp.where(x <= V_FLOOR * 2.0, 0.0, x)
    return x.reshape(*shape[:-1], -1)[..., :shape[-1]]


def q8nd_init(params) -> Dict:
    def zeros_m(p):
        if p.ndim == 0:
            return {"q": jnp.zeros(p.shape, jnp.float32)}  # scalars: fp32
        nb = _last_blocks(p.shape[-1])
        return {"q": jnp.zeros((*p.shape[:-1], nb, BLOCK), jnp.int8),
                "scale": jnp.zeros((*p.shape[:-1], nb), jnp.float32)}

    def zeros_v(p):
        if p.ndim == 0:
            return {"q": jnp.zeros(p.shape, jnp.float32)}
        q, s = quantize_nonneg_nd(jnp.zeros(p.shape, jnp.float32))
        return {"q": q, "scale": s}

    return {"mu": jax.tree.map(zeros_m, params),
            "nu": jax.tree.map(zeros_v, params),
            "step": jnp.zeros((), jnp.int32)}


def q8nd_adamw_update(params, grads, state: Dict, *, lr,
                      b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                      weight_decay: float = 0.1,
                      max_grad_norm: float = 1.0):
    """AdamW with shape-preserving int8 moments (sharding-compatible)."""
    from .adamw import clip_by_global_norm

    step = state["step"] + 1
    lr_t = lr(step) if callable(lr) else lr
    if max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = jnp.zeros(())
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])

    new_p, new_m, new_v = [], [], []
    for p, g, mq, vq in zip(flat_p, flat_g, flat_m, flat_v):
        gf = g.astype(jnp.float32)
        if p.ndim == 0:
            m = b1 * mq["q"] + (1 - b1) * gf
            v = b2 * vq["q"] + (1 - b2) * gf * gf
            delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) \
                + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32)
                          - lr_t * delta).astype(p.dtype))
            new_m.append({"q": m})
            new_v.append({"q": v})
            continue
        m = dequantize_signed_nd(mq["q"], mq["scale"], p.shape)
        v = dequantize_nonneg_nd(vq["q"], vq["scale"], p.shape)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) \
            + weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype))
        q, s = quantize_signed_nd(m)
        new_m.append({"q": q, "scale": s})
        q, s = quantize_nonneg_nd(v)
        new_v.append({"q": q, "scale": s})

    return (tdef.unflatten(new_p),
            {"mu": tdef.unflatten(new_m), "nu": tdef.unflatten(new_v),
             "step": step},
            {"grad_norm": gnorm, "lr": lr_t})

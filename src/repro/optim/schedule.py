"""LR schedules.  WSD (warmup-stable-decay) is the minicpm-2b paper's
schedule [arXiv:2404.06395]: linear warmup, long stable plateau, short
(~10%) exponential/linear decay."""
from __future__ import annotations

import jax.numpy as jnp


def linear_schedule(peak_lr: float, warmup: int, total: int):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        decay = jnp.maximum(0.0, (total - s) / jnp.maximum(total - warmup, 1))
        return peak_lr * jnp.where(s < warmup, warm, decay)
    return lr


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        t = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak_lr * jnp.where(s < warmup, warm, cos)
    return lr


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_fraction: float = 0.1, min_ratio: float = 0.01):
    """Warmup -> Stable (peak) -> Decay (last decay_fraction of steps)."""
    decay_start = int(total * (1.0 - decay_fraction))

    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        t = jnp.clip((s - decay_start) / jnp.maximum(total - decay_start, 1),
                     0.0, 1.0)
        decay = jnp.exp(jnp.log(jnp.maximum(min_ratio, 1e-6)) * t)
        val = jnp.where(s < warmup, warm,
                        jnp.where(s < decay_start, 1.0, decay))
        return peak_lr * val
    return lr


def for_arch(arch_name: str, peak_lr: float, warmup: int, total: int):
    """minicpm trains with WSD (its paper's contribution); others cosine."""
    if "minicpm" in arch_name:
        return wsd_schedule(peak_lr, warmup, total)
    return cosine_schedule(peak_lr, warmup, total)

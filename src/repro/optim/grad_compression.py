"""Int8 error-feedback gradient compression for the cross-pod all-reduce
(DESIGN.md §5 distributed-optimization tricks).

Wire format: per-tensor symmetric int8 quantization (scale = max|g|/127).
Error feedback: the quantization residual is added back into the next
step's gradient, so compression bias does not accumulate (Karimireddy et
al., "Error Feedback Fixes SignSGD").

The collective-model pricing of the 4x wire-byte reduction lives in
core/autotune.py (plan.compressed_grads).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(g) -> Tuple[jax.Array, jax.Array]:
    """g -> (int8 tensor, fp32 scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def error_feedback_update(grads, residuals) -> Tuple[Any, Any]:
    """Quantize (grads + residuals); return (decompressed grads for the
    optimizer — what the wire would deliver — and new residuals)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

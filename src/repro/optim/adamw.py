"""AdamW with dtype-configurable moments (bf16 moments for the >=100B
configs; DESIGN.md §5 memory budget) and global-norm clipping."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params, *, moment_dtype: Optional[str] = None) -> Dict:
    md = jnp.dtype(moment_dtype) if moment_dtype else None

    def zeros_like(p):
        return jnp.zeros(p.shape, md or p.dtype)

    return {
        "mu": jax.tree.map(zeros_like, params),
        "nu": jax.tree.map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32)
                                   * scale).astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state: Dict, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 eps_root: float = 0.0,
                 weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics).  lr may be a scalar or a
    callable step -> lr.

    ``eps_root`` is added inside the square root (optax semantics, default
    off): a nonzero value bounds the update's sensitivity to gradient
    noise when the second moment is near zero.  Without it, the first
    steps behave like sign(g) with an eps-wide transition, so two gradient
    estimates that agree to fp32 round-off (e.g. accumulated microbatches
    vs. the full batch) can produce updates differing by O(lr) on
    near-zero-gradient elements.  The train substrate opts in
    (train_step.EPS_ROOT)."""
    step = state["step"] + 1
    lr_t = lr(step) if callable(lr) else lr
    if max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = jnp.zeros(())

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat + eps_root) + eps) \
            + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr_t * delta
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = {"mu": new_m, "nu": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr_t}

"""Launch the prediction server as a long-lived local service.

Thin launcher over ``repro.serve.server.main`` that (a) puts ``src/`` on
``sys.path`` so it runs from a repo checkout without ``PYTHONPATH``
plumbing, and (b) applies service-shaped defaults on top of the server's
own (which are tuned for tests and ephemeral subprocesses):

    --jobs 0             worker pool sized to every core
    --binary-port 8708   the framed persistent-socket transport, on
    --metrics on         observability layer live; scrape GET /v1/metrics
    --slow-request-ms 500  structured JSON slow-request log on stderr,
                           each line carrying the request's trace id

Every flag is forwarded verbatim and anything you pass explicitly wins
over these defaults — ``--metrics off`` disables every counter,
histogram and span process-wide (the ``/v1/metrics`` surface stays up
but stops moving), and ``--slow-request-ms 0`` logs every sweep.
See ``src/repro/serve/README.md`` "Observability" for the metric naming
contract and the Prometheus scrape stanza.

Run:  python launch/predict_serve.py
      python launch/predict_serve.py --port 9000 --metrics off
      python launch/predict_serve.py --slow-request-ms 50 2>slow.jsonl
"""
import os
import sys

_SRC = os.path.normpath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

DEFAULTS = (
    ("--jobs", "0"),
    ("--binary-port", "8708"),
    ("--metrics", "on"),
    ("--slow-request-ms", "500"),
)


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    for flag, value in DEFAULTS:
        if not any(a == flag or a.startswith(flag + "=") for a in argv):
            argv += [flag, value]
    from repro.serve.server import main as server_main
    server_main(argv)


if __name__ == "__main__":
    main()

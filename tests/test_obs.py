"""Observability tests: metrics primitives, trace propagation, contract.

Three layers are pinned here:

* ``repro.obs.metrics`` / ``repro.obs.trace`` primitives — thread-safe
  counters/gauges/histograms, Prometheus text exposition shape, the
  process-wide kill switch, trace-id grammar, span ring, slow-log lines.
* The **trace propagation matrix** — one trace id minted client-side is
  demonstrably visible in the client's attempt span, the server's
  structured slow-request log line, and a histogram exemplar, across
  every serving path: HTTP solo, HTTP coalesced, binary pipelined,
  dedup'd duplicate, and the poison-isolated solo re-run.
* The **metric-name contract** — family names are append-only once
  shipped; the snapshot test below is the tripwire (extending the list
  is fine, renaming/removing a name is a breaking change for scrapers).
"""
import json
import threading

import pytest

from repro.core import hardware, sweep
from repro.core.workload import TileConfig, WorkloadTable, gemm_workload
from repro.obs import metrics, trace
from repro.serve.client import PredictionClient
from repro.serve.server import Coalescer, PredictionServer

B200 = hardware.B200
TILES = [TileConfig(bm, bn, 32) for bm in (64, 128) for bn in (64, 128)]


def small_table(name="g"):
    return WorkloadTable.tile_lattice(
        gemm_workload(name, 1024, 1024, 1024, precision="fp16"), TILES)


def poison_table(name="POISON"):
    return WorkloadTable.tile_lattice(
        gemm_workload(name, 1024, 1024, 1024, precision="fp64"), TILES)


class PoisonEngine(sweep.SweepEngine):
    """Refuses any table containing an fp64 row (see test_serve_faults)."""

    def predict_table(self, table, hw, **kw):
        if "fp64" in {table.precision_vocab[c]
                      for c in table.precision_codes}:
            raise ValueError("poisoned row (fp64 sentinel)")
        return super().predict_table(table, hw, **kw)


def exemplar_ids():
    """Every trace id currently attached to a histogram exemplar."""
    ids = set()
    for fam in metrics.snapshot().values():
        for s in fam["series"]:
            for ex in s.get("exemplars", ()):
                ids.add(ex["trace_id"])
    return ids


def assert_trace_visible(tid, slow_lines):
    """The matrix invariant: one id, three observation points."""
    client_spans = trace.recent_spans(trace_id=tid, name="client.attempt")
    assert client_spans, f"no client.attempt span for {tid}"
    logged = [json.loads(l) for l in slow_lines]
    assert any(r.get("trace_id") == tid for r in logged), \
        f"trace {tid} missing from slow-request log"
    assert tid in exemplar_ids(), \
        f"trace {tid} not attached to any histogram exemplar"


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

class TestMetricsPrimitives:
    def test_counter(self):
        reg = metrics.Registry()
        c = reg.counter("t_requests_total", "help text", transport="http")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge(self):
        reg = metrics.Registry()
        g = reg.gauge("t_depth")
        g.set(5)
        g.inc(2)
        g.dec(3)
        assert g.value == 4.0

    def test_histogram_buckets_cumulative(self):
        reg = metrics.Registry()
        h = reg.histogram("t_seconds", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 0.5, 5.0, 50.0):
            h.observe(v)
        assert h.count == 5
        assert h.sum == pytest.approx(56.05)
        text = reg.render_prometheus()
        assert 't_seconds_bucket{le="0.1"} 1' in text
        assert 't_seconds_bucket{le="1"} 3' in text
        assert 't_seconds_bucket{le="10"} 4' in text
        assert 't_seconds_bucket{le="+Inf"} 5' in text
        assert "t_seconds_count 5" in text

    def test_histogram_boundary_is_inclusive(self):
        # le is <=: an observation exactly on a bound lands in its bucket
        reg = metrics.Registry()
        h = reg.histogram("t_edge", buckets=(1.0,))
        h.observe(1.0)
        assert 't_edge_bucket{le="1"} 1' in reg.render_prometheus()

    def test_histogram_exemplar_keeps_last(self):
        reg = metrics.Registry()
        h = reg.histogram("t_ex_seconds")
        h.observe(0.1, trace_id="aaaaaaaaaaaaaaaa")
        h.observe(0.2, trace_id="bbbbbbbbbbbbbbbb")
        h.observe(0.3)                         # no id: exemplar unchanged
        assert h.exemplar == ("bbbbbbbbbbbbbbbb", 0.2)
        assert [t for t, _ in h.exemplars] \
            == ["aaaaaaaaaaaaaaaa", "bbbbbbbbbbbbbbbb"]
        snap = reg.snapshot()["t_ex_seconds"]["series"][0]
        assert snap["exemplar"] == {"trace_id": "bbbbbbbbbbbbbbbb",
                                    "value": 0.2}
        # exemplars never leak into the text exposition
        assert "bbbbbbbbbbbbbbbb" not in reg.render_prometheus()

    def test_get_or_create_is_idempotent(self):
        reg = metrics.Registry()
        a = reg.counter("t_same_total", "h", op="argmin")
        b = reg.counter("t_same_total", "h", op="argmin")
        assert a is b
        c = reg.counter("t_same_total", "h", op="topk")
        assert c is not a

    def test_kind_conflict_raises(self):
        reg = metrics.Registry()
        reg.counter("t_conflict")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("t_conflict")

    def test_bad_names_raise(self):
        reg = metrics.Registry()
        with pytest.raises(ValueError):
            reg.counter("0bad")
        with pytest.raises(ValueError):
            reg.counter("ok_total", **{"le": "x", "0bad": "y"})

    def test_disabled_registry_is_a_no_op(self):
        reg = metrics.Registry(enabled=False)
        c = reg.counter("t_off_total")
        h = reg.histogram("t_off_seconds")
        c.inc()
        h.observe(1.0)
        assert c.value == 0 and h.count == 0
        reg.enabled = True
        c.inc()
        assert c.value == 1

    def test_global_kill_switch(self):
        c = metrics.counter("t_kill_total")
        before = c.value
        metrics.set_enabled(False)
        try:
            assert not metrics.enabled()
            c.inc()
            assert c.value == before
        finally:
            metrics.set_enabled(True)
        c.inc()
        assert c.value == before + 1

    def test_label_escaping(self):
        reg = metrics.Registry()
        reg.counter("t_esc_total", reason='quo"te\nnl').inc()
        assert 'reason="quo\\"te\\nnl"' in reg.render_prometheus()

    def test_help_and_type_lines(self):
        reg = metrics.Registry()
        reg.counter("t_doc_total", "what it counts").inc()
        text = reg.render_prometheus()
        assert "# HELP t_doc_total what it counts" in text
        assert "# TYPE t_doc_total counter" in text

    def test_latency_ladder_shape(self):
        # fixed log-spaced ladder: 1 us .. 50 s, 3 buckets per decade
        assert metrics.LATENCY_BUCKETS_S[0] == 1e-6
        assert metrics.LATENCY_BUCKETS_S[-1] == 50.0
        assert 2.5e-3 in metrics.LATENCY_BUCKETS_S
        assert list(metrics.LATENCY_BUCKETS_S) \
            == sorted(metrics.LATENCY_BUCKETS_S)


# ---------------------------------------------------------------------------
# trace primitives
# ---------------------------------------------------------------------------

class TestTracePrimitives:
    def test_id_grammar(self):
        tid = trace.new_trace_id()
        assert trace.is_trace_id(tid)
        assert len(tid) == 16
        assert len({trace.new_trace_id() for _ in range(64)}) == 64

    def test_coerce(self):
        assert trace.coerce_trace_id(" ABCDEF0123456789 ") \
            == "abcdef0123456789"
        for bad in (None, "", "zzzz", "abc", 42, "abcdef012345678g"):
            assert trace.coerce_trace_id(bad) is None

    def test_spans_filter_and_noop(self):
        tid = trace.new_trace_id()
        trace.record_span("unit.op", tid, 0.01, op="argmin")
        assert trace.record_span("unit.op", None, 0.01) is None
        got = trace.recent_spans(trace_id=tid)
        assert len(got) == 1 and got[0].attrs == {"op": "argmin"}
        assert trace.recent_spans(trace_id=tid, name="other") == []

    def test_span_contextmanager(self):
        tid = trace.new_trace_id()
        with trace.span("unit.ctx", tid, stage="x"):
            pass
        sp = trace.recent_spans(trace_id=tid, name="unit.ctx")
        assert sp and sp[0].duration_s >= 0

    def test_kill_switch_silences_spans(self):
        tid = trace.new_trace_id()
        metrics.set_enabled(False)
        try:
            trace.record_span("unit.off", tid, 0.01)
        finally:
            metrics.set_enabled(True)
        assert trace.recent_spans(trace_id=tid) == []

    def test_slow_log_line(self):
        lines = []
        out = trace.slow_log({"event": "slow_request", "trace_id": "ab",
                              "duration_ms": 12.5}, sink=lines.append)
        assert lines == [out]
        assert json.loads(out) == {"event": "slow_request",
                                   "trace_id": "ab", "duration_ms": 12.5}


# ---------------------------------------------------------------------------
# serving paths: the trace propagation matrix + exposition parity
# ---------------------------------------------------------------------------

@pytest.mark.serve
class TestTracePropagationMatrix:
    def test_http_solo(self):
        lines = []
        with PredictionServer(port=0, slow_request_ms=0.0,
                              slow_log_sink=lines.append).start() as srv:
            client = PredictionClient(*srv.address, transport="http")
            tid = trace.new_trace_id()
            client.argmin(small_table(), "b200", trace_id=tid)
            assert_trace_visible(tid, lines)
            assert trace.recent_spans(trace_id=tid, name="serve.eval")
            client.close()

    def test_http_coalesced(self):
        lines = []
        with PredictionServer(port=0, coalesce_window_s=0.2,
                              slow_request_ms=0.0,
                              slow_log_sink=lines.append).start() as srv:
            client = PredictionClient(*srv.address, transport="http")
            tids = [trace.new_trace_id() for _ in range(3)]
            threads = [threading.Thread(
                target=client.argmin,
                args=(small_table(f"co{i}"), "b200"),
                kwargs={"trace_id": tid})
                for i, tid in enumerate(tids)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert not any(t.is_alive() for t in threads)
            for tid in tids:
                assert_trace_visible(tid, lines)
                assert trace.recent_spans(trace_id=tid, name="serve.eval")
            client.close()

    def test_binary_pipelined(self):
        lines = []
        with PredictionServer(port=0, binary_port=0,
                              coalesce_window_s=0.1,
                              slow_request_ms=0.0,
                              slow_log_sink=lines.append).start() as srv:
            client = PredictionClient(*srv.address, transport="binary")
            tids = [trace.new_trace_id() for _ in range(2)]
            client.argmin_many(
                [small_table("bp0"), small_table("bp1")], "b200",
                trace_ids=tids)
            for tid in tids:
                assert_trace_visible(tid, lines)
                assert trace.recent_spans(trace_id=tid, name="serve.eval")
            client.close()

    def test_binary_dedup_duplicate(self):
        lines = []
        with PredictionServer(port=0, binary_port=0,
                              coalesce_window_s=0.2,
                              slow_request_ms=0.0,
                              slow_log_sink=lines.append).start() as srv:
            client = PredictionClient(*srv.address, transport="binary")
            table = small_table("dup")
            tids = [trace.new_trace_id() for _ in range(2)]
            client.argmin_many([table, table], "b200", trace_ids=tids)
            assert srv.stats()["coalescer_deduped_requests"] >= 1
            for tid in tids:
                assert_trace_visible(tid, lines)
            # the duplicate kept its own identity through dedup
            dedup_spans = [
                s for tid in tids
                for s in trace.recent_spans(trace_id=tid,
                                            name="serve.eval")
                if s.attrs.get("dedup")]
            assert dedup_spans, "no serve.eval span marked dedup=True"
            client.close()

    def test_poison_isolated_rerun(self):
        lines = []
        with PredictionServer(port=0, engine=PoisonEngine(),
                              coalesce_window_s=0.15,
                              slow_request_ms=0.0,
                              slow_log_sink=lines.append).start() as srv:
            client = PredictionClient(*srv.address, max_retries=0)
            healthy_tids = [trace.new_trace_id() for _ in range(2)]
            poison_tid = trace.new_trace_id()
            failures = {}

            def run(key, table, tid):
                try:
                    client.argmin(table, "b200", trace_id=tid)
                except BaseException as e:     # noqa: BLE001
                    failures[key] = e

            threads = [threading.Thread(target=run, args=(i, t, tid))
                       for i, (t, tid) in enumerate(
                           [(small_table("h0"), healthy_tids[0]),
                            (small_table("h1"), healthy_tids[1]),
                            (poison_table(), poison_tid)])]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            assert not any(t.is_alive() for t in threads)
            assert set(failures) == {2}
            assert srv.stats()["coalescer_isolated_failures"] >= 1
            # the healthy batchmates kept their ids through the solo
            # re-run after the poisoned fused batch failed
            for tid in healthy_tids:
                assert_trace_visible(tid, lines)
                solo = [s for s in trace.recent_spans(
                    trace_id=tid, name="serve.eval")
                    if s.attrs.get("solo")]
                assert solo, f"no solo re-run span for {tid}"
            client.close()


@pytest.mark.serve
class TestMetricsEndpoints:
    def test_http_and_binary_serve_the_same_snapshot(self):
        with PredictionServer(port=0, binary_port=0).start() as srv:
            http_c = PredictionClient(*srv.address, transport="http")
            bin_c = PredictionClient(*srv.address, transport="binary")
            http_c.argmin(small_table("m0"), "b200")
            via_http = http_c.metrics_text()
            via_bin = bin_c.metrics_text()

            def families(text):
                return {l for l in text.splitlines()
                        if l.startswith("# TYPE ")}

            def series(text, name):
                return sorted(l for l in text.splitlines()
                              if l.startswith(name))

            assert families(via_http) == families(via_bin)
            # sweep counters were quiescent between the two fetches, so
            # the request-counter samples agree exactly
            assert series(via_http, "repro_serve_requests_total") \
                == series(via_bin, "repro_serve_requests_total")
            http_c.close()
            bin_c.close()

    def test_metrics_endpoint_is_plain_prometheus_text(self):
        import http.client
        with PredictionServer(port=0).start() as srv:
            host, port = srv.address
            conn = http.client.HTTPConnection(host, port, timeout=10)
            conn.request("GET", "/v1/metrics")
            resp = conn.getresponse()
            body = resp.read().decode("utf-8")
            assert resp.status == 200
            assert resp.getheader("Content-Type").startswith("text/plain")
            assert "# TYPE repro_serve_queue_depth gauge" in body
            conn.close()

    def test_stats_snapshot_is_consistent(self):
        # satellite 1: stats() reads under one lock — dedup/shed counts
        # can never exceed the requests they derive from, even torn reads
        engine = sweep.SweepEngine()
        co = Coalescer(engine, window_s=0.02)
        try:
            stop = threading.Event()
            bad = []

            def reader():
                while not stop.is_set():
                    s = co.stats_snapshot()
                    if s["deduped_requests"] > s["requests"] or \
                            s["coalesced_requests"] > s["requests"]:
                        bad.append(dict(s))

            r = threading.Thread(target=reader)
            r.start()
            table = small_table("snap")
            threads = [threading.Thread(
                target=co.submit,
                args=("argmin", table, B200, None))
                for _ in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30.0)
            stop.set()
            r.join(timeout=5.0)
            assert not bad, f"torn stats read: {bad[:3]}"
        finally:
            co.close()


# ---------------------------------------------------------------------------
# the metric-name contract
# ---------------------------------------------------------------------------

#: shipped family names — APPEND-ONLY.  Extending this list is fine;
#: renaming or removing an entry breaks scrapers and dashboards (see
#: serve/README.md "Observability").
EXPECTED_FAMILIES = [
    "repro_client_attempt_seconds",
    "repro_client_attempts_total",
    "repro_client_backoff_seconds_total",
    "repro_client_breaker_open_total",
    "repro_client_retries_total",
    "repro_pool_shard_seconds",
    "repro_pool_straggler_redispatch_total",
    "repro_serve_binary_connections_total",
    "repro_serve_binary_inflight",
    "repro_serve_dedup_rows_saved_total",
    "repro_serve_deduped_requests_total",
    "repro_serve_fused_batch_cost",
    "repro_serve_fused_batch_requests",
    "repro_serve_fused_batch_rows",
    "repro_serve_isolated_failures_total",
    "repro_serve_queue_depth",
    "repro_serve_request_seconds",
    "repro_serve_requests_total",
    "repro_serve_shed_total",
    "repro_serve_slow_requests_total",
    "repro_serve_stage_seconds",
    "repro_sweep_predict_table_seconds",
    "repro_sweep_rows_total",
]


@pytest.mark.serve
def test_metric_name_contract():
    # touching every instrumented layer registers every family
    with PredictionServer(port=0, binary_port=0).start() as srv:
        client = PredictionClient(*srv.address)
        client.argmin(small_table("contract"), "b200")
        client.close()
    missing = set(EXPECTED_FAMILIES) - set(metrics.REGISTRY.family_names())
    assert not missing, \
        f"shipped metric families disappeared (breaking change): {missing}"

"""Validation of EXPERIMENTS.md against the paper's own published claims.

Every assertion here traces to a specific paper table/section (cited
inline).  Ground-truth provenance rules are in core/suites/__init__.py.
"""
import pytest

from repro.core import blackwell, calibrate, cdna3, hardware, predict, \
    roofline, validate
from repro.core.suites import b200_microbench as b200_suite
from repro.core.suites import mi300a_microbench as mi300a_suite
from repro.core.suites import ports, rodinia, spechpc, split
from repro.core import segments as seg_mod


class TestTableVI:
    """Table VI: microbenchmark validation MAE per platform."""

    def test_b200_model_mae(self):
        rep = validate.validate_suite(hardware.B200,
                                      *split(b200_suite.suite()))
        assert rep.n == 21
        # paper: 1.33% (Table VI) / 1.31% (§V-B(c))
        assert rep.model_mae < 2.5, rep.model_mae

    def test_b200_roofline_error_exceeds_94pct(self):
        rep = validate.validate_suite(hardware.B200,
                                      *split(b200_suite.suite()))
        assert rep.roofline_mae > 94.0, rep.roofline_mae  # paper: 96.1%

    def test_mi300a_uncalibrated_5_to_8pct(self):
        rep = validate.validate_suite(hardware.MI300A,
                                      *split(mi300a_suite.suite()))
        assert rep.n == 27
        # paper Obs. 1: "roughly 5-8% MAE" uncalibrated
        assert 4.0 < rep.model_mae < 9.0, rep.model_mae

    def test_mi300a_calibrated_near_zero(self):
        ws, meas = split(mi300a_suite.suite())

        def pf(w):
            return predict.predict(w, hardware.MI300A)
        cal = calibrate.fit_per_case(ws, meas, pf)
        cal.per_case = {k: round(v, 3) for k, v in cal.per_case.items()}
        rep = validate.validate_suite(hardware.MI300A, ws, meas,
                                      calibration=cal)
        # paper: ~0.09% calibrated ceiling accuracy
        assert rep.model_mae < 0.15, rep.model_mae

    def test_mi300a_roofline_error(self):
        rep = validate.validate_suite(hardware.MI300A,
                                      *split(mi300a_suite.suite()))
        assert rep.roofline_mae > 94.0, rep.roofline_mae  # paper: 99.6%

    def test_h200_port_param_swap_only(self):
        rep = validate.validate_suite(hardware.H200,
                                      *split(ports.h200_suite()))
        assert rep.n == 21
        assert rep.model_mae < 12.0, rep.model_mae      # paper: 9.57%
        assert rep.roofline_mae > 90.0, rep.roofline_mae  # paper: 94.5%

    def test_mi250x_port(self):
        rep = validate.validate_suite(hardware.MI250X,
                                      *split(ports.mi250x_suite()))
        assert rep.n == 19
        assert rep.model_mae < 6.0, rep.model_mae       # paper: 4.69%
        assert rep.roofline_mae > 94.0, rep.roofline_mae  # paper: 97.9%

    def test_model_beats_roofline_everywhere(self):
        """The paper's core comparative claim, per platform."""
        suites = [
            (hardware.B200, b200_suite.suite()),
            (hardware.MI300A, mi300a_suite.suite()),
            (hardware.H200, ports.h200_suite()),
            (hardware.MI250X, ports.mi250x_suite()),
        ]
        for hw, ents in suites:
            rep = validate.validate_suite(hw, *split(ents))
            assert rep.model_mae < rep.roofline_mae / 5.0, hw.name


class TestWorkedExamples:
    """§IV-D worked example and §V-B(c) point validations."""

    def test_gemm_16384_prediction(self):
        """GEMM M=N=K=16384, tile 128x128x32: predicted 4.17 ms vs
        measured 4.10 ms (1.8% error)."""
        w = [x for x in b200_suite.workloads()
             if x.name == "gemm_fp8_16384"][0]
        pred_ms = predict.predict(w, hardware.B200).total * 1e3
        assert abs(pred_ms - 4.17) / 4.17 < 0.03, pred_ms
        err = abs(pred_ms - 4.10) / 4.10
        assert err < 0.05, err     # paper: 1.8%

    def test_two_sm_speedup(self):
        """§V-B(c): predicted 1.30x vs measured 1.28x, within 2%."""
        s = blackwell.two_sm_speedup(b200_suite.two_sm_case(),
                                     hardware.B200)
        assert abs(s - 1.30) < 0.02, s
        assert abs(s - 1.28) / 1.28 < 0.04   # "within 2%" of measured

    def test_two_cta_traffic_reduction(self):
        """§IV-A4: up to ~1.33x traffic reduction for square tiles."""
        from repro.core.workload import TileConfig
        r = blackwell.two_sm_traffic_reduction(TileConfig(128, 128, 32))
        assert abs(r - 4.0 / 3.0) < 1e-9
        # non-square tiles reduce less
        r2 = blackwell.two_sm_traffic_reduction(TileConfig(256, 64, 32))
        assert r2 < r

    def test_mi250x_dgemm_point(self):
        """§V-B(e): FP64 GEMM 16384^3 predicted 0.283 s = measured."""
        w = [x for x in ports.mi250x_workloads()
             if x.name == "dgemm_16384"][0]
        t = predict.predict(w, hardware.MI250X).total
        assert abs(t - 0.283) / 0.283 < 0.02, t

    def test_tile_ordering_16_faster_than_8(self):
        """Eq. 14 'yields the correct ordering (16x16 faster than 8x8)'."""
        cases = {w.name: w for w in mi300a_suite.occupancy_tile_cases()}
        t8 = cdna3.occupancy_tile_predict(cases["occ_gemm_tile8"],
                                          hardware.MI300A).total
        t16 = cdna3.occupancy_tile_predict(cases["occ_gemm_tile16"],
                                           hardware.MI300A).total
        assert t16 < t8

    def test_adaptive_tile_selection_returns_min(self):
        from repro.core.workload import TileConfig, gemm_workload
        base = gemm_workload("sel", 1024, 1024, 1024, precision="fp32")
        tiles = [TileConfig(t, t, 16) for t in (8, 16, 32)]
        best, costs = cdna3.adaptive_tile_selection(
            base, hardware.MI300A, tiles)
        assert costs[f"{best.bm}x{best.bn}x{best.bk}"] == min(costs.values())


class TestRodinia:
    """Table X / Fig. 4 and the streamcluster flagship case."""

    @pytest.mark.parametrize("platform", ["b200", "mi300a"])
    def test_per_benchmark_mae(self, platform):
        hw = hardware.get(platform)
        for app in rodinia.apps(platform):
            pred = seg_mod.predict_app(app.name, app.segments, hw)
            err = pred.mae_vs(app.measured_s)
            assert abs(err - app.paper_mae_pct) < max(
                1.5, 0.15 * app.paper_mae_pct), (app.name, err)

    def test_streamcluster_roofline_catastrophe(self):
        """Paper §V-C: measured 157 ms, model ~157 ms (0.03%), naive
        roofline ~0.005 ms (~100% error)."""
        hw = hardware.MI300A
        app = [a for a in rodinia.apps("mi300a")
               if a.name == "streamcluster_1M"][0]
        model_t = seg_mod.predict_app(app.name, app.segments, hw).total
        assert abs(model_t - 0.157) / 0.157 < 0.01, model_t
        # naive roofline: total traffic only, no launches
        seg = app.segments[0]
        roof_t = roofline.predict(seg.workload, hw).total * seg.n_exec
        assert roof_t < 0.157 * 0.05          # catastrophic underprediction

    def test_irregular_worse_than_regular(self):
        """Obs. 2: accuracy boundary = workload regularity."""
        maes = {a.name: a.paper_mae_pct for a in rodinia.apps("mi300a")}
        assert maes["bfs_1M"] > maes["pathfinder_1000"]
        assert maes["bfs_1M"] > maes["srad_502"]


class TestSPEChpc:
    """Table XI / XII and the characterization-gap finding (Obs. 3)."""

    @pytest.mark.parametrize("platform", ["b200", "mi300a"])
    def test_profiler_characterized_mae(self, platform):
        hw = hardware.get(platform)
        for app in spechpc.apps(platform):
            pred = seg_mod.predict_app(app.name, app.segments, hw)
            err = pred.mae_vs(app.measured_s)
            assert abs(err - app.paper_mae_pct) < max(
                1.5, 0.15 * app.paper_mae_pct), (app.name, err)

    def test_first_principles_characterization_fails(self):
        """Obs. 3: same model, first-principles inputs -> ~92.5% MAE;
        the failure is in the INPUTS, not the model."""
        hw = hardware.MI300A
        fp_segs = spechpc.first_principles_segments()
        errs = []
        for app in spechpc.apps("mi300a"):
            pred = seg_mod.predict_app(app.name,
                                       tuple(fp_segs[app.name]), hw)
            errs.append(pred.mae_vs(app.measured_s))
        fp_mae = sum(errs) / len(errs)
        assert fp_mae > 50.0, fp_mae   # paper: 92.5%

    def test_flop_ratio_extremes(self):
        """Table XII: miniswp ratio 0.001 (1000x gap), pot3d 0.961."""
        r = spechpc.flop_ratios()
        assert r["521.miniswp_t"] == pytest.approx(0.001)
        assert r["528.pot3d_t"] > 0.9
        assert min(r.values()) < 0.01 < 1.0 < max(r.values())


class TestArchitecturalInsights:
    """Obs. 5: AI thresholds and Infinity Cache advantage."""

    def test_ai_threshold_mi300a_higher_than_b200(self):
        """Compute-bound threshold ~45% higher on MI300A (AI>23 vs >16)."""
        ai_b200 = roofline.ridge_intensity(hardware.B200, "fp16")
        ai_mi300a = roofline.ridge_intensity(hardware.MI300A, "fp8")
        # ridge-point comparison at each platform's marquee precision
        assert ai_mi300a > ai_b200 * 0.8

    def test_infinity_cache_bandwidth_advantage(self):
        """256 MB LLC delivers 1.5-2x over HBM when working sets fit."""
        from repro.core.cache import effective_bandwidth_llc
        hw = hardware.MI300A
        bw_resident = effective_bandwidth_llc(100e6, hw)   # 100 MB fits
        bw_streaming = effective_bandwidth_llc(2e9, hw)    # 2 GB spills
        assert bw_resident / bw_streaming > 1.5
        assert bw_resident / hw.hbm_sustained_bw > 1.5

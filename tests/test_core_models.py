"""Unit tests for the analytical-model equations (hand-computed values)."""
import math

import pytest

from repro.core import blackwell, cache, cdna3, collectives, generic, \
    hardware, predict, roofline, tpu
from repro.core.workload import GemmShape, HostPhase, Segment, TileConfig, \
    TimeBreakdown, Workload, gemm_workload, streaming_workload


HW_B = hardware.B200
HW_M = hardware.MI300A
HW_T = hardware.TPU_V5E


class TestCacheModels:
    def test_hllc_piecewise_table_iii(self):
        # W < 205 MB -> 1.0
        assert cache.llc_hit_rate(100e6, HW_M) == 1.0
        assert cache.llc_hit_rate(204.9e6, HW_M) == 1.0
        # transition zone: strictly between 0 and 1, decreasing
        h220 = cache.llc_hit_rate(220e6, HW_M)
        h250 = cache.llc_hit_rate(250e6, HW_M)
        assert 0.0 < h250 < h220 < 1.0
        # streaming: (256/W)^beta
        h512 = cache.llc_hit_rate(512e6, HW_M)
        expected = (256.0 / 512.0) ** HW_M.llc_transition_beta
        assert h512 == pytest.approx(expected)

    def test_hllc_boundary_behavior(self):
        eps = 1e3
        # continuous at the 205 MB resident/transition boundary
        lo = cache.llc_hit_rate(205e6 - eps, HW_M)
        hi = cache.llc_hit_rate(205e6 + eps, HW_M)
        assert abs(lo - hi) < 0.01
        # NOTE: the paper's Table III is DISCONTINUOUS at W = 256 MB as
        # published (transition branch -> 0, streaming branch -> 1).  We
        # implement it faithfully and document the jump (DESIGN.md §8).
        lo = cache.llc_hit_rate(256e6 - eps, HW_M)
        hi = cache.llc_hit_rate(256e6 + eps, HW_M)
        assert lo < 0.01 and hi > 0.99   # the published discontinuity

    def test_effective_bandwidth_mix(self):
        # fully resident -> LLC bandwidth; fully streaming -> ~HBM
        bw_res = cache.effective_bandwidth_llc(10e6, HW_M)
        assert bw_res == pytest.approx(HW_M.cache_levels[-1].bandwidth)
        bw_str = cache.effective_bandwidth_llc(100e9, HW_M)
        assert bw_str < 1.2 * HW_M.hbm_sustained_bw

    def test_eq16_blend_bounds(self):
        # B_eff in [sustained, peak], monotonically decreasing in W
        for w in (1e3, 1e6, 1e9, 1e12):
            b = cache.working_set_blend(w, HW_B)
            assert HW_B.hbm_sustained_bw <= b <= HW_B.hbm_peak_bw
        assert cache.working_set_blend(1e6, HW_B) > \
            cache.working_set_blend(1e9, HW_B)

    def test_eq16_disabled_with_w0_leq_0(self):
        hw = HW_B.with_updates(working_set_scale_bytes=0.0)
        assert cache.working_set_blend(1e3, hw) == hw.hbm_sustained_bw

    def test_eq10_latency_walk_hand_computed(self):
        # single L1 access: h1=1 -> N * L1_cycles / clock
        t = cache.hierarchy_latency_walk(1000, {"l1": 1.0}, HW_M)
        expected = 1000 * 5 / (HW_M.clock_ghz * 1e9)
        assert t == pytest.approx(expected)
        # all-miss -> HBM latency
        t = cache.hierarchy_latency_walk(
            1, {"l1": 0.0, "l2": 0.0, "llc": 0.0}, HW_M)
        assert t == pytest.approx(HW_M.cycles_to_seconds(400))

    def test_eq10_rejects_invalid_hit_rates(self):
        with pytest.raises(ValueError):
            cache.hierarchy_latency_walk(1, {"l1": 1.5}, HW_M)
        with pytest.raises(ValueError):
            cache.hierarchy_latency_walk(1, {"l2": -0.1}, HW_M)


class TestRoofline:
    def test_max_form(self):
        w = Workload(name="x", wclass="compute", flops=1e12, bytes=1e9,
                     precision="fp16", matrix=True)
        t = roofline.predict(w, HW_B)
        t_c = 1e12 / HW_B.peak_flops("fp16")
        t_m = 1e9 / HW_B.hbm_peak_bw
        assert t.total == pytest.approx(max(t_c, t_m))

    def test_no_launch_no_cache_terms(self):
        """Naive roofline must ignore launch latency entirely."""
        w = streaming_workload("tiny", 1e3)
        t = roofline.predict(w, HW_B).total
        assert t < 1e-9  # far below any launch latency


class TestBlackwellStages:
    def test_eq2_tmem_per_tile(self):
        tile = TileConfig(128, 128, 32)
        t = blackwell.tmem_time_per_tile(tile, HW_B)
        d = 128 * 128 * 4
        expected = (d / (HW_B.accum_read_bw / HW_B.num_sms)
                    + HW_B.cycles_to_seconds(HW_B.mma_latency_cycles)
                    + d / (HW_B.accum_write_bw / HW_B.num_sms))
        assert t == pytest.approx(expected)

    def test_tmem_spill_penalty(self):
        big = TileConfig(512, 512, 32)    # 1 MB accum > 256 KB TMEM
        small = TileConfig(128, 128, 32)
        per_byte_big = blackwell.tmem_time_per_tile(big, HW_B) / (512 * 512)
        per_byte_small = blackwell.tmem_time_per_tile(small, HW_B) \
            / (128 * 128)
        assert per_byte_big > 1.5 * per_byte_small

    def test_eq4_tma_latency_floor(self):
        w = gemm_workload("g", 256, 256, 256, precision="fp16")
        t = blackwell.tma_time_per_step(w, HW_B)
        assert t >= HW_B.cycles_to_seconds(HW_B.tma_latency_cycles)

    def test_tma_multicast_reduces_time(self):
        w1 = gemm_workload("g", 4096, 4096, 4096, precision="fp16")
        w4 = w1.replace(tma_participants=4)
        assert blackwell.tma_time_per_step(w4, HW_B) < \
            blackwell.tma_time_per_step(w1, HW_B)

    def test_eq5_decompression(self):
        w = Workload(name="d", wclass="memory", flops=0, bytes=1e9,
                     compressed_bytes=0.5e9, compression_ratio=2.0)
        t = blackwell.decompression_time(w, HW_B)
        assert t > 0
        # incompressible data decompresses slower per uncompressed byte
        w2 = w.replace(compression_ratio=1.0)
        assert blackwell.decompression_time(w2, HW_B) > 0

    def test_eq7_overlap_hides_io(self):
        hw_overlap = HW_B.with_updates(pipeline_overlap_alpha=0.95)
        hw_serial = HW_B.with_updates(pipeline_overlap_alpha=0.0)
        w = gemm_workload("g", 2048, 2048, 2048, precision="fp16")
        t_o = blackwell.predict(w, hw_overlap).total
        t_s = blackwell.predict(w, hw_serial).total
        assert t_o < t_s

    def test_stage_serialization_exceeds_roofline(self):
        """The paper's core structural point: stage model >= naive
        roofline time (serialized stages + overheads that max() hides)."""
        for n in (512, 2048, 8192):
            w = gemm_workload(f"g{n}", n, n, n, precision="fp16")
            t_stage = blackwell.predict(w, HW_B).total
            t_roof = roofline.predict(w, HW_B).total
            assert t_stage > t_roof

    def test_concurrent_stream_term(self):
        w = gemm_workload("g", 1024, 1024, 1024, precision="fp16")
        t1 = blackwell.predict(w, HW_B).total
        t2 = blackwell.predict(w.replace(concurrent_kernels=3), HW_B).total
        assert t2 == pytest.approx(t1 + 2 * HW_B.tau_interference_s)

    def test_misroute_raises(self):
        w = streaming_workload("v", 1e6)
        with pytest.raises(ValueError):
            blackwell.predict(w, HW_M)


class TestCDNA3:
    def test_eq9_overlap_bounds(self):
        assert cdna3.overlap_factor(1, 1.0, 1.0) == 0.0
        assert cdna3.overlap_factor(32, 1.0, 1.0) == 1.0
        assert cdna3.overlap_factor(4, 0.0, 1.0) == 0.0
        assert 0.0 <= cdna3.overlap_factor(8, 0.1, 1.0) <= 1.0

    def test_vgpr_occupancy_formula(self):
        # min(32, floor(65536 / VGPR_per_wf)); VGPR_per_wf = vgpr*64
        assert cdna3.vgpr_limited_occupancy(32, HW_M) == 32
        assert cdna3.vgpr_limited_occupancy(64, HW_M) == 16
        assert cdna3.vgpr_limited_occupancy(256, HW_M) == 4
        assert cdna3.vgpr_limited_occupancy(100000, HW_M) == 1

    def test_mwp_cwp_caps(self):
        assert cdna3.vgpr_limited_occupancy(32, HW_M, mwp=8) == 8
        assert cdna3.vgpr_limited_occupancy(32, HW_M, cwp=4) == 4

    def test_eq12_overlap_denominator(self):
        assert cdna3.step_time(1.0, 1.0, 1.0) == pytest.approx(1.0)
        assert cdna3.step_time(1.0, 1.0, 0.0) == pytest.approx(2.0)

    def test_eq13_assembly_terms(self):
        w = streaming_workload("v", 1e6)
        out = cdna3.predict(w, HW_M)
        assert out.total >= (HW_M.launch_latency_s
                             + HW_M.coherence_latency_s
                             + HW_M.cross_xcd_latency_s)

    def test_occupancy_beats_no_occupancy(self):
        """More resident wavefronts -> more overlap -> faster."""
        w = streaming_workload("v", 1e8).replace(
            flops=1e8 * 2, vgpr_per_workitem=32)
        w_low = w.replace(vgpr_per_workitem=100000)
        t_hi = cdna3.predict(w, HW_M).total
        t_lo = cdna3.predict(w_low, HW_M).total
        assert t_hi <= t_lo

    def test_fusion_saves_traffic(self):
        a = streaming_workload("a", 1e8)
        b = streaming_workload("b", 1e8)
        t_fused = cdna3.fused_predict([a, b], HW_M).total
        t_sep = cdna3.predict(a, HW_M).total + cdna3.predict(b, HW_M).total
        assert t_fused < t_sep

    def test_multi_gpu_interference(self):
        w = streaming_workload("v", 1e6)
        t1 = cdna3.predict(w, HW_M).total
        t2 = cdna3.predict(w.replace(num_devices=2), HW_M).total
        assert t2 == pytest.approx(t1 + HW_M.tau_interference_gpu_s)


class TestGenericPath:
    def test_eq15_memcpy(self):
        p = HostPhase(kind="h2d", bytes=45e9, count=1)
        t = generic.host_phase_time(p, HW_M)
        assert t == pytest.approx(1.0 + HW_M.tau_memcpy_s)

    def test_sync_points(self):
        p = HostPhase(kind="sync", count=10)
        assert generic.host_phase_time(p, HW_M) == \
            pytest.approx(10 * HW_M.tau_sync_s)

    def test_multi_kernel_launch_accounting(self):
        w = streaming_workload("v", 1e6)
        s1 = Segment(workload=w, n_exec=1)
        s2 = Segment(workload=w, n_exec=1, extra_kernels=3)
        assert generic.segment_overhead(s2, HW_M) - \
            generic.segment_overhead(s1, HW_M) == \
            pytest.approx(3 * HW_M.launch_latency_s)

    def test_class_scales_applied(self):
        hw = HW_M.with_updates(class_scales={"memory": 2.0, "compute": 1.0,
                                             "balanced": 1.0, "stencil": 1.0})
        w = streaming_workload("v", 1e8)
        t2 = generic.predict(w, hw).total
        t1 = generic.predict(w, HW_M).total
        assert t2 > t1


class TestTPUModel:
    def test_mxu_alignment_penalty(self):
        w_ok = gemm_workload("a", 1024, 1024, 1024, precision="bf16")
        w_bad = gemm_workload("b", 1000, 1000, 1000, precision="bf16")
        assert tpu.mxu_utilization(w_ok, HW_T) > \
            tpu.mxu_utilization(w_bad, HW_T)

    def test_collective_stage_exposed(self):
        mesh = collectives.MeshSpec(axes=(("data", 16), ("model", 16)))
        w = gemm_workload("g", 8192, 8192, 8192, precision="bf16")
        big_coll = [("all-reduce", 1e10, "data")]
        out = tpu.predict(w, HW_T, mesh=mesh, collective_ops=big_coll)
        assert out.collective > 0
        assert out.total > tpu.predict(w, HW_T).total

    def test_report_terms_formulas(self):
        r = tpu.RooflineReport(name="x", num_chips=256, hlo_flops=1e18,
                               hlo_bytes=1e15, collective_bytes=1e13,
                               model_flops=8e17)
        assert r.compute_term == pytest.approx(1e18 / (256 * 197e12))
        assert r.memory_term == pytest.approx(1e15 / (256 * 819e9))
        assert r.collective_term == pytest.approx(1e13 / (256 * 50e9))
        assert r.useful_flops_ratio == pytest.approx(0.8)
        assert r.dominant in ("compute", "memory", "collective")


class TestCollectives:
    MESH = collectives.MeshSpec(axes=(("pod", 2), ("data", 16),
                                      ("model", 16)))

    def test_ring_factors(self):
        n = 16
        b = 1e9
        bw = collectives.axis_bandwidth(self.MESH, "data", HW_T)
        ag = collectives.collective_time("all-gather", b, "data",
                                         self.MESH, HW_T)
        assert ag == pytest.approx((n - 1) * b / bw)
        ar = collectives.collective_time("all-reduce", b, "data",
                                         self.MESH, HW_T)
        rs = collectives.collective_time("reduce-scatter", b, "data",
                                         self.MESH, HW_T)
        assert ar == pytest.approx(2 * rs)

    def test_pod_axis_slower(self):
        t_pod = collectives.collective_time("collective-permute", 1e9,
                                            "pod", self.MESH, HW_T)
        t_ici = collectives.collective_time("collective-permute", 1e9,
                                            "data", self.MESH, HW_T)
        assert t_pod > t_ici

    def test_trivial_axis_free(self):
        mesh = collectives.MeshSpec(axes=(("data", 1),))
        assert collectives.collective_time("all-reduce", 1e9, "data",
                                           mesh, HW_T) == 0.0

    def test_unknown_op_raises(self):
        with pytest.raises(ValueError):
            collectives.collective_time("gossip", 1e9, "data",
                                        self.MESH, HW_T)


class TestPortability:
    """Obs. 6: parameter-file portability — same formulas, new values."""

    def test_with_updates_changes_only_values(self):
        hw = HW_B.with_updates(hbm_peak_bw=4.8e12, hbm_capacity=141e9)
        assert hw.hbm_peak_bw == 4.8e12
        assert hw.num_sms == HW_B.num_sms   # untouched fields preserved
        assert HW_B.hbm_peak_bw == 8.0e12   # original immutable

    def test_registry_roundtrip(self):
        for name in ("b200", "mi300a", "h200", "mi250x", "tpu_v5e"):
            assert hardware.get(name).name == name
        with pytest.raises(KeyError):
            hardware.get("rubin")

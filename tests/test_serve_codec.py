"""Wire-codec tests: round-trip fidelity and malformed-input hardening.

Round-trips must preserve the engine-visible identity of every payload:
``content_token()`` (including NaN column payloads bit-for-bit), per-row
names and hit-rate dicts, the read-only/frozen column contract, lattice
plans chunk-for-chunk, and ``SweepWinner`` floats exactly.  Malformed
buffers — truncations at every byte prefix, bad magic, future versions,
out-of-range section tables, garbage JSON — must raise ``WireFormatError``
rather than the IndexError/struct.error soup a server loop would crash
on.  Also pins the vocab-canonicalization bugfix: semantically identical
tables built with different precision/wclass insertion orders share one
content token (and therefore one memo-cache entry).

Property-style sweeps use ``hypothesis`` when installed and fall back to
a seeded ``numpy.random`` sweep otherwise (the container has no
hypothesis).
"""
import json

import numpy as np
import pytest

from repro.core import hardware, sweep
from repro.core.workload import LatticeSpec, TileConfig, Workload, \
    WorkloadTable, gemm_workload, streaming_workload
from repro.serve import codec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

B200 = hardware.B200
TILES = [TileConfig(bm, bn, bk) for bm in (64, 128, 256)
         for bn in (64, 128) for bk in (16, 32)]


def gemm_base(name="g", m=4096):
    return gemm_workload(name, m, 4096, 4096, precision="fp16")


def sample_tables():
    """Tables exercising every metadata shape: per-row names, shared
    names + offsets, hit-rate dicts, merged vocabularies, zero rows."""
    ws = [gemm_base("a"), streaming_workload("b", 1e9),
          Workload(name="hr", wclass="memory", flops=1e9, bytes=1e9,
                   hit_rates={"h_l2": 0.7, "h_l1": 0.4})]
    yield WorkloadTable.from_workloads(ws)                 # names + hr
    yield WorkloadTable.tile_lattice(gemm_base(), TILES)   # shared name
    lat = LatticeSpec.cartesian(gemm_base(), k_tiles=[4, 8, 16, 32],
                                precision=["fp16", "fp8"])
    yield lat.chunk(3, 7)                                  # name_offset
    yield WorkloadTable.concat(
        [WorkloadTable.from_workloads([w]) for w in ws])   # merged vocab
    yield WorkloadTable.tile_lattice(gemm_base(), TILES)._slice(0, 0)


def table_equal(a: WorkloadTable, b: WorkloadTable) -> bool:
    return (a.content_token() == b.content_token()
            and a.cols.tobytes() == b.cols.tobytes()
            and a.precision_vocab == b.precision_vocab
            and a.wclass_vocab == b.wclass_vocab
            and list(a.precision_codes) == list(b.precision_codes)
            and list(a.wclass_codes) == list(b.wclass_codes)
            and a.hit_rates == b.hit_rates
            and [a.name(i) for i in range(len(a))]
            == [b.name(i) for i in range(len(b))])


class TestTableRoundTrip:
    def test_samples_round_trip(self):
        for table in sample_tables():
            out = codec.decode_table(codec.encode_table(table))
            assert table_equal(table, out)

    def test_decoded_arrays_are_frozen_views(self):
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        out = codec.decode_table(codec.encode_table(table))
        assert not out.cols.flags.writeable
        assert not out.cols.flags.owndata          # zero-copy view
        with pytest.raises(ValueError):
            out.cols[0, 0] = 1.0

    def test_writable_buffer_decode_is_still_frozen(self):
        # bytearray/memoryview payloads (reusable receive buffers) give
        # numpy WRITABLE zero-copy views; the decoded table must freeze
        # cols AND the code arrays or a mutation would leave the cached
        # content_token stale and poison the engine's memo cache
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        out = codec.decode_table(bytearray(codec.encode_table(table)))
        for arr in (out.cols, out.precision_codes, out.wclass_codes):
            assert not arr.flags.writeable
        with pytest.raises(ValueError):
            out.precision_codes[0] = 0
        assert out.content_token() == table.content_token()

    def test_nan_payloads_survive_bit_for_bit(self):
        # a quiet NaN with a distinctive payload must not be canonicalized
        # by the wire (raw column bytes travel untouched)
        cols = np.array(WorkloadTable.tile_lattice(gemm_base(),
                                                   TILES).cols)
        cols[0, 0] = np.float64(float("nan"))
        weird = np.frombuffer(np.uint64(0x7FF8_0000_DEAD_BEEF).tobytes(),
                              dtype=np.float64)[0]
        cols[1, 1] = weird
        n = cols.shape[0]
        table = WorkloadTable(cols, np.zeros(n, dtype=np.intp), ("fp16",),
                              np.zeros(n, dtype=np.intp), ("compute",))
        out = codec.decode_table(codec.encode_table(table))
        assert out.cols.tobytes() == table.cols.tobytes()
        assert np.isnan(out.cols[0, 0]) and np.isnan(out.cols[1, 1])
        assert out.content_token() == table.content_token()

    def test_predictions_match_after_round_trip(self):
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        out = codec.decode_table(codec.encode_table(table))
        eng = sweep.SweepEngine(use_cache=False)
        a = sweep.argmin_table(table, B200, engine=eng)
        b = sweep.argmin_table(out, B200, engine=eng)
        assert a.index == b.index and a.total == b.total
        assert a.breakdown == b.breakdown

    def test_random_tables_round_trip(self):
        rng = np.random.default_rng(7)
        for _ in range(25):
            n = int(rng.integers(0, 40))
            cols = rng.standard_normal((n, 26)) * rng.choice(
                [1.0, 1e12, 1e-12])
            cols[rng.random((n, 26)) < 0.05] = np.nan
            pv = tuple(f"p{i}" for i in range(int(rng.integers(1, 4))))
            wv = tuple(("memory", "compute", "balanced")
                       [:int(rng.integers(1, 4))])
            table = WorkloadTable(
                cols, rng.integers(0, len(pv), n).astype(np.intp), pv,
                rng.integers(0, len(wv), n).astype(np.intp), wv,
                names=tuple(f"w{i}" for i in range(n)) if n and
                rng.random() < 0.5 else None,
                name_offset=int(rng.integers(0, 100)))
            out = codec.decode_table(codec.encode_table(table))
            assert table_equal(table, out)


class TestSpecRoundTrip:
    def specs(self):
        base = gemm_base()
        yield LatticeSpec.cartesian(
            base, k_tiles=[4.0, 8.0, 16.0], num_ctas=[64, 128],
            precision=["fp16", "fp8"], tile=TILES[:3])
        yield LatticeSpec.tile_lattice(base, TILES)
        yield LatticeSpec.from_table(
            WorkloadTable.from_workloads([base,
                                          streaming_workload("s", 1e8)]))
        yield LatticeSpec.concat([
            LatticeSpec.tile_lattice(base, TILES[:4]),
            LatticeSpec.from_table(WorkloadTable.tile_lattice(base,
                                                              TILES[:2])),
            LatticeSpec.cartesian(base, k_tiles=[4, 8])])

    def test_specs_round_trip_chunk_for_chunk(self):
        for spec in self.specs():
            out = codec.decode_spec(codec.encode_spec(spec))
            assert out.n_rows == spec.n_rows
            for lo in range(0, spec.n_rows, 5):
                hi = min(lo + 5, spec.n_rows)
                a, b = spec.chunk(lo, hi), out.chunk(lo, hi)
                assert a.cols.tobytes() == b.cols.tobytes()
                assert a.content_token() == b.content_token()
                assert [a.name(i) for i in range(len(a))] == \
                    [b.name(i) for i in range(len(b))]

    def test_streamed_winner_matches_after_round_trip(self):
        spec = LatticeSpec.cartesian(gemm_base(),
                                     k_tiles=[4 + i for i in range(32)],
                                     num_ctas=[32 + 8 * i
                                               for i in range(32)])
        out = codec.decode_spec(codec.encode_spec(spec))
        a = sweep.argmin_stream(spec, B200, chunk_size=100)
        b = sweep.argmin_stream(out, B200, chunk_size=100)
        assert a.index == b.index and a.total == b.total
        assert a.name == b.name and a.breakdown == b.breakdown

    def test_plan_is_tiny_even_for_huge_lattices(self):
        spec = LatticeSpec.cartesian(
            gemm_base(), k_tiles=list(range(1, 1025)),
            num_ctas=list(range(1, 1025)),
            tma_participants=[1, 2, 4, 8] * 256)
        assert spec.n_rows == 1024 * 1024 * 1024
        assert len(codec.encode_spec(spec)) < 64 * 1024


class TestResultRoundTrip:
    def winners(self):
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        eng = sweep.SweepEngine(use_cache=False)
        return sweep.topk_table(table, B200, 5, engine=eng)

    def test_winners_round_trip_exact(self):
        wins = self.winners()
        out = codec.decode_winners(codec.encode_winners(wins))
        assert len(out) == len(wins)
        for a, b in zip(wins, out):
            assert (a.index, a.name) == (b.index, b.name)
            assert a.total == b.total          # bit-exact float round-trip
            assert a.breakdown == b.breakdown
            assert a.breakdown.detail == b.breakdown.detail

    def test_nan_total_survives(self):
        w = self.winners()[0]
        import dataclasses
        nan_w = dataclasses.replace(w, total=float("nan"))
        out = codec.decode_winners(codec.encode_winners([nan_w]))[0]
        assert np.isnan(out.total)

    def test_totals_round_trip(self):
        t = np.array([1.5e-3, np.nan, -0.0, np.inf, 7e-9])
        out = codec.decode_totals(codec.encode_totals(t))
        assert out.tobytes() == t.tobytes()
        assert not out.flags.writeable

    def test_request_round_trip(self):
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        buf = codec.encode_request("topk", table, hw="b200", k=7,
                                   model="roofline", coalesce=False)
        op, source, meta = codec.decode_request(buf)
        assert op == "topk" and meta["k"] == 7
        assert meta["hw"] == "b200" and meta["model"] == "roofline"
        assert meta["coalesce"] is False
        assert table_equal(source, table)
        spec = LatticeSpec.tile_lattice(gemm_base(), TILES)
        op, source, meta = codec.decode_request(
            codec.encode_request("argmin", spec, hw="mi300a",
                                 chunk_size=512, jobs=2))
        assert op == "argmin" and meta["chunk_size"] == 512
        assert meta["jobs"] == 2
        assert source.n_rows == spec.n_rows

    def test_json_and_error_round_trip(self):
        payload = {"status": "ok", "n": 3, "nested": {"a": [1, 2]}}
        assert codec.decode_json(codec.encode_json(payload)) == payload
        buf = codec.encode_error(ValueError("boom"))
        with pytest.raises(codec.RemoteError, match="ValueError: boom"):
            codec.raise_if_error(buf)
        codec.raise_if_error(codec.encode_json({}))   # non-error: no-op


class TestMalformed:
    def payloads(self):
        table = next(iter(sample_tables()))
        return [codec.encode_table(table),
                codec.encode_spec(LatticeSpec.tile_lattice(gemm_base(),
                                                           TILES)),
                codec.encode_winners(self.__class__._wins),
                codec.encode_totals(np.arange(4.0)),
                codec.encode_request("argmin", table, hw="b200")]

    _wins = None

    @classmethod
    def setup_class(cls):
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        cls._wins = sweep.topk_table(
            table, B200, 2, engine=sweep.SweepEngine(use_cache=False))

    def _decoders(self):
        return (codec.decode_table, codec.decode_spec,
                codec.decode_winners, codec.decode_totals,
                codec.decode_request, codec.message_type)

    def test_truncations_raise_cleanly(self):
        for buf in self.payloads():
            step = max(1, len(buf) // 23)     # every stratum of the buffer
            for cut in list(range(0, len(buf), step)) + [len(buf) - 1]:
                for decode in self._decoders():
                    with pytest.raises(codec.WireFormatError):
                        decode(buf[:cut])

    def test_bad_magic_and_version(self):
        buf = bytearray(self.payloads()[0])
        bad = b"XXXX" + bytes(buf[4:])
        with pytest.raises(codec.WireFormatError, match="magic"):
            codec.decode_table(bad)
        future = bytes(buf[:4]) + (99).to_bytes(2, "little") + \
            bytes(buf[6:])
        with pytest.raises(codec.WireFormatError, match="version"):
            codec.decode_table(future)

    def test_wrong_message_type(self):
        with pytest.raises(codec.WireFormatError, match="expected table"):
            codec.decode_table(codec.encode_totals(np.arange(3.0)))
        with pytest.raises(codec.WireFormatError, match="expected totals"):
            codec.decode_totals(self.payloads()[0])

    def test_section_bounds_are_checked(self):
        buf = bytearray(self.payloads()[3])
        # rewrite the first section's length to reach past the buffer
        import struct
        tag, off, ln = struct.unpack_from("<4sQQ", buf, 12)
        struct.pack_into("<4sQQ", buf, 12, tag, off, len(buf) * 2)
        with pytest.raises(codec.WireFormatError, match="outside"):
            codec.decode_totals(bytes(buf))

    def test_garbage_json_meta(self):
        # corrupting an encoded message in place now trips the CRC32
        # integrity check before the JSON parse ever runs
        good = codec.encode_json({"x": 1})
        bad = good.replace(b'{"payload"', b'{"payload!!')
        with pytest.raises(codec.WireFormatError, match="checksum"):
            codec.decode_json(bad)
        # an authentically-stamped garbage payload (valid checksum over
        # invalid JSON) still reaches the pointed JSON error
        stamped = codec._pack(codec.MSG_JSON,
                              [(b"meta", b'{"payload!!: 1}')])
        with pytest.raises(codec.WireFormatError, match="JSON"):
            codec.decode_json(stamped)

    def test_wrong_column_payload_size(self):
        table = next(iter(sample_tables()))
        assert len(table) == 3
        # lie about the row count in the meta section (same digit width,
        # so the section table still frames the JSON correctly)
        bad = codec.encode_table(table).replace(b'"n":3', b'"n":4', 1)
        with pytest.raises(codec.WireFormatError):
            codec.decode_table(bad)

    def test_codes_outside_vocab_rejected(self):
        table = WorkloadTable.tile_lattice(gemm_base(), TILES[:2])
        n = len(table)
        bad = WorkloadTable(np.array(table.cols),
                            np.array([0, 5], dtype=np.intp)[:n], ("fp16",),
                            np.zeros(n, dtype=np.intp), ("compute",))
        with pytest.raises(codec.WireFormatError, match="vocabulary"):
            codec.decode_table(codec.encode_table(bad))

    def test_random_garbage_never_escapes_wireformaterror(self):
        rng = np.random.default_rng(11)
        real = self.payloads()[0]
        for _ in range(200):
            size = int(rng.integers(0, 200))
            blob = rng.integers(0, 256, size).astype(np.uint8).tobytes()
            if rng.random() < 0.5 and len(real) > 8:
                # realistic header, scrambled body
                blob = real[:8] + blob
            for decode in self._decoders():
                try:
                    decode(blob)
                except codec.WireFormatError:
                    pass

    if HAVE_HYPOTHESIS:
        @settings(max_examples=200, deadline=None)
        @given(st.binary(max_size=300))
        def test_hypothesis_garbage(self, blob):
            for decode in self._decoders():
                try:
                    decode(blob)
                except codec.WireFormatError:
                    pass

        @settings(max_examples=100, deadline=None)
        @given(st.data())
        def test_hypothesis_flip_bytes(self, data):
            buf = bytearray(codec.encode_totals(np.arange(8.0)))
            i = data.draw(st.integers(0, len(buf) - 1))
            buf[i] ^= data.draw(st.integers(1, 255))
            try:
                codec.decode_totals(bytes(buf))
            except codec.WireFormatError:
                pass


class TestIntegrity:
    """The CRC32 integrity section: bit flips in transit become clean
    ``WireFormatError``s, never silently wrong floats."""

    def test_every_single_byte_flip_is_caught_or_harmless(self):
        # flip one bit in EVERY byte of a totals message: decode must
        # either raise WireFormatError or return the exact original
        # (a flip in padding can be genuinely harmless; a flip anywhere
        # that reaches the numbers must be caught)
        ref = np.arange(16.0) * 1.5
        buf = codec.encode_totals(ref)
        survived_wrong = []
        for i in range(len(buf)):
            bad = bytearray(buf)
            bad[i] ^= 0x10
            try:
                out = codec.decode_totals(bytes(bad))
            except codec.WireFormatError:
                continue
            if not np.array_equal(out, ref):
                survived_wrong.append(i)
        assert survived_wrong == []

    def test_table_payload_flip_is_caught(self):
        table = next(iter(sample_tables()))
        buf = bytearray(codec.encode_table(table))
        buf[-20] ^= 0x01          # land inside a trailing payload section
        with pytest.raises(codec.WireFormatError, match="checksum"):
            codec.decode_table(bytes(buf))

    def test_unstamped_messages_still_decode(self):
        # pre-integrity peers (or checksum=False packers) stay readable:
        # the csum section is additive, not mandatory
        ref = np.arange(5.0)
        unstamped = codec._pack(
            codec.MSG_TOTALS,
            [(b"meta", codec._json_bytes({"n": 5})),
             (b"tots", np.ascontiguousarray(ref).tobytes())],
            checksum=False)
        assert b"csum" not in unstamped[:64]
        assert np.array_equal(codec.decode_totals(unstamped), ref)

    def test_checksum_roundtrip_all_message_kinds(self):
        for payload in (codec.encode_json({"a": [1, 2]}),
                        codec.encode_totals(np.arange(3.0)),
                        codec.encode_table(next(iter(sample_tables())))):
            # a clean message decodes (checksum self-consistent)
            codec.raise_if_error(payload)


class TestContentTokenCanonicalization:
    """The vocab-order bugfix: identical rows => identical token."""

    def _pair(self):
        w1 = gemm_base("a")
        w2 = streaming_workload("b", 1e9, precision="fp32")
        ta = WorkloadTable.from_workloads([w1, w2])
        # same rows, opposite vocab insertion order
        tb = WorkloadTable.from_workloads([w2, w1]).take(np.array([1, 0]))
        return ta, tb

    def test_cross_order_tokens_match(self):
        ta, tb = self._pair()
        assert ta.precision_vocab != tb.precision_vocab   # the trap
        assert np.array_equal(ta.cols, tb.cols)
        assert ta.content_token() == tb.content_token()

    def test_cross_order_tables_hit_the_memo_cache(self):
        ta, tb = self._pair()
        eng = sweep.SweepEngine()
        eng.predict_table(ta, B200)
        before = eng.cache_stats()
        res = eng.predict_table(tb, B200)
        after = eng.cache_stats()
        assert after["hits"] == before["hits"] + len(tb)
        assert after["table_entries"] == before["table_entries"]
        # and the served rows are correct for tb's row order
        ref = sweep.SweepEngine(use_cache=False).predict_table(tb, B200)
        assert list(res.totals) == list(ref.totals)

    def test_wire_decoded_table_hits_the_cache(self):
        table = WorkloadTable.concat([
            WorkloadTable.from_workloads([gemm_base("x")]),
            WorkloadTable.from_workloads(
                [streaming_workload("y", 1e8)])])
        out = codec.decode_table(codec.encode_table(table))
        eng = sweep.SweepEngine()
        eng.predict_table(table, B200)
        before = eng.cache_stats()["hits"]
        eng.predict_table(out, B200)
        assert eng.cache_stats()["hits"] == before + len(table)

    def test_different_content_still_differs(self):
        ta, _ = self._pair()
        other = WorkloadTable.from_workloads(
            [gemm_base("a"), streaming_workload("b", 2e9,
                                                precision="fp32")])
        assert ta.content_token() != other.content_token()
        # same cols, different per-row precision strings must differ
        w = gemm_base("a")
        t1 = WorkloadTable.from_workloads([w])
        t2 = WorkloadTable(np.array(t1.cols),
                           np.zeros(1, dtype=np.intp), ("fp8",),
                           np.zeros(1, dtype=np.intp), ("compute",))
        assert t1.content_token() != t2.content_token()

    def test_unused_vocab_entries_are_ignored(self):
        w = gemm_base("a")
        t1 = WorkloadTable.from_workloads([w])
        t2 = WorkloadTable(np.array(t1.cols),
                           np.zeros(1, dtype=np.intp), ("fp16", "fp4"),
                           np.zeros(1, dtype=np.intp),
                           ("compute", "memory"))
        assert t1.content_token() == t2.content_token()


class TestWorkloadDictRoundTrip:
    def test_to_from_dict(self):
        ws = [gemm_base(), streaming_workload("s", 1e9, irregular=True),
              Workload(name="hr", wclass="memory", flops=1e9, bytes=1e9,
                       hit_rates={"h_l2": 0.7})]
        for w in ws:
            out = Workload.from_dict(json.loads(json.dumps(w.to_dict())))
            assert out == w


class TestWireV2HardwareAndCalibration:
    """v2 message types: hardware entries, calibrations, measured suites,
    calibrate requests — plus the v1 backward-decode guarantee."""

    def test_hardware_entry_round_trips_with_audit_trail(self):
        from repro.core import hwlib
        path = hwlib.library_file("b200")
        entry = hwlib.load_file(path)
        out = codec.decode_hardware(codec.encode_hardware(entry))
        assert out.params == entry.params
        assert out.provenance == entry.provenance
        assert out.units == entry.units
        assert out.source == entry.source
        # a decoded entry prices bit-identically to the local one
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        eng = sweep.SweepEngine(use_cache=False)
        assert np.array_equal(eng.predict_table(table, out.params).totals,
                              eng.predict_table(table, entry.params).totals)

    def test_bare_params_encode_as_entry(self):
        out = codec.decode_hardware(codec.encode_hardware(B200))
        assert out.params == B200
        assert out.provenance == {}

    def test_hardware_decode_rejects_schema_violations(self):
        from repro.core import hwlib
        doc = hwlib.HardwareEntry(params=B200).to_doc()
        doc["params"]["num_sms"] = "lots"
        bad = codec._pack(codec.MSG_HARDWARE,
                          [(b"meta", json.dumps({"entry": doc}).encode())])
        with pytest.raises(codec.WireFormatError, match="bad hardware"):
            codec.decode_hardware(bad)
        with pytest.raises(codec.WireFormatError, match="missing its entry"):
            codec.decode_hardware(codec._pack(
                codec.MSG_HARDWARE, [(b"meta", b"{}")]))

    def test_calibration_round_trips_with_disclosure(self):
        from repro.core.calibrate import Calibration
        cal = Calibration(per_case={"k1": 1.25, "k2": 0.5},
                          per_class={"memory": 2.0},
                          global_scale=1.1, skipped=["dead_kernel"])
        report = {"train_mae": 0.5, "holdout_mae": 1.5}
        out, rep = codec.decode_calibration(
            codec.encode_calibration(cal, report))
        assert out.to_dict() == cal.to_dict()
        assert out.disclose() == cal.disclose()
        assert rep == report
        out2, rep2 = codec.decode_calibration(codec.encode_calibration(cal))
        assert out2.to_dict() == cal.to_dict() and rep2 is None

    def test_calibration_decode_rejects_unknown_keys(self):
        bad = codec._pack(codec.MSG_CALIBRATION, [(b"meta", json.dumps(
            {"calibration": {"scale": 2.0}}).encode())])
        with pytest.raises(codec.WireFormatError, match="bad calibration"):
            codec.decode_calibration(bad)

    def test_suite_round_trips_measurements_bit_exactly(self):
        from repro.core.microbench import MeasuredSuite
        ws = [gemm_base(f"s{i}", 1024 + 256 * i) for i in range(5)]
        meas = [1e-3 * (1 + i) / 3.0 for i in range(5)]
        suite = MeasuredSuite(name="t", workloads=ws, measured_s=meas,
                              meta={"repeats": 7.0})
        out = codec.decode_suite(codec.encode_suite(suite))
        assert out.name == suite.name
        assert out.measured_s == meas          # float64 column, bit-exact
        assert out.meta == suite.meta
        assert [w.to_dict() for w in out.workloads] == \
            [w.to_dict() for w in ws]

    def test_suite_decode_rejects_length_mismatch(self):
        from repro.core.microbench import MeasuredSuite
        suite = MeasuredSuite(name="t", workloads=[gemm_base()],
                              measured_s=[1e-3])
        raw = bytearray(codec.encode_suite(suite))
        # claim 2 measurements in the meta: the raw column no longer fits
        raw = codec._pack(codec.MSG_SUITE, [
            (b"meta", json.dumps({"name": "t", "workloads": [],
                                  "meta": {}, "n": 2}).encode()),
            (b"meas", b"\x00" * 8)])
        with pytest.raises(codec.WireFormatError, match="meas"):
            codec.decode_suite(raw)

    def test_calibrate_request_round_trips(self):
        from repro.core.microbench import MeasuredSuite
        suite = MeasuredSuite(name="t",
                              workloads=[gemm_base(f"c{i}") for i in
                                         range(3)],
                              measured_s=[1e-3, 2e-3, 3e-3])
        body = codec.encode_calibrate_request(
            suite, hw="b200", mode="case", holdout_fraction=0.25, seed=7,
            model="roofline", register_as="mine")
        out, params = codec.decode_calibrate_request(body)
        assert out.measured_s == suite.measured_s
        assert params["hw"] == "b200" and params["mode"] == "case"
        assert params["holdout_fraction"] == 0.25 and params["seed"] == 7
        assert params["model"] == "roofline"
        assert params["register_as"] == "mine"
        with pytest.raises(ValueError, match="unknown calibrate mode"):
            codec.encode_calibrate_request(suite, hw="b200", mode="median")

    def test_v1_messages_still_decode(self):
        """Backward-decode guarantee: a v1 envelope (types 1-7 unchanged)
        decodes under the v2 codec."""
        table = WorkloadTable.tile_lattice(gemm_base(), TILES)
        body = bytearray(codec.encode_request("argmin", table, hw="b200"))
        assert body[4:6] == (2).to_bytes(2, "little")
        body[4:6] = (1).to_bytes(2, "little")     # stamp a v1 envelope
        op, source, meta = codec.decode_request(bytes(body))
        assert op == "argmin" and meta["hw"] == "b200"
        assert source.content_token() == table.content_token()
        # v1 senders never stamp a calibration name
        assert "calibration" not in meta

    def test_request_without_calibration_matches_v1_meta_shape(self):
        table = WorkloadTable.tile_lattice(gemm_base(), TILES[:2])
        plain = codec.encode_request("argmin", table, hw="b200")
        _, _, meta = codec.decode_request(plain)
        assert "calibration" not in meta
        named = codec.encode_request("argmin", table, hw="b200",
                                     calibration="fit1")
        _, _, meta2 = codec.decode_request(named)
        assert meta2["calibration"] == "fit1"

    def test_v2_types_rejected_under_wrong_expected_type(self):
        from repro.core.calibrate import Calibration
        msg = codec.encode_calibration(Calibration())
        with pytest.raises(codec.WireFormatError, match="expected hardware"):
            codec.decode_hardware(msg)
        with pytest.raises(codec.WireFormatError, match="expected suite"):
            codec.decode_suite(msg)

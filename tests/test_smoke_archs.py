"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement), plus decode-path equivalence and full-config bookkeeping."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, all_cells, cell_applicable, \
    get_config, memory_len
from repro.models import build

SEQ = 16
BATCH = 2


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (BATCH, SEQ), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    mlen = memory_len(cfg, SEQ)
    if mlen is not None:
        batch["memory_embeds"] = jax.random.normal(
            k2, (BATCH, max(mlen, 4), cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = get_config(arch, smoke=True)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        logits, aux = model.forward(params, batch["tokens"],
                                    memory_embeds=batch.get("memory_embeds"))
        assert logits.shape == (BATCH, SEQ, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert bool(jnp.isfinite(aux))

    def test_one_train_step(self, arch):
        """loss + grads + SGD step: finite loss, finite grads, params move."""
        cfg = get_config(arch, smoke=True)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))

        def loss(p):
            l, _ = model.loss_fn(p, batch)
            return l

        l0, grads = jax.value_and_grad(loss)(params)
        assert bool(jnp.isfinite(l0))
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
        new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                           params, grads)
        l1 = loss(new)
        assert bool(jnp.isfinite(l1))

    def test_decode_matches_forward(self, arch):
        cfg = get_config(arch, smoke=True)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        logits, _ = model.forward(params, batch["tokens"],
                                  memory_embeds=batch.get("memory_embeds"))
        cache = model.init_cache(BATCH, SEQ)
        last, _ = model.prefill(params, batch["tokens"], cache,
                                memory_embeds=batch.get("memory_embeds"))
        err = float(jnp.max(jnp.abs(last - logits[:, -1, :])))
        assert err < 5e-3, err

    def test_full_config_bookkeeping(self, arch):
        """Full config: analytic param count sane, exact assigned dims."""
        cfg = get_config(arch)
        n = cfg.param_count()
        assert n > 1e6
        # spot-check assigned dimensions
        expected = {
            "mamba2-1.3b": (48, 2048, 50280),
            "h2o-danube-1.8b": (24, 2560, 32000),
            "minicpm-2b": (40, 2304, 122753),
            "deepseek-67b": (95, 8192, 102400),
            "llama3-405b": (126, 16384, 128256),
            "deepseek-v3-671b": (61, 7168, 129280),
            "qwen3-moe-235b-a22b": (94, 4096, 151936),
            "whisper-tiny": (4, 384, 51865),
            "recurrentgemma-9b": (38, 4096, 256000),
            "llama-3.2-vision-90b": (100, 8192, 128256),
        }[arch]
        assert (cfg.n_layers, cfg.d_model, cfg.vocab) == expected


class TestParamCountsVsBillions:
    """Analytic totals must land near the advertised model sizes."""

    @pytest.mark.parametrize("arch,lo,hi", [
        ("mamba2-1.3b", 1.1e9, 1.6e9),
        ("h2o-danube-1.8b", 1.5e9, 2.1e9),
        ("minicpm-2b", 2.0e9, 3.2e9),
        ("deepseek-67b", 60e9, 72e9),
        ("llama3-405b", 380e9, 430e9),
        ("deepseek-v3-671b", 620e9, 720e9),
        ("qwen3-moe-235b-a22b", 210e9, 260e9),
        ("whisper-tiny", 25e6, 60e6),
        ("recurrentgemma-9b", 8e9, 11e9),
        ("llama-3.2-vision-90b", 80e9, 100e9),
    ])
    def test_total_params(self, arch, lo, hi):
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo / 1e9}," \
                              f" {hi / 1e9}]B"

    def test_moe_active_params(self):
        """deepseek-v3: ~37B active of 671B; qwen3: ~22B active of 235B."""
        ds = get_config("deepseek-v3-671b")
        assert 30e9 <= ds.active_param_count() <= 45e9
        qw = get_config("qwen3-moe-235b-a22b")
        assert 18e9 <= qw.active_param_count() <= 28e9


class TestCellMatrix:
    def test_40_cells(self):
        cells = all_cells()
        assert len(cells) == 40
        runnable = [c for c in cells if c[2]]
        skipped = [c for c in cells if not c[2]]
        # long_500k runs only for the 3 sub-quadratic archs
        assert len(skipped) == 7
        assert all(s[1] == "long_500k" for s in skipped)
        assert len(runnable) == 33

    def test_decode_shapes_exist_for_encdec(self):
        """whisper is enc-dec (has a decoder) -> decode cells runnable."""
        ok, _ = cell_applicable("whisper-tiny", "decode_32k")
        assert ok

"""MoE correctness: scatter dispatch vs dense loop oracle, capacity
behavior, gate normalization, aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe

CFG = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                  pattern=("moe",), n_experts=8, top_k=2, d_expert=48,
                  capacity_factor=8.0)   # high cf: no drops -> exact


class TestDispatch:
    def test_matches_dense_oracle(self):
        p = moe.moe_init(jax.random.PRNGKey(0), CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        out, aux = moe.moe_apply(p, x, CFG)
        ref = moe.moe_apply_reference(p, x, CFG)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-4)
        assert float(aux) > 0

    def test_with_shared_expert(self):
        cfg = CFG.replace(n_shared_experts=1)
        p = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        out, _ = moe.moe_apply(p, x, cfg)
        ref = moe.moe_apply_reference(p, x, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-4)

    def test_capacity_drops_reduce_output(self):
        """With tiny capacity, some tokens are dropped (residual path):
        output norm strictly smaller than the no-drop oracle's."""
        cfg = CFG.replace(capacity_factor=0.01)
        p = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
        out, _ = moe.moe_apply(p, x, cfg)
        ref = moe.moe_apply_reference(p, x, cfg)
        assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(ref))

    def test_gates_normalized(self):
        p = moe.moe_init(jax.random.PRNGKey(0), CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
        logits = x.reshape(-1, 32).astype(jnp.float32) @ p["w_router"]
        gv, _ = jax.lax.top_k(jax.nn.softmax(logits, -1), CFG.top_k)
        gv = gv / jnp.sum(gv, axis=-1, keepdims=True)
        np.testing.assert_allclose(np.asarray(jnp.sum(gv, -1)), 1.0,
                                   atol=1e-6)

    def test_grad_flows_through_dispatch(self):
        p = moe.moe_init(jax.random.PRNGKey(0), CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

        def loss(pp):
            out, aux = moe.moe_apply(pp, x, CFG)
            return jnp.sum(out.astype(jnp.float32) ** 2) + aux

        g = jax.grad(loss)(p)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.all(jnp.isfinite(leaf)))
        # expert weights actually receive gradient
        assert float(jnp.linalg.norm(g["we_g"])) > 0

    def test_aux_loss_balanced_lower_than_collapsed(self):
        """Uniform routing should have lower aux loss than collapsed."""
        p = moe.moe_init(jax.random.PRNGKey(0), CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
        _, aux_normal = moe.moe_apply(p, x, CFG)
        # collapse routing: all mass on expert 0 regardless of input
        p2 = dict(p)
        p2["w_router"] = jnp.zeros_like(p["w_router"]).at[:, 0].set(10.0)
        _, aux_collapsed = moe.moe_apply(p2, x, CFG)
        assert float(aux_collapsed) > float(aux_normal)
